"""QPART beyond classifiers: a decoder LM through the FULL serving
pipeline.

With the ``ModelBackend`` protocol a transformer goes through the same
calibrate → build_store → serve → execute path as the paper's
classifiers: per-block (z_w, z_x, o) come from the analytic cost model,
Alg. 1 tabulates per-block bit-widths + partition points, Alg. 2 picks a
plan per request context, and ``Deployment.execute`` really runs the
quantized device blocks + quantized cut activation + f32 server tail —
reporting measured accuracy degradation.

This is the TPU-serving view from DESIGN.md §3: the same water-filled
bit allocation that cuts the radio payload cuts HBM traffic for the
W8/W4 Pallas matmul kernels in repro/kernels.

  PYTHONPATH=src python examples/quantized_lm_serving.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.quantizer import round_bits
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest

SEQ = 32


def cycle_batch(rng, vocab, n):
    """Learnable synthetic next-token task: t[i+1] = (t[i] + 1) % V."""
    start = rng.integers(0, vocab, size=(n, 1))
    toks = (start + np.arange(SEQ + 1)[None, :]) % vocab
    return (jnp.asarray(toks[:, :SEQ], jnp.int32),
            jnp.asarray(toks[:, SEQ], jnp.int32))


def main():
    cfg = dataclasses.replace(
        get_config("smollm-135m"), name="smollm-8m", num_layers=4,
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=768,
        vocab_size=256, tp_pad=1, dtype="float32")
    key = jax.random.key(0)
    params = T.init_params(key, cfg)
    rng = np.random.default_rng(0)

    print("1) briefly train so quantization has something to preserve...")

    def loss_fn(p, toks):
        logits, _ = T.forward(p, cfg, toks[:, :-1])
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:][..., None], -1))

    @jax.jit
    def step(p, toks):
        l, g = jax.value_and_grad(loss_fn)(p, toks)
        return jax.tree.map(lambda a, b: a - 0.3 * b, p, g), l

    for i in range(300):
        start = rng.integers(0, cfg.vocab_size, size=(32, 1))
        toks = jnp.asarray((start + np.arange(SEQ + 1)[None, :])
                           % cfg.vocab_size, jnp.int32)
        params, l = step(params, toks)
    print(f"   final loss {float(l):.3f}")

    print("2) register the TransformerBackend; calibrate + build the "
          "pattern store (Alg. 1)...")
    # decode_max_len marks the backend decode-PLANNED: KV-cache
    # feasibility enters the plan mask and Deployment.generate streams
    backend = TransformerBackend(cfg, params, seq_len=SEQ, decode_max_len=64)
    srv = QPARTServer()
    x_cal, y_cal = cycle_batch(rng, cfg.vocab_size, 128)
    srv.register("smollm", backend, x_cal, y_cal)
    srv.calibrate("smollm")
    print(f"   base next-token accuracy: "
          f"{srv.models['smollm'].base_accuracy:.3f}")
    dev = DeviceProfile()
    ch = Channel(capacity_bps=2e6)
    # a server-cost-sensitive tenant: eta prices server MACs high enough
    # that keeping quantized blocks on-device wins (cf. the privacy
    # reading: raw tokens never leave the device when p = L)
    w = ObjectiveWeights(eta=1e7)
    srv.build_store("smollm", dev, ch, w)

    print("3) serve one edge request (Alg. 2) and really execute it...")
    req = InferenceRequest("smollm", 0.01, dev, ch, w, segment_cached=True)
    dep = srv.serve(req)
    plan = dep.plan
    bits = np.asarray(round_bits(plan.bits_w)) if plan.p else []
    L = backend.num_layers
    print(f"   partition p = {plan.p}/{L} blocks on-device, bits = {bits}")
    specs = backend.layer_specs()
    f32_bits = sum(sp.z_w for sp in specs[:plan.p]) * 32
    if plan.p:
        print(f"   device-segment payload: {plan.payload_w_bits/1e6:.1f} "
              f"Mbit vs {f32_bits/1e6:.1f} Mbit f32 "
              f"({100*(1-plan.payload_w_bits/max(f32_bits,1)):.0f}% saved)")
    x_te, y_te = cycle_batch(rng, cfg.vocab_size, 128)
    res = dep.execute(x_te, y_te)
    print(f"   measured accuracy {res.accuracy:.3f} "
          f"(degradation {100*res.accuracy_degradation:+.2f}% vs f32 on the "
          f"same set)")

    print("4) generate with the plan's quantized blocks, compare to f32...")
    qparams = quantize_blocks(params, bits, cfg.num_layers)
    x_p, _ = cycle_batch(rng, cfg.vocab_size, 2)
    prompt = x_p[:, :16]
    out_f32 = generate(params, cfg, prompt, max_len=32, gen=16)
    out_q = generate(qparams, cfg, prompt, max_len=32, gen=16)
    match = float(jnp.mean(out_f32 == out_q))
    print(f"   greedy tokens agree on {100*match:.0f}% of steps")
    assert res.accuracy_degradation <= 0.25, "quantization hurt the LM too much"

    print("5) stream the SAME deployment through the partitioned "
          "prefill→decode pipeline (DESIGN.md §11)...")
    streamed = []
    out = dep.generate(prompt, 16,
                       stream_cb=lambda i, tok: streamed.append(tok))
    assert len(streamed) == 16 and out.tokens.shape == (2, 16)
    print(f"   TTFT {out.ttft_s*1e3:.1f} ms, {out.tokens_per_s:.0f} tok/s "
          f"wall-clock; device KV cache {out.device_cache_bytes/1024:.0f} "
          f"KiB @ {out.device_cache_dtype} "
          f"(server tail {out.server_cache_bytes/1024:.0f} KiB)")
    stream_match = float(np.mean(out.tokens == np.asarray(out_f32)))
    print(f"   streamed tokens agree with f32 greedy on "
          f"{100*stream_match:.0f}% of steps")
    # the measured per-stage stream timings feed the calibration ledger —
    # decode and prefill samples sharpen one set of StageRates
    srv.record_decode(dep)
    print(f"   ledger now holds {len(srv.ledger.samples)} measured sample(s)")


def quantize_blocks(params, bits_per_block, num_blocks):
    """Fake-quantize the first `len(bits)` stacked blocks layer-wise."""
    from repro.core.quantizer import fake_quant
    out = jax.tree.map(lambda x: x, params)      # shallow copy
    for per, bp in enumerate(out["blocks"]):
        def q(leaf):
            new = []
            for layer in range(leaf.shape[0]):
                idx = layer * len(out["blocks"]) + per
                if idx < len(bits_per_block):
                    b = int(bits_per_block[idx])
                    new.append(fake_quant(leaf[layer], b))
                else:
                    new.append(leaf[layer])
            return jnp.stack(new)
        out["blocks"][per] = jax.tree.map(q, bp)
    return out


if __name__ == "__main__":
    main()
