"""QPART beyond classifiers: layer-wise quantized LM serving.

Applies the paper's decision layer to a transformer decoder: per-block
(z_w, z_x, o) come from the analytic cost model, the closed-form solver
picks the partition point + per-block bit-widths for an edge request,
the chosen blocks are really quantized (Eq. 10) and generation runs with
the quantized weights — comparing perplexity and payload against f32.

This is the TPU-serving view from DESIGN.md §3: the same water-filled
bit allocation that cuts the radio payload cuts HBM traffic for the
W8/W4 Pallas matmul kernels in repro/kernels.

  PYTHONPATH=src python examples/quantized_lm_serving.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   delta_coeff, eps_coeff,
                                   transformer_layer_specs, xi_coeff,
                                   ServerProfile)
from repro.core.quantizer import fake_quant, round_bits
from repro.core.solver import solve_joint
from repro.data.pipeline import TokenStream, TokenStreamConfig
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step


def quantize_blocks(params, bits_per_block, num_blocks):
    """Fake-quantize the first `len(bits)` stacked blocks layer-wise."""
    out = jax.tree.map(lambda x: x, params)      # shallow copy
    for per, bp in enumerate(out["blocks"]):
        def q(leaf):
            new = []
            for layer in range(leaf.shape[0]):
                idx = layer * len(out["blocks"]) + per
                if idx < len(bits_per_block):
                    b = int(bits_per_block[idx])
                    new.append(fake_quant(leaf[layer], b))
                else:
                    new.append(leaf[layer])
            return jnp.stack(new)
        out["blocks"][per] = jax.tree.map(q, bp)
    return out


def main():
    cfg = dataclasses.replace(
        get_config("smollm-135m"), name="smollm-8m", num_layers=4,
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=768,
        vocab_size=2048, tp_pad=1, dtype="float32")
    key = jax.random.key(0)
    params = T.init_params(key, cfg)

    print("1) briefly train so quantization has something to preserve...")
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=10,
                                                    total_steps=150),
                                   remat=False), donate_argnums=(0, 1))
    opt = init_opt_state(params)
    stream = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                           seq_len=129, batch_size=16))
    for i, batch in enumerate(stream.batches()):
        if i >= 150:
            break
        params, opt, m = step(params, opt, batch)
    print(f"   final loss {float(m['loss']):.3f}")

    print("2) solve layer-wise bits + partition for an edge request...")
    specs = transformer_layer_specs(cfg, seq_len=128, batch=1,
                                    mode="prefill")[1:]   # skip embed row
    L = len(specs)
    dev, ch, w = DeviceProfile(), Channel(capacity_bps=20e6), ObjectiveWeights()
    # noise stats: analytic scale (quantizer round-off law) with uniform
    # robustness — the LM analogue of Alg. 1's probes at CPU-budget scale
    rng = np.random.default_rng(0)
    s = np.array([sp.z_w for sp in specs]) * 1e-4
    rho = np.full(L, 1e-3)
    # privacy constraint: raw tokens must not leave the device, so full
    # offload (p = 0) is excluded — the solver picks the cheapest cut among
    # on-device segments (allow_full_offload=False)
    best, plans = solve_joint(
        [sp.z_w for sp in specs], [sp.z_x for sp in specs], s, s, rho,
        [sp.o for sp in specs], xi=xi_coeff(w, dev),
        delta_cost=delta_coeff(w, ServerProfile()),
        eps=eps_coeff(w, dev, ch), psi_budget=1e-2,
        allow_full_offload=False, input_z=128.0)
    bits = np.asarray(round_bits(best.bits_w))
    print(f"   partition p = {best.p}/{L} blocks on-device, bits = {bits}")

    f32_bits = sum(sp.z_w for sp in specs[:best.p]) * 32
    print(f"   device-segment payload: {best.payload_bits/1e6:.1f} Mbit vs "
          f"{f32_bits/1e6:.1f} Mbit f32 "
          f"({100*(1-best.payload_bits/max(f32_bits,1)):.0f}% saved)")

    print("3) generate with quantized weights, compare to f32...")
    qparams = quantize_blocks(params, bits, cfg.num_layers)
    prompt = next(stream.batches())["tokens"][:2, :32]
    out_f32 = generate(params, cfg, prompt, max_len=48, gen=16)
    out_q = generate(qparams, cfg, prompt, max_len=48, gen=16)
    match = float(jnp.mean(out_f32 == out_q))
    print(f"   greedy tokens agree on {100*match:.0f}% of steps")

    # eval: quantized xent vs f32 xent on held-out stream
    from repro.train.train_loop import lm_loss
    eval_batch = next(TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=129, batch_size=16,
        seed=9)).batches())
    l_f32, _ = lm_loss(params, cfg, eval_batch, remat=False)
    l_q, _ = lm_loss(qparams, cfg, eval_batch, remat=False)
    print(f"   eval xent: f32 {float(l_f32):.4f} vs quantized "
          f"{float(l_q):.4f} (delta {float(l_q - l_f32):+.4f})")
    assert float(l_q - l_f32) < 0.1, "quantization hurt the LM too much"


if __name__ == "__main__":
    main()
