"""Quickstart: the whole QPART loop in ~60 lines.

Trains the paper's 6-FC-layer MNIST classifier on the synthetic surrogate,
calibrates the quantization-noise model, builds the offline pattern store
(Alg. 1), and serves one inference request (Alg. 2) — printing the chosen
partition point, per-layer bit-widths, payload and the priced plan.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.quantizer import round_bits
from repro.data.pipeline import minibatches, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.backends import ClassifierBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest


def main():
    print("1) train the paper's MNIST MLP (synthetic surrogate)...")
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=8192, n_test=4096)
    params = init_classifier(jax.random.key(0), MNIST_MLP)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, MNIST_MLP, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.1 * b, p, g)

    it = minibatches(x_tr, y_tr, 128)
    for _ in range(400):
        bx, by = next(it)
        params = step(params, bx, by)
    acc = float(jnp.mean(jnp.argmax(
        classifier_forward(params, MNIST_MLP, jnp.asarray(x_te[:2048])), -1)
        == y_te[:2048]))
    print(f"   test accuracy: {acc:.4f}")

    print("2) register + calibrate on the QPART server (Alg. 1)...")
    srv = QPARTServer()
    backend = ClassifierBackend(MNIST_MLP, params)
    srv.register("mnist", backend, x_te[2048:3072], y_te[2048:3072])
    srv.calibrate("mnist")
    # a realistic edge setting: low-power device (200 MHz, cheap joules),
    # congested uplink (2 Mbps) — local inference beats uploading the raw
    # input (with the default 200 Mbps lab channel, full offload p=0 is
    # trivially optimal)
    dev = DeviceProfile()
    ch = Channel(capacity_bps=2e6)
    w = ObjectiveWeights()
    srv.build_store("mnist", dev, ch, w)

    print("3) serve a repeat request with a 1% accuracy budget (Alg. 2)...")
    # segment_cached: the device holds the quantized segment from an
    # earlier request, so only the cut activation is priced (uplink)
    req = InferenceRequest("mnist", accuracy_budget=0.01, device=dev,
                           channel=ch, weights=w, segment_cached=True)
    dep = srv.serve(req)                      # plan + priced Deployment
    res = dep.execute(jnp.asarray(x_te[:2048]), y_te[:2048])  # really run it
    plan = dep.plan
    specs = backend.layer_specs()
    print(f"   partition point p = {plan.p} "
          f"(device runs layers 1..{plan.p}, server the rest)")
    if plan.p:
        seg_f32 = sum(sp.z_w for sp in specs[:plan.p]) * 32
        print(f"   per-layer bits    = {np.asarray(round_bits(plan.bits_w))}")
        print(f"   activation bits   = {int(np.ceil(plan.bits_x))}")
        print(f"   cached segment    = {plan.payload_w_bits / 1e6:.2f} Mbit "
              f"({100 * (1 - plan.payload_w_bits / seg_f32):.1f}% below its "
              f"f32 size {seg_f32 / 1e6:.2f} Mbit)")
        print(f"   uplink activation = {res.payload_bits / 1e3:.2f} kbit "
              f"(vs raw input {784 * 32 / 1e3:.1f} kbit)")
    print(f"   time {res.costs.t_total * 1e3:.2f} ms | energy "
          f"{res.costs.e_total * 1e3:.2f} mJ | objective {res.objective:.4f}")
    print(f"   measured accuracy  = {res.accuracy:.4f} "
          f"(degradation {100 * res.accuracy_degradation:.2f}% vs "
          f"budget {100 * req.accuracy_budget:.0f}%)")
    # Delta calibration is statistical (calib and eval are different
    # splits); allow the tier-1 suite's 2x slack + noise floor
    assert res.accuracy_degradation <= 2 * req.accuracy_budget + 0.02


if __name__ == "__main__":
    main()
