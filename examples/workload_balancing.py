"""Dynamic workload balancing (the paper title's second half): a window of
concurrent inference requests share one server; as the queue builds, the
re-priced Eq. 17 objective pushes later requests' partition points toward
their devices — no new math, just the paper's objective under load.

  PYTHONPATH=src python examples/workload_balancing.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.data.pipeline import minibatches, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.backends import ClassifierBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.scheduler import WorkloadBalancer, total_latency
from repro.serving.simulator import InferenceRequest


def main():
    print("training + calibrating the MNIST classifier...")
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=8192, n_test=4096)
    params = init_classifier(jax.random.key(0), MNIST_MLP)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, MNIST_MLP, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.1 * b, p, g)

    it = minibatches(x_tr, y_tr, 128)
    for _ in range(400):
        bx, by = next(it)
        params = step(params, bx, by)

    # a 1 GHz shared server: strong enough that low-load requests offload
    # layers to it, weak enough that a 48-request backlog visibly moves
    # the Eq. 17 optimum toward the devices
    shared = ServerProfile(f_clock=1e9)
    srv = QPARTServer(shared)
    srv.register("mnist", ClassifierBackend(MNIST_MLP, params),
                 x_te[2048:3072], y_te[2048:3072])
    srv.calibrate("mnist")
    dev = DeviceProfile()
    ch = Channel(capacity_bps=2e6)
    w = ObjectiveWeights()
    srv.build_store("mnist", dev, ch, w)

    reqs = [InferenceRequest("mnist", 0.01, dev, ch, w, segment_cached=True)
            for _ in range(48)]
    bal = WorkloadBalancer(shared, policy="fcfs")
    results = bal.schedule(srv, reqs)
    print(f"\n{'req':>4} {'queue ms':>9} {'p':>2}  (identical requests; the "
          f"growing queue pushes work on-device)")
    last_p = None
    for i, r in enumerate(results):
        if r.result.plan.p != last_p or i in (0, len(results) - 1):
            print(f"{i:>4} {r.queue_delay*1e3:>8.2f} {r.result.plan.p:>2}")
            last_p = r.result.plan.p
    ps = [r.result.plan.p for r in results]
    assert ps[-1] > ps[0], "congestion should push partition points up"

    # heterogeneous window: balanced (SJF) vs FCFS
    strong = dataclasses.replace(dev, f_clock=2e9)
    mixed = [InferenceRequest("mnist", 0.01, strong if i % 2 else dev, ch, w,
                              segment_cached=True) for i in range(12)]
    t_f = total_latency(WorkloadBalancer(shared,
                                         policy="fcfs").schedule(srv, mixed))
    t_b = total_latency(WorkloadBalancer(shared,
                                         policy="balanced").schedule(srv, mixed))
    print(f"\nheterogeneous window of 12: total latency "
          f"FCFS {t_f*1e3:.1f} ms vs balanced {t_b*1e3:.1f} ms "
          f"({100*(1 - t_b/t_f):.1f}% better)")


if __name__ == "__main__":
    main()
