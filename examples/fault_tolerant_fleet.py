"""Fault-tolerant fleet serving (serving.engine resilience layer,
DESIGN.md §10): bursty MMPP arrivals over a 3-server fleet while devices
churn — disconnects cancel in-flight attempts (server reservations
released, pending cache installs invalidated), a RetryPolicy re-admits
with capped backoff and a degraded accuracy budget, requests whose
device never returns drain to the dead-letter queue, and the whole run
replays bit-for-bit from its event journal.

The QPART server is stub-calibrated (synthetic noise constants, real
Alg. 1 pattern store): the fault dynamics exercise the pricing/queueing
path only, so the demo needs no training and runs in seconds.

  PYTHONPATH=src python examples/fault_tolerant_fleet.py
"""
import numpy as np

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import (DISCONNECT, RECONNECT,
                                  FaultEvent, FaultInjector,
                                  FleetEngine, RetryPolicy, churn_trace,
                                  degrade_trace, materialize, mmpp_arrivals)
from repro.serving.qpart_server import QPARTServer
from repro.serving.testing import stub_classifier_server

W = ObjectiveWeights()
FLEET = [ServerProfile(f_clock=3e8)] * 3
DEVICES = [DeviceProfile(f_clock=f) for f in (4e8, 1e9, 2e9)]
CHANNELS = [Channel(capacity_bps=c) for c in (2e6, 1e7, 5e7)]
POOL = 60                       # repeat-requester population


def stub_server() -> QPARTServer:
    return stub_classifier_server([("mnist", MNIST_MLP)], server=FLEET[0],
                                  device=DEVICES[0], channel=CHANNELS[1],
                                  weights=W)


def make_trace(n=500, seed=0):
    # bursty arrivals: calm 200 rps, bursts of 1400 rps
    arrivals = mmpp_arrivals(n, rates=(200.0, 1400.0),
                             mean_dwell=(0.4, 0.1), seed=seed)
    return materialize("mnist", arrivals, DEVICES, CHANNELS, W,
                       budgets=(0.004, 0.01, 0.02),
                       deadlines=(0.020, 0.035, 0.060),
                       batches=(1, 1, 4), device_pool=POOL, seed=seed)


def make_faults(horizon, seed=0):
    """Churn a third of the pool, drift another third, and kill two
    devices mid-trace (they never reconnect)."""
    flappy = [f"dev-{i}" for i in range(0, POOL, 3)]
    drifty = [f"dev-{i}" for i in range(1, POOL, 3)]
    deaths = FaultInjector([FaultEvent(horizon * 0.4, DISCONNECT, "dev-2"),
                            FaultEvent(horizon * 0.6, DISCONNECT, "dev-5")])
    return (churn_trace(flappy, horizon, mean_uptime=0.3,
                        mean_downtime=0.1, seed=seed)
            + degrade_trace(drifty, horizon, mean_interval=0.8,
                            mean_duration=0.2, seed=seed + 1)
            + deaths)


def main():
    srv = stub_server()
    trace = make_trace()
    horizon = trace[-1].arrival_time + 0.5
    faults = make_faults(horizon)
    retry = RetryPolicy(max_attempts=3, base_backoff_s=0.01,
                        max_backoff_s=0.1, degrade_on_retry=True)
    print(f"{len(trace)} MMPP arrivals over {trace[-1].arrival_time:.2f} s, "
          f"{len(FLEET)} servers, {len(faults)} ambient fault events "
          f"(churn + channel drift + 2 permanent losses)\n")

    # fault-free baseline vs the same trace under chaos
    base_m = FleetEngine(srv, servers=FLEET, policy="edf", slo="degrade",
                         epoch_interval=0.005).run(trace)
    base = base_m.summary()
    # aim a few micro-outages mid-window at the baseline's longest radio
    # transfers: random churn almost never intersects millisecond
    # transfers, targeted cuts make the cancel -> retry path visible
    longest = sorted((r for r in base_m.completed() if r.request.device_id),
                     key=lambda r: r.timeline.transfer_done
                     - r.timeline.admit, reverse=True)
    cuts = []
    for r in longest[:25]:
        t = (r.timeline.admit + r.timeline.transfer_done) / 2
        cuts += [FaultEvent(t, DISCONNECT, r.request.device_id),
                 FaultEvent(t + 0.02, RECONNECT, r.request.device_id)]
    faults = faults + FaultInjector(cuts)
    eng = FleetEngine(srv, servers=FLEET, policy="edf", slo="degrade",
                      epoch_interval=0.005, retry=retry, faults=faults)
    m = eng.run(trace)
    m.assert_terminal()             # every request completed or dropped
    s = m.summary()

    print(f"{'':>22} {'fault-free':>10} {'chaos':>10}")
    for key in ("goodput_rps", "p99_latency_s", "deadline_miss_rate",
                "rejected", "degraded"):
        print(f"{key:>22} {base[key]:>10} {s[key]:>10}")
    print(f"\n  disrupted by faults : {s['disrupted']} "
          f"(cancelled in flight or parked on a down device)")
    print(f"  retried             : {s['retried']}")
    print(f"  dead-lettered       : {s['dead_lettered']}")
    print(f"  drop reasons        : {s['drop_reasons']}")
    for d in m.dead_letters[:3]:
        print(f"    index={d.index:4d} device={d.device_id:<8} "
              f"reason={d.reason} after {d.attempts} attempt(s)")

    # the determinism contract: the run's journal replays to an
    # identical journal (same engine config, fault schedule rebuilt
    # from the journaled FAULT entries)
    m.journal.verify_replay(srv, trace, servers=FLEET)
    print(f"\njournal: {len(m.journal.entries)} entries, "
          f"replay verified identical")
    assert s["completed"] + s["rejected"] == len(trace)
    assert np.isclose(sum(s["drop_reasons"].values()), s["rejected"])


if __name__ == "__main__":
    main()
