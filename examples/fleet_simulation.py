"""Event-driven fleet serving (serving.engine, DESIGN.md §8): Poisson
arrivals over a 3-server fleet, deadline-aware admission, engine-managed
device segment caches, and the pluggable admission policies side by side.

The QPART server is stub-calibrated (synthetic noise constants, real
Alg. 1 pattern store): the fleet dynamics exercise the pricing/queueing
path only, so the demo needs no training and runs in seconds.

  PYTHONPATH=src python examples/fleet_simulation.py
"""
import dataclasses

import numpy as np

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import poisson_trace, stub_classifier_server

W = ObjectiveWeights()
FLEET = [ServerProfile(f_clock=3e8)] * 3
DEVICES = [DeviceProfile(f_clock=f) for f in (4e8, 1e9, 2e9)]
CHANNELS = [Channel(capacity_bps=c) for c in (2e6, 1e7, 5e7)]


def stub_server() -> QPARTServer:
    return stub_classifier_server([("mnist", MNIST_MLP)], server=FLEET[0],
                                  device=DEVICES[0], channel=CHANNELS[1],
                                  weights=W)


def make_trace(n=400, rate=700.0, seed=0):
    # mixed batch sizes: zero-load server demands differ, so balanced
    # (shortest-demand-first) really orders differently from fcfs
    return poisson_trace("mnist", n, rate, DEVICES, CHANNELS, W,
                         budgets=(0.004, 0.01, 0.02),
                         deadlines=(0.020, 0.035, 0.060),
                         batches=(1, 1, 4), device_pool=60, seed=seed)


def main():
    srv = stub_server()
    trace = make_trace()
    print(f"{len(trace)} Poisson arrivals over {trace[-1].arrival_time:.2f} s "
          f"onto {len(FLEET)} servers (0.3 GHz each), 5 ms decision epochs\n")
    print(f"{'policy':>13} {'p50 ms':>7} {'p99 ms':>7} {'miss%':>6} "
          f"{'rej':>4} {'degr':>4} {'util':>5}")
    summaries = {}
    for policy in ("fcfs", "balanced", "edf", "least_loaded"):
        engine = srv.fleet(servers=FLEET, policy=policy, slo="degrade",
                           epoch_interval=0.005)
        m = engine.run(trace)
        s = m.summary()
        summaries[policy] = s
        print(f"{policy:>13} {s['p50_latency_s']*1e3:>7.2f} "
              f"{s['p99_latency_s']*1e3:>7.2f} "
              f"{100*s['deadline_miss_rate']:>6.1f} {s['rejected']:>4} "
              f"{s['degraded']:>4} "
              f"{np.mean(s['server_utilization']):>5.2f}")
    assert summaries["edf"]["deadline_miss_rate"] <= \
        summaries["fcfs"]["deadline_miss_rate"] + 0.05

    # segment-cache amortization: one device, three visits. The engine
    # ships the quantized segment once; later requests upload only the
    # cut activation (segment_cached decided by the ENGINE, not the
    # caller).
    dev = DEVICES[2]
    ch = Channel()                      # 200 Mbps: shipping the segment
    # is cheap enough that keeping layers on the device wins
    first = InferenceRequest("mnist", 0.01, dev, ch, W, device_id="alice")
    probe = FleetEngine(srv, servers=[ServerProfile(f_clock=1e7)])
    tl = probe.run([first]).records[0].timeline
    repeats = [dataclasses.replace(first, arrival_time=tl.ship_done + k)
               for k in (1.0, 2.0)]
    recs = FleetEngine(srv, servers=[ServerProfile(f_clock=1e7)]).run(
        [first] + repeats).records
    print("\nsegment cache (device 'alice', 10 MHz server so p > 0 wins):")
    for r in recs:
        dep = r.deployment
        kind = "activation-only" if dep.payload_bits == \
            dep.plan.payload_x_bits and dep.plan.p else "full shipment"
        print(f"  t={r.arrival:6.3f}s  p={dep.plan.p}  "
              f"wire={dep.payload_bits/1e3:8.1f} kbit  ({kind})")
    assert recs[1].deployment.payload_bits < recs[0].deployment.payload_bits


if __name__ == "__main__":
    main()
