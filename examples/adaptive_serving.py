"""Adaptive serving under changing conditions (the paper's core pitch):
the SAME model served to heterogeneous devices over fluctuating channels
picks different partition points and bit-widths per request.

Sweeps (channel capacity x device clock x accuracy budget) and prints the
plan QPART chooses for each — watch p move toward the device as the
channel degrades, and bits rise as the budget tightens.

  PYTHONPATH=src python examples/adaptive_serving.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.quantizer import round_bits
from repro.data.pipeline import minibatches, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.backends import ClassifierBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest


def train():
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=8192, n_test=4096)
    params = init_classifier(jax.random.key(0), MNIST_MLP)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, MNIST_MLP, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.1 * b, p, g)

    it = minibatches(x_tr, y_tr, 128)
    for _ in range(400):
        bx, by = next(it)
        params = step(params, bx, by)
    return params, (x_te, y_te)


def main():
    params, (x_te, y_te) = train()
    srv = QPARTServer()
    srv.register("mnist", ClassifierBackend(MNIST_MLP, params),
                 x_te[2048:3072], y_te[2048:3072])
    srv.calibrate("mnist")
    base_dev, base_ch, w = DeviceProfile(), Channel(), ObjectiveWeights()
    srv.build_store("mnist", base_dev, base_ch, w)

    print(f"{'channel':>10} {'device_clk':>10} {'budget':>7} {'cached':>6} "
          f"{'p':>2} {'bits':>20} {'uplink':>10} {'objective':>10}")
    scenarios = []
    for cap in (200e6, 20e6, 2e6, 0.5e6):             # Mbps: 200 .. 0.5
        for f_clk in (200e6, 50e6):                   # weak / weaker device
            for budget in (0.002, 0.02):
                for cached in (False, True):
                    scenarios.append((cap, f_clk, budget, cached))
    seen_plans = set()
    for cap, f_clk, budget, cached in scenarios:
        dev = dataclasses.replace(base_dev, f_clock=f_clk)
        ch = dataclasses.replace(base_ch, capacity_bps=cap)
        req = InferenceRequest("mnist", budget, dev, ch, w,
                               segment_cached=cached)
        res = srv.serve(req)                 # a Deployment (plan + costs)
        bits = np.asarray(round_bits(res.plan.bits_w)) if res.plan.p else []
        print(f"{cap/1e6:>8.1f}Mb {f_clk/1e6:>8.0f}MHz {budget:>7.3f} "
              f"{str(cached):>6} {res.plan.p:>2} {str(list(bits)):>20} "
              f"{res.payload_bits/1e3:>8.1f}kb {res.objective:>10.4f}")
        seen_plans.add((res.plan.p, tuple(bits.tolist()) if len(bits) else ()))
    print(f"\ndistinct plans chosen: {len(seen_plans)} "
          f"across {len(scenarios)} scenarios — the serving pattern adapts "
          f"to device, channel and accuracy demand (no model retraining).")
    assert len(seen_plans) >= 3


if __name__ == "__main__":
    main()
