"""End-to-end training driver: a ~15M-parameter SmolLM-family decoder
trained for a few hundred steps on the synthetic low-rank bigram stream,
with checkpointing and eval — the CPU-scale version of the train_4k
dry-run path (same step function, same sharding rules on the host mesh).

  PYTHONPATH=src python examples/train_small_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.data.pipeline import TokenStream, TokenStreamConfig
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_eval_step, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/qpart_lm_ckpt")
    args = ap.parse_args()

    # a 4-layer, d=256 SmolLM-family stack (~8M params): big enough to
    # show real learning on CPU in minutes, same code path as the 135M
    cfg = dataclasses.replace(
        get_config("smollm-135m"), name="smollm-8m", num_layers=4,
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=768,
        vocab_size=2048, tp_pad=1)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params ~{n_params/1e6:.1f}M  "
          f"layers {cfg.num_layers} d_model {cfg.d_model}")

    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    params = T.init_params(jax.random.key(0), cfg)
    opt_state = init_opt_state(params)
    p_specs = shard_lib.param_pspecs(cfg, params, mesh=mesh)
    to_sh = lambda sp: jax.tree.map(lambda s: NamedSharding(mesh, s), sp,
                                    is_leaf=lambda x: isinstance(x, P))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False),
                      donate_argnums=(0, 1))
    eval_fn = jax.jit(make_eval_step(cfg))

    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
        batch_size=args.batch))
    eval_batch = next(TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
        batch_size=args.batch, seed=123)).batches())

    with mesh:
        params = jax.device_put(params, to_sh(p_specs))
        losses, t0 = [], time.time()
        for i, batch in enumerate(stream.batches()):
            if i >= args.steps:
                break
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if i % 25 == 0 or i == args.steps - 1:
                ev = eval_fn(params, eval_batch)
                tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
                print(f"step {i:4d} train {losses[-1]:.4f} "
                      f"eval {float(ev['xent']):.4f} "
                      f"({tok_s:,.0f} tok/s)")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f}")
    assert last < first - 0.2, "model failed to learn"
    save_checkpoint(args.ckpt, params, opt_state, step=args.steps,
                    metadata={"arch": cfg.name})
    # resume check
    p2, o2, meta = load_checkpoint(args.ckpt, params, opt_state)
    print(f"checkpoint saved + restored (step {meta['step']}) at {args.ckpt}")


if __name__ == "__main__":
    main()
