"""Benchmark harness entrypoint — one benchmark per paper table/figure
(deliverable d) plus kernel microbench, planner/serving hot paths and the
roofline table.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig3_payload roofline
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: fast serving
                                                     # subset, refreshes
                                                     # BENCH_serving.json
"""
from __future__ import annotations

import argparse
import csv
import functools
import io
import sys
import time

BENCHES = {}


def _register():
    from benchmarks import (calibration_bench, cost_fidelity_bench,
                            decode_bench, fleet_bench, fleet_scale_bench,
                            kernel_bench, paper_tables, planner_bench,
                            roofline_report)
    BENCHES.update({
        "fig3_payload": paper_tables.payload,
        "fig5_layerwise": paper_tables.layerwise_cost,
        "fig6_size_vs_acc": paper_tables.size_vs_accuracy,
        "fig7_10_baselines": paper_tables.baselines,
        "table4_multimodel": paper_tables.multimodel,
        "kernels": kernel_bench.kernels,
        "planner": planner_bench.planner,
        "serving": calibration_bench.serving,
        "fleet": fleet_bench.fleet,
        "fleet_chaos": fleet_bench.fleet_chaos,
        "fleet_scale": fleet_scale_bench.fleet_scale,
        "decode": decode_bench.decode,
        "cost_fidelity": cost_fidelity_bench.cost_fidelity,
        "roofline": roofline_report.roofline,
    })


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--csv", default=None, help="also write rows to a file")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: reduced-depth serving bench + "
                         "full-size fleet bench")
    ap.add_argument("--profile", action="store_true",
                    help="run the selected benchmarks under cProfile and "
                         "print the top-20 cumulative-time hot spots")
    args = ap.parse_args(argv)
    if args.smoke and args.only:
        ap.error("--smoke selects its own benchmark set; drop --only")
    _register()
    if args.smoke:
        from benchmarks import calibration_bench
        BENCHES["serving"] = functools.partial(calibration_bench.serving,
                                               smoke=True)
        from benchmarks import cost_fidelity_bench, decode_bench
        BENCHES["cost_fidelity"] = functools.partial(
            cost_fidelity_bench.cost_fidelity, smoke=True)
        BENCHES["decode"] = functools.partial(decode_bench.decode,
                                              smoke=True)
        # kernel microbench smoke point: the decode-attention scan-vs-
        # kernel rows (interpret-lane correctness off TPU) ride CI
        from benchmarks import kernel_bench
        BENCHES["kernels"] = functools.partial(kernel_bench.kernels,
                                               smoke=True)
        # the fleet benches are pricing-only and already CI-fast: --smoke
        # runs them at FULL size (>=1k requests, >=3 servers) so the
        # BENCH_serving.json fleet + fleet_chaos (MMPP arrivals, seeded
        # churn, retry/dead-letter accounting, journal-replay check)
        # trajectories are always fresh; the cost-fidelity bench
        # refreshes the predicted-vs-measured trajectory (its MNIST
        # setup is shared/cached)
        from benchmarks import fleet_scale_bench
        BENCHES["fleet_scale"] = functools.partial(
            fleet_scale_bench.fleet_scale, smoke=True)
        # fleet_scale --smoke: one 50k x 16-server point through the
        # engine's scale configuration with an asserted wall budget —
        # the §12 hot-path latency contract runs on every CI build
        names = ["serving", "fleet", "fleet_chaos", "fleet_scale",
                 "decode", "kernels", "cost_fidelity"]
    else:
        names = args.only or list(BENCHES)
    all_rows = []
    profiler = None
    if args.profile:
        import cProfile
        profiler = cProfile.Profile()
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        if profiler is not None:
            profiler.enable()
        rows = BENCHES[name]()
        if profiler is not None:
            profiler.disable()
        all_rows += rows
        keys = list(rows[0].keys()) if rows else []
        out = io.StringIO()
        w = csv.DictWriter(out, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
        print(out.getvalue().rstrip())
        print(f"--- {name}: {len(rows)} rows in {time.time() - t0:.1f}s\n",
              flush=True)
    if args.csv:
        keys = sorted({k for r in all_rows for k in r})
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(all_rows)
    if profiler is not None:
        import pstats
        print("=== profile: top 20 by cumulative time ===")
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(20)
    print(f"TOTAL {len(all_rows)} rows from {len(names)} benchmarks")
    return 0


if __name__ == "__main__":
    sys.exit(main())
