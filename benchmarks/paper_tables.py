"""One benchmark per paper table / figure (deliverable d).

  Fig. 3  payload            — layer-wise parameter size reduction
  Fig. 5  layerwise_cost     — time / energy / server cost vs partition
  Fig. 6  size_vs_accuracy   — optimized model size vs accuracy threshold
  Fig. 7–10 + Table III  baselines — QPART vs AE / pruning / no-opt
  Table IV multimodel        — payload compression + degradation per model
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import (CHANNEL, DEVICE, SERVER, WEIGHTS, cnn_setup,
                               mnist_setup)
from repro.configs.classifier import CIFAR_CNN, MNIST_MLP
from repro.core.cost_model import classifier_layer_specs, cost_breakdown
from repro.core.quantizer import round_bits
from repro.serving.baselines import (AutoencoderBaseline, PruningBaseline,
                                     no_opt_offload)
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest, simulate_plan


# ---------------------------------------------------------------------------
# Fig. 3: layer-wise parameter size reduction at a = 1%.

def payload():
    srv, params, data, acc = mnist_setup()
    m = srv.models["mnist"]
    specs = classifier_layer_specs(MNIST_MLP)
    plan = m.store().plans[(0.01, MNIST_MLP.num_layers)]   # fully on-device
    rows = []
    bits = np.asarray(round_bits(plan.bits_w))
    for i, sp in enumerate(specs):
        before = sp.z_w * 32.0
        after = sp.z_w * float(bits[i])
        rows.append({
            "bench": "fig3_payload", "layer": i + 1,
            "bits": int(bits[i]),
            "before_bits": before, "after_bits": after,
            "reduction_pct": 100.0 * (1 - after / before),
        })
    return rows


# ---------------------------------------------------------------------------
# Fig. 5: layer-wise time / energy / server-cost, QPART vs no-opt.

def layerwise_cost():
    srv, params, data, acc = mnist_setup()
    m = srv.models["mnist"]
    specs = classifier_layer_specs(MNIST_MLP)
    o = np.array([sp.o for sp in specs])
    rows = []
    for p in range(0, MNIST_MLP.num_layers + 1):
        plan = m.store().plans[(0.01, p)]
        q = cost_breakdown(float(o[:p].sum()), float(o[p:].sum()),
                           plan.payload_bits, DEVICE, SERVER, CHANNEL)
        f32_wire = sum(specs[i].z_w for i in range(p)) * 32.0 + \
            (specs[p - 1].z_x if p else 784.0) * 32.0
        n = cost_breakdown(float(o[:p].sum()), float(o[p:].sum()),
                           f32_wire, DEVICE, SERVER, CHANNEL)
        rows.append({
            "bench": "fig5_layerwise", "p": p,
            "qpart_time_s": q.t_total, "noopt_time_s": n.t_total,
            "qpart_energy_j": q.e_total, "noopt_energy_j": n.e_total,
            "qpart_server_cost": q.server_cost,
            "time_saving_pct": 100 * (1 - q.t_total / n.t_total),
        })
    return rows


# ---------------------------------------------------------------------------
# Fig. 6: optimized total parameter size vs accuracy threshold.

def size_vs_accuracy():
    srv, params, data, acc = mnist_setup()
    m = srv.models["mnist"]
    specs = classifier_layer_specs(MNIST_MLP)
    full_bits = sum(sp.z_w for sp in specs) * 32.0
    rows = []
    for a in srv.levels:
        plan = m.store().plans[(a, MNIST_MLP.num_layers)]
        rows.append({
            "bench": "fig6_size_vs_acc", "accuracy_budget": a,
            "payload_bits": plan.payload_bits,
            "full_f32_bits": full_bits,
            "compression_ratio_pct": 100.0 * plan.payload_bits / full_bits,
        })
    return rows


# ---------------------------------------------------------------------------
# Fig. 7–10 + Table III: the four offloading schemes.

def baselines():
    srv, params, data, acc = mnist_setup()
    x_tr, y_tr, x_te, y_te = data
    x_te, y_te = jnp.asarray(x_te), y_te
    m = srv.models["mnist"]
    backend = m.backend
    specs = backend.layer_specs()
    ae = AutoencoderBaseline(code_ratio=0.25)
    rows = []
    for p in range(1, MNIST_MLP.num_layers + 1):
        q_plan = m.store().plans[(0.01, p)]
        q = simulate_plan(q_plan, specs, DEVICE, SERVER, CHANNEL, WEIGHTS)
        q.accuracy = srv.execute_partitioned("mnist", q_plan, x_te, y_te)
        n = no_opt_offload(backend, p, DEVICE, SERVER,
                           CHANNEL, WEIGHTS, x_te, y_te, acc)
        a = ae.offload(backend, p, jnp.asarray(x_tr[:512]),
                       DEVICE, SERVER, CHANNEL, WEIGHTS, x_te, y_te, acc)
        pr = PruningBaseline().calibrated(
            backend, p, jnp.asarray(x_tr[:1024]),
            y_tr[:1024], budget=float(acc - q.accuracy) + 0.01,
            base_accuracy=acc)
        pres = pr.offload(backend, p, DEVICE, SERVER,
                          CHANNEL, WEIGHTS, x_te, y_te, acc)
        for scheme, r in (("qpart", q), ("no_opt", n), ("autoencoder", a),
                          ("pruning", pres)):
            rows.append({
                "bench": "fig7_10_baselines", "p": p, "scheme": scheme,
                "objective": r.objective, "time_s": r.costs.t_total,
                "energy_j": r.costs.e_total,
                "payload_mbits": r.payload_bits / 1e6,
                "accuracy": r.accuracy,
            })
    return rows


# ---------------------------------------------------------------------------
# Table IV: payload compression + degradation across models/datasets.

def multimodel():
    rows = []
    setups = [("mnist-mlp6", "synthetic-MNIST", mnist_setup())]
    for nm, seed in (("synthetic-SVHN", 1), ("synthetic-CIFAR10", 2)):
        setups.append(("cifar-cnn", nm, cnn_setup(nm, seed)))
    for model_name, ds, (srv, params, data, acc) in setups:
        key = list(srv.models)[0]
        m = srv.models[key]
        cfg = m.backend.cfg
        specs = m.backend.layer_specs()
        L = cfg.num_layers
        plan = m.store().plans[(0.005, L)]       # a = 0.5% budget, all layers
        x_te, y_te = jnp.asarray(data[2]), data[3]
        acc_opt = srv.execute_partitioned(key, plan, x_te, y_te)
        full_mb = sum(sp.z_w for sp in specs) * 32.0 / 8e6
        opt_mb = plan.payload_bits / 8e6
        rows.append({
            "bench": "table4_multimodel", "model": model_name, "dataset": ds,
            "initial_mb": round(full_mb, 3), "optimized_mb": round(opt_mb, 3),
            "compression_ratio_pct": round(100 * opt_mb / full_mb, 2),
            "initial_acc": round(acc, 4), "optimized_acc": round(acc_opt, 4),
            "degradation_pct": round(100 * (acc - acc_opt), 3),
        })
    return rows
