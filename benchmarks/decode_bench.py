"""Decode serving benchmark (the BENCH_serving.json "decode" section).

Two parts, one section:

``decode_session`` rows — REAL streamed generation through the
partitioned prefill→decode pipeline (``DecodeSession``) on reduced
variants of two model scales (smollm-135m and qwen1.5-4b flavours),
across >= 3 cut points each with an 8-bit quantized device segment
(float8 KV storage). Reports wall-clock TTFT, decode tokens/s, the
resident device-cache footprint/dtype and the per-token wire bits. The
compile-once contract is ASSERTED: after one warm pass over every cut,
a second full pass may not grow the backend's ``trace_count`` — every
cut point reuses the same jitted decode programs (DESIGN.md §7/§11).

``decode_fleet`` rows — the fleet engine's continuous-batching decode
lane (pricing-only, stub-calibrated): a trace of concurrent decode
streams plus one-shot traffic, reporting tokens/s, TTFT percentiles and
the realized mean round batch, with terminal accounting asserted and
the journal replayed as a determinism check.

  PYTHONPATH=src python -m benchmarks.run --only decode
"""
from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import update_bench_json
from repro.configs.base import get_config
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import DecodeSession
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_transformer_calibration

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

MODELS = ("smollm-135m", "qwen1.5-4b")
SEQ = 16
MAX_LEN = 64
DEVICE_BITS = 8.0               # quantized device segment -> float8 KV


def _plan(p: int, bits: float = DEVICE_BITS) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


def _session_rows(smoke: bool) -> list:
    gen = 8 if smoke else 24
    rows = []
    for name in MODELS:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        if not smoke:
            # deepen past the 2-layer smoke variant so the cut sweep has
            # interior points on both scales
            cfg = dataclasses.replace(cfg, num_layers=4)
        params = T.init_params(jax.random.key(0), cfg)
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        L = cfg.num_layers
        cuts = sorted({0, 1, L // 2, L})
        prompt = np.asarray(jax.random.randint(
            jax.random.key(1), (1, SEQ), 0, cfg.vocab_size))
        for p in cuts:                               # warm pass: compile
            DecodeSession(backend, _plan(p),
                          max_len=MAX_LEN).generate(prompt, 2)
        n_traces = backend.trace_count
        for p in cuts:                               # measured pass
            sess = DecodeSession(backend, _plan(p), max_len=MAX_LEN)
            t0 = time.perf_counter()
            out = sess.generate(prompt, gen)
            wall = time.perf_counter() - t0
            decode_s = wall - out.ttft_s
            rows.append({
                "bench": "decode_session",
                "model": name,
                "layers": L,
                "p": p,
                "bits": int(DEVICE_BITS) if p else 0,
                "ttft_ms": round(out.ttft_s * 1e3, 3),
                "decode_tok_s": round((gen - 1) / decode_s, 1)
                if decode_s > 0 else None,
                "wire_bits_per_tok": sess.wire_bits_per_token(1),
                "device_cache_kib": round(out.device_cache_bytes / 1024, 1),
                "server_cache_kib": round(out.server_cache_bytes / 1024, 1),
                "cache_dtype": out.device_cache_dtype if p else None,
            })
        assert backend.trace_count == n_traces, \
            f"{name}: decode programs re-traced across cut points"
    return rows


def _fleet_rows(smoke: bool) -> list:
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    dev = DeviceProfile(memory_bytes=2e9)
    ch = Channel(capacity_bps=2e6)
    w = ObjectiveWeights()
    srv = QPARTServer()
    stub_transformer_calibration(srv, "lm", cfg, dev, ch, w, seq_len=SEQ,
                                 decode_max_len=MAX_LEN)
    n = 80 if smoke else 300
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1 / 400.0, size=n))
    trace = [InferenceRequest(
        "lm", float(rng.choice((0.02, 0.05))), dev, ch, w,
        arrival_time=float(arrivals[i]), device_id=f"dev-{rng.integers(24)}",
        max_new_tokens=int(rng.choice((0, 8, 16, 32))))
        for i in range(n)]
    engine = FleetEngine(srv)
    t0 = time.perf_counter()
    metrics = engine.run(trace)
    wall = time.perf_counter() - t0
    metrics.assert_terminal()
    metrics.journal.verify_replay(srv, trace)
    s = metrics.summary()
    rounds = [dict(e.data) for e in metrics.journal.entries
              if e.kind == "decode_step" and not dict(e.data)["stale"]]
    total_tokens = sum(r.tokens_emitted for r in metrics.records)
    return [{
        "bench": "decode_fleet",
        "requests": n,
        "streams": sum(1 for r in trace if r.max_new_tokens > 1),
        "tokens": total_tokens,
        "planned_rps_wall": round(n / wall, 1),
        "tokens_per_s": s["tokens_per_s"],
        "ttft_p50_ms": round(s["ttft_p50"] * 1e3, 3),
        "ttft_p99_ms": round(s["ttft_p99"] * 1e3, 3),
        "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
        "decode_rounds": len(rounds),
        "mean_round_batch": round(float(np.mean(
            [r["batch"] for r in rounds])), 2) if rounds else None,
    }]


def _paged_rows(smoke: bool) -> list:
    """Block-granular KV allocation vs the worst-case reservation (PR 9),
    asserted not just reported: (a) the SAME trace priced under paged and
    dense admission produces equivalent fleet summaries when memory is
    ample, (b) the paged engine's realized peak residency (its page
    ledger) is STRICTLY below the dense reservation those streams would
    have pinned, with no page leaked, and (c) at a device-memory budget
    between the two requirements the paged mask admits a deep cut the
    worst-case mask rejects."""
    import repro.serving.pricing as pricing

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    # fast channel + expensive server compute so the argmin lands on a
    # device cut p > 0 (streams hold device KV; p = 0 holds none)
    dev = DeviceProfile(memory_bytes=2e9)
    ch = Channel(capacity_bps=2e10)
    w = ObjectiveWeights(eta=1e5)

    def build(kv_page_tokens):
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ, decode_max_len=MAX_LEN,
                                     kv_page_tokens=kv_page_tokens)
        return srv

    n = 6 if smoke else 16
    gen = 20
    # simultaneous arrivals: every stream's lifetime overlaps, so the
    # dense reservation sum IS the dense peak
    reqs = [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                             device_id=f"d{i}", max_new_tokens=gen)
            for i in range(n)]
    srv_p, srv_d = build(16), build(None)
    eng_p = FleetEngine(srv_p)
    m_p = eng_p.run(reqs)
    m_d = FleetEngine(srv_d).run(reqs)
    for m in (m_p, m_d):
        m.assert_terminal()
    s_p, s_d = m_p.summary(), m_d.summary()
    for key in ("tokens_per_s", "ttft_p50", "p99_latency_s"):
        assert s_p[key] == s_d[key], \
            f"paged admission changed fleet behavior: {key}"

    led = eng_p.kv_ledger
    assert led.open_streams == 0 and led.resident_bytes == 0
    assert led.total_page_allocs == led.total_page_frees > 0, \
        "paged fleet run never exercised the page ledger"
    backend = srv_p.models["lm"].backend
    dense_row = backend.kv_bytes_row(1)
    cuts = [r.deployment.plan.p for r in m_p.records
            if r.deployment is not None and r.deployment.plan.p > 0]
    dense_peak = sum(float(dense_row[p]) for p in cuts)
    assert 0 < led.peak_bytes < dense_peak, \
        "paged residency should be strictly below the dense reservation"

    # (c) admission widening at a budget between the two requirements
    store = srv_p.models["lm"].store(None)
    mem = np.asarray(store.level_memory_rows(store.level_for(0.05)))
    need_d = mem + np.asarray(dense_row)
    need_p = mem + np.asarray(backend.kv_bytes_row(1, tokens=SEQ + 4))
    c = len(dense_row) - 1
    budget = float((need_p[c] + need_d[c]) / 2)
    tight = dataclasses.replace(dev, memory_bytes=budget)
    probe = InferenceRequest("lm", 0.05, tight, ch, w, max_new_tokens=4)
    tab_d = pricing.price_window(srv_d.models, srv_d.server, [probe])
    tab_p = pricing.price_window(srv_p.models, srv_p.server, [probe])
    admitted_d = int(np.isfinite(tab_d.obj[0]).sum())
    admitted_p = int(np.isfinite(tab_p.obj[0]).sum())
    assert np.isinf(tab_d.obj[0][c]) and np.isfinite(tab_p.obj[0][c]), \
        "paged mask should admit the deep cut the worst case rejects"
    return [{
        "bench": "decode_paged_kv",
        "streams": len(cuts),
        "page_tokens": 16,
        "paged_peak_kib": round(led.peak_bytes / 1024, 1),
        "dense_reserved_kib": round(dense_peak / 1024, 1),
        "kv_saving_pct": round(100 * (1 - led.peak_bytes / dense_peak), 1),
        "page_allocs": led.total_page_allocs,
        "page_leaks": led.total_page_allocs - led.total_page_frees,
        "admitted_cuts_dense": admitted_d,
        "admitted_cuts_paged": admitted_p,
    }]


def decode(smoke: bool = False):
    rows = _session_rows(smoke) + _fleet_rows(smoke) + _paged_rows(smoke)
    # one key union across both row shapes (the harness CSV-prints each
    # benchmark with rows[0]'s fieldnames)
    keys = list(dict.fromkeys(k for r in rows for k in r))
    rows = [{k: r.get(k) for k in keys} for r in rows]
    update_bench_json(OUT_PATH, "decode", {
        "smoke": smoke,
        "models": list(MODELS),
        "seq_len": SEQ,
        "max_len": MAX_LEN,
        "device_bits": DEVICE_BITS,
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    for row in decode():
        print(row)
