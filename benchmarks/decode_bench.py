"""Decode serving benchmark (the BENCH_serving.json "decode" section,
plus the PR 10 "decode_chunked" / "decode_speculative" sections).

Four parts:

``decode_session`` rows — REAL streamed generation through the
partitioned prefill→decode pipeline (``DecodeSession``) on reduced
variants of two model scales (smollm-135m and qwen1.5-4b flavours),
across >= 3 cut points each with an 8-bit quantized device segment
(float8 KV storage). Reports wall-clock TTFT, decode tokens/s, the
resident device-cache footprint/dtype and the per-token wire bits. The
compile-once contract is ASSERTED: after one warm pass over every cut,
a second full pass may not grow the backend's ``trace_count`` — every
cut point reuses the same jitted decode programs (DESIGN.md §7/§11).

``decode_fleet`` rows — the fleet engine's continuous-batching decode
lane (pricing-only, stub-calibrated): a trace of concurrent decode
streams plus one-shot traffic, reporting tokens/s, TTFT percentiles and
the realized mean round batch, with terminal accounting asserted and
the journal replayed as a determinism check.

``decode_chunked`` rows — TTFT vs prompt length, chunked-vs-monolithic
prefill, with the compile decoupling asserted: the monolithic lane
re-traces at every fresh prompt length while the chunked lane serves
every length from one chunk-shaped program, so chunked TTFT growth is
strictly sublinear relative to monolithic (DESIGN.md §14).

``decode_speculative`` rows — tokens/s vs draft length k at >= 2 cut
points with the measured acceptance rate; every speculative stream is
asserted bitwise identical to plain greedy at the same cut.

  PYTHONPATH=src python -m benchmarks.run --only decode
"""
from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import update_bench_json
from repro.configs.base import get_config
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import DecodeSession
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_transformer_calibration

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

MODELS = ("smollm-135m", "qwen1.5-4b")
SEQ = 16
MAX_LEN = 64
DEVICE_BITS = 8.0               # quantized device segment -> float8 KV


def _plan(p: int, bits: float = DEVICE_BITS) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


def _session_rows(smoke: bool) -> list:
    gen = 8 if smoke else 24
    rows = []
    for name in MODELS:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        if not smoke:
            # deepen past the 2-layer smoke variant so the cut sweep has
            # interior points on both scales
            cfg = dataclasses.replace(cfg, num_layers=4)
        params = T.init_params(jax.random.key(0), cfg)
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        L = cfg.num_layers
        cuts = sorted({0, 1, L // 2, L})
        prompt = np.asarray(jax.random.randint(
            jax.random.key(1), (1, SEQ), 0, cfg.vocab_size))
        for p in cuts:                               # warm pass: compile
            DecodeSession(backend, _plan(p),
                          max_len=MAX_LEN).generate(prompt, 2)
        n_traces = backend.trace_count
        for p in cuts:                               # measured pass
            sess = DecodeSession(backend, _plan(p), max_len=MAX_LEN)
            t0 = time.perf_counter()
            out = sess.generate(prompt, gen)
            wall = time.perf_counter() - t0
            decode_s = wall - out.ttft_s
            rows.append({
                "bench": "decode_session",
                "model": name,
                "layers": L,
                "p": p,
                "bits": int(DEVICE_BITS) if p else 0,
                "ttft_ms": round(out.ttft_s * 1e3, 3),
                "decode_tok_s": round((gen - 1) / decode_s, 1)
                if decode_s > 0 else None,
                "wire_bits_per_tok": sess.wire_bits_per_token(1),
                "device_cache_kib": round(out.device_cache_bytes / 1024, 1),
                "server_cache_kib": round(out.server_cache_bytes / 1024, 1),
                "cache_dtype": out.device_cache_dtype if p else None,
            })
        assert backend.trace_count == n_traces, \
            f"{name}: decode programs re-traced across cut points"
    return rows


def _fleet_rows(smoke: bool) -> list:
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    dev = DeviceProfile(memory_bytes=2e9)
    ch = Channel(capacity_bps=2e6)
    w = ObjectiveWeights()
    srv = QPARTServer()
    stub_transformer_calibration(srv, "lm", cfg, dev, ch, w, seq_len=SEQ,
                                 decode_max_len=MAX_LEN)
    n = 80 if smoke else 300
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1 / 400.0, size=n))
    trace = [InferenceRequest(
        "lm", float(rng.choice((0.02, 0.05))), dev, ch, w,
        arrival_time=float(arrivals[i]), device_id=f"dev-{rng.integers(24)}",
        max_new_tokens=int(rng.choice((0, 8, 16, 32))))
        for i in range(n)]
    engine = FleetEngine(srv)
    t0 = time.perf_counter()
    metrics = engine.run(trace)
    wall = time.perf_counter() - t0
    metrics.assert_terminal()
    metrics.journal.verify_replay(srv, trace)
    s = metrics.summary()
    rounds = [dict(e.data) for e in metrics.journal.entries
              if e.kind == "decode_step" and not dict(e.data)["stale"]]
    total_tokens = sum(r.tokens_emitted for r in metrics.records)
    return [{
        "bench": "decode_fleet",
        "requests": n,
        "streams": sum(1 for r in trace if r.max_new_tokens > 1),
        "tokens": total_tokens,
        "planned_rps_wall": round(n / wall, 1),
        "tokens_per_s": s["tokens_per_s"],
        "ttft_p50_ms": round(s["ttft_p50"] * 1e3, 3),
        "ttft_p99_ms": round(s["ttft_p99"] * 1e3, 3),
        "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
        "decode_rounds": len(rounds),
        "mean_round_batch": round(float(np.mean(
            [r["batch"] for r in rounds])), 2) if rounds else None,
    }]


def _paged_rows(smoke: bool) -> list:
    """Block-granular KV allocation vs the worst-case reservation (PR 9),
    asserted not just reported: (a) the SAME trace priced under paged and
    dense admission produces equivalent fleet summaries when memory is
    ample, (b) the paged engine's realized peak residency (its page
    ledger) is STRICTLY below the dense reservation those streams would
    have pinned, with no page leaked, and (c) at a device-memory budget
    between the two requirements the paged mask admits a deep cut the
    worst-case mask rejects."""
    import repro.serving.pricing as pricing

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    # fast channel + expensive server compute so the argmin lands on a
    # device cut p > 0 (streams hold device KV; p = 0 holds none)
    dev = DeviceProfile(memory_bytes=2e9)
    ch = Channel(capacity_bps=2e10)
    w = ObjectiveWeights(eta=1e5)

    def build(kv_page_tokens):
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ, decode_max_len=MAX_LEN,
                                     kv_page_tokens=kv_page_tokens)
        return srv

    n = 6 if smoke else 16
    gen = 20
    # simultaneous arrivals: every stream's lifetime overlaps, so the
    # dense reservation sum IS the dense peak
    reqs = [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                             device_id=f"d{i}", max_new_tokens=gen)
            for i in range(n)]
    srv_p, srv_d = build(16), build(None)
    eng_p = FleetEngine(srv_p)
    m_p = eng_p.run(reqs)
    m_d = FleetEngine(srv_d).run(reqs)
    for m in (m_p, m_d):
        m.assert_terminal()
    s_p, s_d = m_p.summary(), m_d.summary()
    for key in ("tokens_per_s", "ttft_p50", "p99_latency_s"):
        assert s_p[key] == s_d[key], \
            f"paged admission changed fleet behavior: {key}"

    led = eng_p.kv_ledger
    assert led.open_streams == 0 and led.resident_bytes == 0
    assert led.total_page_allocs == led.total_page_frees > 0, \
        "paged fleet run never exercised the page ledger"
    backend = srv_p.models["lm"].backend
    dense_row = backend.kv_bytes_row(1)
    cuts = [r.deployment.plan.p for r in m_p.records
            if r.deployment is not None and r.deployment.plan.p > 0]
    dense_peak = sum(float(dense_row[p]) for p in cuts)
    assert 0 < led.peak_bytes < dense_peak, \
        "paged residency should be strictly below the dense reservation"

    # (c) admission widening at a budget between the two requirements
    store = srv_p.models["lm"].store(None)
    mem = np.asarray(store.level_memory_rows(store.level_for(0.05)))
    need_d = mem + np.asarray(dense_row)
    need_p = mem + np.asarray(backend.kv_bytes_row(1, tokens=SEQ + 4))
    c = len(dense_row) - 1
    budget = float((need_p[c] + need_d[c]) / 2)
    tight = dataclasses.replace(dev, memory_bytes=budget)
    probe = InferenceRequest("lm", 0.05, tight, ch, w, max_new_tokens=4)
    tab_d = pricing.price_window(srv_d.models, srv_d.server, [probe])
    tab_p = pricing.price_window(srv_p.models, srv_p.server, [probe])
    admitted_d = int(np.isfinite(tab_d.obj[0]).sum())
    admitted_p = int(np.isfinite(tab_p.obj[0]).sum())
    assert np.isinf(tab_d.obj[0][c]) and np.isfinite(tab_p.obj[0][c]), \
        "paged mask should admit the deep cut the worst case rejects"
    return [{
        "bench": "decode_paged_kv",
        "streams": len(cuts),
        "page_tokens": 16,
        "paged_peak_kib": round(led.peak_bytes / 1024, 1),
        "dense_reserved_kib": round(dense_peak / 1024, 1),
        "kv_saving_pct": round(100 * (1 - led.peak_bytes / dense_peak), 1),
        "page_allocs": led.total_page_allocs,
        "page_leaks": led.total_page_allocs - led.total_page_frees,
        "admitted_cuts_dense": admitted_d,
        "admitted_cuts_paged": admitted_p,
    }]


def _chunked_rows(smoke: bool) -> list:
    """TTFT vs prompt length, chunked vs monolithic prefill (PR 10).

    The decoupling claim is about COMPILATION, not FLOPs: monolithic
    prefill admits the whole prompt as one cache extension whose jitted
    program is shape-keyed on the prompt length, so every fresh length
    pays an XLA retrace inside TTFT.  Chunked prefill walks the prompt
    in fixed-size chunks — one chunk-shaped program serves every prompt
    length.  Asserted, not just reported: (a) after one warm chunked
    pass at the SHORTEST length, longer prompts add zero traces while
    the monolithic lane re-traces at every new length, (b) chunked TTFT
    beats monolithic TTFT at every unseen length and its end-to-end TTFT
    growth across the sweep is strictly below the monolithic growth
    (sublinear relative to monolithic), (c) the emitted tokens are
    bitwise identical — chunked admission is the same computation."""
    chunk = 8
    lens = (8, 16, 24) if smoke else (8, 16, 24, 32, 40)
    gen = 4
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    params = T.init_params(jax.random.key(0), cfg)
    L = cfg.num_layers
    p = max(1, L // 2)
    seq, max_len = max(lens), max(lens) + 16
    b_mono = TransformerBackend(cfg, params, seq_len=seq,
                                decode_max_len=max_len)
    b_chnk = TransformerBackend(cfg, params, seq_len=seq,
                                decode_max_len=max_len)
    prompts = {s: np.asarray(jax.random.randint(
        jax.random.key(2), (1, s), 0, cfg.vocab_size)) for s in lens}
    # warm BOTH lanes at the shortest prompt so the sweep isolates the
    # per-length cost: the chunked lane's one chunk-shaped program now
    # serves every length, while the monolithic lane still owes a fresh
    # prompt-length-shaped trace at each longer prompt
    DecodeSession(b_chnk, _plan(p), max_len=max_len,
                  prefill_chunk_tokens=chunk).generate(prompts[lens[0]], gen)
    DecodeSession(b_mono, _plan(p), max_len=max_len).generate(
        prompts[lens[0]], gen)
    warm_traces = b_chnk.trace_count
    rows = []
    for i, s in enumerate(lens):
        tm0 = b_mono.trace_count
        out_m = DecodeSession(b_mono, _plan(p), max_len=max_len).generate(
            prompts[s], gen)
        mono_traced = b_mono.trace_count - tm0
        sess_c = DecodeSession(b_chnk, _plan(p), max_len=max_len,
                               prefill_chunk_tokens=chunk)
        out_c = sess_c.generate(prompts[s], gen)
        assert b_chnk.trace_count == warm_traces, \
            f"chunked prefill re-traced at prompt length {s}"
        np.testing.assert_array_equal(out_c.tokens, out_m.tokens)
        if i > 0:
            assert mono_traced > 0, \
                f"monolithic prefill unexpectedly cached length {s}"
            assert out_c.ttft_s < out_m.ttft_s, \
                f"chunked TTFT should beat a fresh monolithic trace at {s}"
        rows.append({
            "bench": "decode_chunked",
            "model": "smollm-135m",
            "p": p,
            "prompt_len": s,
            "chunks": out_c.prefill_chunks,
            "ttft_mono_ms": round(out_m.ttft_s * 1e3, 3),
            "ttft_chunked_ms": round(out_c.ttft_s * 1e3, 3),
            "mono_traces_added": mono_traced,
            "chunked_traces_added": 0,
        })
    growth_c = rows[-1]["ttft_chunked_ms"] - rows[0]["ttft_chunked_ms"]
    growth_m = rows[-1]["ttft_mono_ms"] - rows[0]["ttft_mono_ms"]
    assert growth_c < growth_m, \
        f"chunked TTFT growth {growth_c}ms not sublinear vs " \
        f"monolithic {growth_m}ms"
    return rows


def _spec_rows(smoke: bool) -> list:
    """Tokens/s vs draft length k at >= 2 cut points, with the measured
    draft acceptance rate (PR 10).  Every k is verified bit-identical to
    the k=0 greedy stream at the same cut — speculation changes the
    round structure (rounds < new_tokens - 1), never the tokens — and
    the measured pass may not grow ``trace_count`` past the warm pass."""
    gen = 10 if smoke else 20
    ks = (0, 1, 2, 3)
    names = MODELS[:1] if smoke else MODELS
    rows = []
    for name in names:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        params = T.init_params(jax.random.key(0), cfg)
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        L = cfg.num_layers
        cuts = sorted({max(1, L // 2), L})
        prompt = np.asarray(jax.random.randint(
            jax.random.key(1), (1, SEQ), 0, cfg.vocab_size))
        for p in cuts:                               # warm pass: compile
            for k in ks:
                DecodeSession(backend, _plan(p), max_len=MAX_LEN,
                              draft_tokens=k).generate(prompt, gen)
        n_traces = backend.trace_count
        for p in cuts:                               # measured pass
            base = None
            for k in ks:
                sess = DecodeSession(backend, _plan(p), max_len=MAX_LEN,
                                     draft_tokens=k)
                t0 = time.perf_counter()
                out = sess.generate(prompt, gen)
                wall = time.perf_counter() - t0
                if k == 0:
                    base = out.tokens
                else:
                    np.testing.assert_array_equal(out.tokens, base)
                rate = out.accept_rate
                rows.append({
                    "bench": "decode_speculative",
                    "model": name,
                    "p": p,
                    "k": k,
                    "rounds": out.rounds,
                    "accept_rate": round(rate, 3)
                    if rate is not None else None,
                    "tokens_per_s": round(gen / wall, 1) if wall > 0
                    else None,
                })
        assert backend.trace_count == n_traces, \
            f"{name}: speculative programs re-traced in the measured pass"
    return rows


def decode(smoke: bool = False):
    rows = _session_rows(smoke) + _fleet_rows(smoke) + _paged_rows(smoke)
    chunked = _chunked_rows(smoke)
    spec = _spec_rows(smoke)
    update_bench_json(OUT_PATH, "decode_chunked", {
        "smoke": smoke,
        "model": "smollm-135m",
        "chunk_tokens": 8,
        "rows": chunked,
    })
    update_bench_json(OUT_PATH, "decode_speculative", {
        "smoke": smoke,
        "models": list(MODELS[:1] if smoke else MODELS),
        "seq_len": SEQ,
        "device_bits": DEVICE_BITS,
        "rows": spec,
    })
    rows = rows + chunked + spec
    # one key union across the row shapes (the harness CSV-prints each
    # benchmark with rows[0]'s fieldnames)
    keys = list(dict.fromkeys(k for r in rows for k in r))
    rows = [{k: r.get(k) for k in keys} for r in rows]
    update_bench_json(OUT_PATH, "decode", {
        "smoke": smoke,
        "models": list(MODELS),
        "seq_len": SEQ,
        "max_len": MAX_LEN,
        "device_bits": DEVICE_BITS,
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    for row in decode():
        print(row)
