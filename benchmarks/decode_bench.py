"""Decode serving benchmark (the BENCH_serving.json "decode" section).

Two parts, one section:

``decode_session`` rows — REAL streamed generation through the
partitioned prefill→decode pipeline (``DecodeSession``) on reduced
variants of two model scales (smollm-135m and qwen1.5-4b flavours),
across >= 3 cut points each with an 8-bit quantized device segment
(float8 KV storage). Reports wall-clock TTFT, decode tokens/s, the
resident device-cache footprint/dtype and the per-token wire bits. The
compile-once contract is ASSERTED: after one warm pass over every cut,
a second full pass may not grow the backend's ``trace_count`` — every
cut point reuses the same jitted decode programs (DESIGN.md §7/§11).

``decode_fleet`` rows — the fleet engine's continuous-batching decode
lane (pricing-only, stub-calibrated): a trace of concurrent decode
streams plus one-shot traffic, reporting tokens/s, TTFT percentiles and
the realized mean round batch, with terminal accounting asserted and
the journal replayed as a determinism check.

  PYTHONPATH=src python -m benchmarks.run --only decode
"""
from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import update_bench_json
from repro.configs.base import get_config
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import DecodeSession
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_transformer_calibration

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

MODELS = ("smollm-135m", "qwen1.5-4b")
SEQ = 16
MAX_LEN = 64
DEVICE_BITS = 8.0               # quantized device segment -> float8 KV


def _plan(p: int, bits: float = DEVICE_BITS) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


def _session_rows(smoke: bool) -> list:
    gen = 8 if smoke else 24
    rows = []
    for name in MODELS:
        cfg = dataclasses.replace(get_config(name).reduced(),
                                  dtype="float32")
        if not smoke:
            # deepen past the 2-layer smoke variant so the cut sweep has
            # interior points on both scales
            cfg = dataclasses.replace(cfg, num_layers=4)
        params = T.init_params(jax.random.key(0), cfg)
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        L = cfg.num_layers
        cuts = sorted({0, 1, L // 2, L})
        prompt = np.asarray(jax.random.randint(
            jax.random.key(1), (1, SEQ), 0, cfg.vocab_size))
        for p in cuts:                               # warm pass: compile
            DecodeSession(backend, _plan(p),
                          max_len=MAX_LEN).generate(prompt, 2)
        n_traces = backend.trace_count
        for p in cuts:                               # measured pass
            sess = DecodeSession(backend, _plan(p), max_len=MAX_LEN)
            t0 = time.perf_counter()
            out = sess.generate(prompt, gen)
            wall = time.perf_counter() - t0
            decode_s = wall - out.ttft_s
            rows.append({
                "bench": "decode_session",
                "model": name,
                "layers": L,
                "p": p,
                "bits": int(DEVICE_BITS) if p else 0,
                "ttft_ms": round(out.ttft_s * 1e3, 3),
                "decode_tok_s": round((gen - 1) / decode_s, 1)
                if decode_s > 0 else None,
                "wire_bits_per_tok": sess.wire_bits_per_token(1),
                "device_cache_kib": round(out.device_cache_bytes / 1024, 1),
                "server_cache_kib": round(out.server_cache_bytes / 1024, 1),
                "cache_dtype": out.device_cache_dtype if p else None,
            })
        assert backend.trace_count == n_traces, \
            f"{name}: decode programs re-traced across cut points"
    return rows


def _fleet_rows(smoke: bool) -> list:
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    dev = DeviceProfile(memory_bytes=2e9)
    ch = Channel(capacity_bps=2e6)
    w = ObjectiveWeights()
    srv = QPARTServer()
    stub_transformer_calibration(srv, "lm", cfg, dev, ch, w, seq_len=SEQ,
                                 decode_max_len=MAX_LEN)
    n = 80 if smoke else 300
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1 / 400.0, size=n))
    trace = [InferenceRequest(
        "lm", float(rng.choice((0.02, 0.05))), dev, ch, w,
        arrival_time=float(arrivals[i]), device_id=f"dev-{rng.integers(24)}",
        max_new_tokens=int(rng.choice((0, 8, 16, 32))))
        for i in range(n)]
    engine = FleetEngine(srv)
    t0 = time.perf_counter()
    metrics = engine.run(trace)
    wall = time.perf_counter() - t0
    metrics.assert_terminal()
    metrics.journal.verify_replay(srv, trace)
    s = metrics.summary()
    rounds = [dict(e.data) for e in metrics.journal.entries
              if e.kind == "decode_step" and not dict(e.data)["stale"]]
    total_tokens = sum(r.tokens_emitted for r in metrics.records)
    return [{
        "bench": "decode_fleet",
        "requests": n,
        "streams": sum(1 for r in trace if r.max_new_tokens > 1),
        "tokens": total_tokens,
        "planned_rps_wall": round(n / wall, 1),
        "tokens_per_s": s["tokens_per_s"],
        "ttft_p50_ms": round(s["ttft_p50"] * 1e3, 3),
        "ttft_p99_ms": round(s["ttft_p99"] * 1e3, 3),
        "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
        "decode_rounds": len(rounds),
        "mean_round_batch": round(float(np.mean(
            [r["batch"] for r in rounds])), 2) if rounds else None,
    }]


def decode(smoke: bool = False):
    rows = _session_rows(smoke) + _fleet_rows(smoke)
    # one key union across both row shapes (the harness CSV-prints each
    # benchmark with rows[0]'s fieldnames)
    keys = list(dict.fromkeys(k for r in rows for k in r))
    rows = [{k: r.get(k) for k in keys} for r in rows]
    update_bench_json(OUT_PATH, "decode", {
        "smoke": smoke,
        "models": list(MODELS),
        "seq_len": SEQ,
        "max_len": MAX_LEN,
        "device_bits": DEVICE_BITS,
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    for row in decode():
        print(row)
