"""Serving benchmark (BENCH_serving.json trajectory): compile-once
partitioned execution vs the pre-PR-3 per-start-jit design.

What is measured, per depth L (transformer, reduced-width blocks):

  * ``calibrate``  — Alg. 1 noise calibration wall-clock. Legacy: the
    scalar probe loop over a backend whose ``forward_from_layer`` jits
    one UNROLLED block loop per resume point (O(L) compilations of up to
    L traced blocks — O(L^2) traced block applications). Compile-once:
    ``QPARTServer.calibrate``'s vectorized probe (one chunked ``lax.map``
    program over the masked segment forward).
  * ``execute``    — partitioned execution swept over every partition
    point p = 1..L: quantize the device segment, run it, run the server
    tail. Legacy pays an eager per-block python loop plus one fresh XLA
    compilation per distinct p; compile-once runs every split through
    the same programs with (start, stop) as dynamic operands.
  * ``traces``     — XLA trace counts from the backends' shared trace
    counter: O(L) legacy, O(1) compile-once.

Equivalence is asserted inline (s_w/s_x/rho within float tolerance) —
a benchmark of a wrong answer is meaningless. Acceptance (ISSUE 3):
calibrate + execute >= 5x at L = 24.

  PYTHONPATH=src python -m benchmarks.run --only serving
  PYTHONPATH=src python -m benchmarks.run --smoke          # CI subset

Writes ``BENCH_serving.json`` at the repo root (committed — the serving
perf trajectory starts at PR 3).
"""
from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import noise as noise_lib
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.quantizer import fake_quant
from repro.models import rope as rope_lib
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest

SEQ = 16
BATCH = 8
LEVEL = 0.01
OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"


# ---------------------------------------------------------------------------
# The pre-PR-3 execution paths, kept HERE (not in src) as the regression
# baseline: per-start jit family + eager block loops.

class LegacyTransformerBackend(TransformerBackend):
    """``TransformerBackend`` as it was before the masked segment
    forward: ``forward``/``forward_from_layer`` jit one unrolled python
    block loop per start, ``layer_activations`` and the device segment
    run eager block-by-block. Probes go through the scalar reference
    loop (``core.noise.backend_layer_energies``)."""

    def _run_blocks(self, params, h, start: int, stop: int):
        b, s, _ = h.shape
        positions = rope_lib.text_positions(b, s)
        for l in range(start, stop):
            bp, pos = T.block_at(params, self.cfg, l)
            h, _, _ = T.apply_block(bp, self.cfg, pos, h, positions)
        return h

    def _logits_fn(self, start: int):
        def make():
            def f(params, a):
                if start < 0:
                    a = T.embed_tokens(params, self.cfg, a)
                h = self._run_blocks(params, a, max(start, 0),
                                     self.num_layers)
                return T.unembed(params, self.cfg, h)[:, -1, :]
            return f
        return self.jitted(("legacy", start), make)

    def forward(self, x, params=None):
        return self._logits_fn(-1)(self.params if params is None else params,
                                   x)

    def forward_from_layer(self, a, start: int, params=None):
        return self._logits_fn(start)(
            self.params if params is None else params, a)

    def layer_activations(self, x, params=None):
        params = self.params if params is None else params
        h = T.embed_tokens(params, self.cfg, x)
        b, s, _ = h.shape
        positions = rope_lib.text_positions(b, s)
        acts = []
        for l in range(self.num_layers):
            acts.append(h)
            bp, pos = T.block_at(params, self.cfg, l)
            h, _, _ = T.apply_block(bp, self.cfg, pos, h, positions)
        return acts, T.unembed(params, self.cfg, h)[:, -1, :]

    def calibrate_probes(self, x, probe_bits=noise_lib.PROBE_BITS, **_):
        return noise_lib.backend_layer_energies(self, x, probe_bits)

    def run_device_segment(self, seg, plan, x):
        h = T.embed_tokens(self.params, self.cfg, x)
        b, s, _ = h.shape
        positions = rope_lib.text_positions(b, s)
        for l in range(plan.p):
            pos = l % T.period_len(self.cfg)
            h, _, _ = T.apply_block(seg.params[l], self.cfg, pos, h,
                                    positions)
        return fake_quant(h, int(seg.bits_x))


# ---------------------------------------------------------------------------

def _bench_cfg(L: int):
    # keep in sync with tests/test_calibration.py::lm_config — the bench
    # must measure the model the regression tests lock
    return dataclasses.replace(
        get_config("smollm-135m").reduced(), name=f"smollm-bench-L{L}",
        num_layers=L, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=32, tp_pad=1, dtype="float32")


def _cycle_batch(rng, cfg, n):
    start = rng.integers(0, cfg.vocab_size, size=(n, 1))
    toks = (start + np.arange(SEQ + 1)[None, :]) % cfg.vocab_size
    return (jnp.asarray(toks[:, :SEQ], jnp.int32),
            jnp.asarray(toks[:, SEQ], jnp.int32))


def _run_impl(kind: str, cfg, params, x_cal, y_cal, x_te):
    """One full serving lifetime: calibrate -> build_store -> serve ->
    execute every partition point. Returns timings + trace counts."""
    cls = LegacyTransformerBackend if kind == "legacy" else TransformerBackend
    backend = cls(cfg, params, seq_len=SEQ)
    srv = QPARTServer()
    srv.register("lm", backend, x_cal, y_cal)

    t0 = time.perf_counter()
    srv.calibrate("lm", vectorized=(kind != "legacy"))
    t_cal = time.perf_counter() - t0
    traces_cal = backend.trace_count

    dev, ch, w = (DeviceProfile(), Channel(capacity_bps=2e6),
                  ObjectiveWeights())
    t0 = time.perf_counter()
    srv.build_store("lm", dev, ch, w)
    dep = srv.serve(InferenceRequest("lm", LEVEL, dev, ch, w,
                                     segment_cached=True))
    t_serve = time.perf_counter() - t0

    m = srv.models["lm"]
    plans = [m.store().plans[(LEVEL, p)] for p in range(1, cfg.num_layers + 1)]
    t0 = time.perf_counter()
    for plan in plans:
        logits = backend.execute_plan(plan, x_te)
    jax.block_until_ready(logits)
    t_exec = time.perf_counter() - t0

    return {"t_cal": t_cal, "t_serve": t_serve, "t_exec": t_exec,
            "traces_cal": traces_cal,
            "traces_total": backend.trace_count,
            "s_w": m.s_w, "s_x": m.s_x, "rho": m.rho, "dep": dep}


def serving(smoke: bool = False):
    depths = (2, 4) if smoke else (4, 12, 24)
    rng = np.random.default_rng(0)
    rows = []
    for L in depths:
        cfg = _bench_cfg(L)
        params = T.init_params(jax.random.key(0), cfg)
        x_cal, y_cal = _cycle_batch(rng, cfg, BATCH)
        x_te, _ = _cycle_batch(rng, cfg, BATCH)
        res = {k: _run_impl(k, cfg, params, x_cal, y_cal, x_te)
               for k in ("legacy", "compile_once")}
        lg, co = res["legacy"], res["compile_once"]
        # equivalence guard: same calibration within float tolerance
        for key in ("s_w", "s_x", "rho"):
            np.testing.assert_allclose(co[key], lg[key], rtol=5e-2,
                                       err_msg=f"{key} diverged at L={L}")
        t_lg = lg["t_cal"] + lg["t_exec"]
        t_co = co["t_cal"] + co["t_exec"]
        rows.append({
            "bench": "serving_calibrate_execute",
            "config": f"L{L}xB{BATCH}xS{SEQ}",
            "depth": L,
            "legacy_cal_s": round(lg["t_cal"], 3),
            "compile_once_cal_s": round(co["t_cal"], 3),
            "legacy_exec_s": round(lg["t_exec"], 3),
            "compile_once_exec_s": round(co["t_exec"], 3),
            "serve_s": round(co["t_serve"], 4),
            "legacy_traces": lg["traces_total"],
            "compile_once_traces": co["traces_total"],
            "speedup": round(t_lg / t_co, 1),
        })
    if not smoke:
        last = rows[-1]
        assert last["depth"] >= 24 and last["speedup"] >= 5.0, \
            f"acceptance: >=5x at L=24, got {last['speedup']}x"
        # compile count O(1) in depth: identical trace counts across L
        counts = {r["compile_once_traces"] for r in rows}
        assert len(counts) == 1, f"compile-once traces grew with depth: {rows}"
    from benchmarks.common import update_bench_json
    update_bench_json(OUT_PATH, "serving", {"smoke": smoke, "rows": rows})
    return rows


if __name__ == "__main__":
    for row in serving():
        print(row)
