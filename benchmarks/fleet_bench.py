"""Fleet serving benchmark (the BENCH_serving.json "fleet" trajectory).

A ≥1k-request Poisson-arrival trace over a ≥3-server fleet with
heterogeneous devices (0.2–2 GHz), heterogeneous channels (1–10 Mbps),
mixed accuracy budgets, per-request deadlines, and a population of
repeat requesters (device_ids) whose segment caches the engine manages.
Every admission policy prices the same trace, so the rows compare what
the POLICY buys: deadline-miss rate, p50/p99 end-to-end latency, queue
delay, server utilization — plus the engine's own planning throughput
(requests planned per second of wall clock, the serving-control hot
path).

The QPART server is stub-calibrated (synthetic noise constants, real
Alg. 1 store): the fleet engine exercises the pricing/queueing path
only, so no model training or execution is needed and the bench stays
CI-fast (it runs in --smoke at full size).

  PYTHONPATH=src python -m benchmarks.run --only fleet
"""
from __future__ import annotations

import pathlib
import time

import numpy as np

from benchmarks.common import update_bench_json
from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.testing import poisson_trace, stub_classifier_server

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

N_REQUESTS = 1200
N_SERVERS = 3
ARRIVAL_RATE = 700.0            # requests/s — ~0.85 fleet utilization
# (mixed batch sizes mean ~2x MACs per request on average)
EPOCH_S = 0.005                 # 5 ms decision epochs: ~12-request windows
DEADLINES_S = (0.020, 0.035, 0.060)   # mixed SLOs — EDF ordering matters
BATCHES = (1, 1, 4)             # mixed batch sizes — server demands
# differ at zero load, so balanced (SJF) ordering differs from fcfs
POLICIES = ("fcfs", "balanced", "edf", "least_loaded")

# slow fleet + fast devices (incl. a 200 Mbps channel tier): under
# congestion the Eq. 17 queue term pushes plans device-side, segments
# really ship, and the engine's caches get hits
DEVICES = [DeviceProfile(f_clock=f) for f in (4e8, 1e9, 2e9)]
CHANNELS = [Channel(capacity_bps=c) for c in (2e6, 1e7, 2e8)]
WEIGHTS = ObjectiveWeights()
FLEET = [ServerProfile(f_clock=3e8)] * N_SERVERS


def _stub_server() -> QPARTServer:
    return stub_classifier_server([("mnist", MNIST_MLP)], server=FLEET[0],
                                  device=DEVICES[0], channel=CHANNELS[1],
                                  weights=WEIGHTS)


def _trace(n: int = N_REQUESTS, rate: float = ARRIVAL_RATE, seed: int = 0):
    # ~200 repeat requesters: the engine's segment caches amortize model
    # shipments across a device's later requests
    return poisson_trace("mnist", n, rate, DEVICES, CHANNELS, WEIGHTS,
                         budgets=(0.004, 0.01, 0.02), deadlines=DEADLINES_S,
                         batches=BATCHES, device_pool=200, seed=seed)


def fleet():
    srv = _stub_server()
    trace = _trace()
    rows = []
    for policy in POLICIES:
        engine = FleetEngine(srv, servers=FLEET, policy=policy,
                             slo="degrade", epoch_interval=EPOCH_S)
        t0 = time.perf_counter()
        metrics = engine.run(trace)
        wall = time.perf_counter() - t0
        s = metrics.summary()
        assert s["completed"] + s["rejected"] == len(trace)
        done = metrics.completed()
        cache_hits = sum(1 for r in done if r.deployment.plan.p > 0
                         and r.deployment.payload_bits
                         == r.deployment.plan.payload_x_bits)
        rows.append({
            "bench": "fleet_poisson",
            "policy": policy,
            "requests": s["requests"],
            "servers": N_SERVERS,
            "planned_rps_wall": round(len(trace) / wall, 1),
            "p50_latency_ms": round(s["p50_latency_s"] * 1e3, 3),
            "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
            "deadline_miss_rate": s["deadline_miss_rate"],
            "mean_queue_delay_ms": round(s["mean_queue_delay_s"] * 1e3, 3),
            "mean_queue_depth": s["mean_queue_depth"],
            "rejected": s["rejected"],
            "degraded": s["degraded"],
            "cache_hits": cache_hits,
            "utilization": round(float(np.mean(s["server_utilization"])), 4),
            "total_payload_Mbit": round(s["total_payload_bits"] / 1e6, 1),
        })
    assert rows[0]["requests"] >= 1000 and N_SERVERS >= 3
    update_bench_json(OUT_PATH, "fleet", {
        "requests": len(trace),
        "servers": N_SERVERS,
        "arrival_rate_rps": ARRIVAL_RATE,
        "epoch_ms": EPOCH_S * 1e3,
        "deadlines_ms": [d * 1e3 for d in DEADLINES_S],
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    for row in fleet():
        print(row)
