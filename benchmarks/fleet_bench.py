"""Fleet serving benchmark (the BENCH_serving.json "fleet" +
"fleet_chaos" trajectories).

``fleet``: a ≥1k-request Poisson-arrival trace over a ≥3-server fleet
with heterogeneous devices (0.2–2 GHz), heterogeneous channels (1–10
Mbps), mixed accuracy budgets, per-request deadlines, and a population
of repeat requesters (device_ids) whose segment caches the engine
manages. Every admission policy prices the same trace, so the rows
compare what the POLICY buys: deadline-miss rate, p50/p99 end-to-end
latency, queue delay, server utilization — plus the engine's own
planning throughput (requests planned per second of wall clock, the
serving-control hot path).

``fleet_chaos``: the same fleet under operational chaos (DESIGN.md
§10): bursty MMPP arrivals, seeded device churn (disconnect/reconnect
renewal processes over the requester population) and channel-quality
drift, with retry-with-degraded-budget recovery. Rows report goodput,
retry rate, dead-letter rate and p99 against the fault-free baseline on
the identical trace; every run is asserted terminally accounted for and
the fcfs run is replayed from its journal as a determinism check.

The QPART server is stub-calibrated (synthetic noise constants, real
Alg. 1 store): the fleet engine exercises the pricing/queueing path
only, so no model training or execution is needed and the bench stays
CI-fast (both sections run in --smoke at full size).

  PYTHONPATH=src python -m benchmarks.run --only fleet fleet_chaos
"""
from __future__ import annotations

import pathlib
import time

import numpy as np

from benchmarks.common import update_bench_json
from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import (DISCONNECT, RECONNECT, FaultEvent,
                                  FaultInjector, FleetEngine, RetryPolicy,
                                  churn_trace, degrade_trace, materialize,
                                  mmpp_arrivals)
from repro.serving.qpart_server import QPARTServer
from repro.serving.testing import poisson_trace, stub_classifier_server

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

N_REQUESTS = 1200
N_SERVERS = 3
ARRIVAL_RATE = 700.0            # requests/s — ~0.85 fleet utilization
# (mixed batch sizes mean ~2x MACs per request on average)
EPOCH_S = 0.005                 # 5 ms decision epochs: ~12-request windows
DEADLINES_S = (0.020, 0.035, 0.060)   # mixed SLOs — EDF ordering matters
BATCHES = (1, 1, 4)             # mixed batch sizes — server demands
# differ at zero load, so balanced (SJF) ordering differs from fcfs
POLICIES = ("fcfs", "balanced", "edf", "least_loaded")

# slow fleet + fast devices (incl. a 200 Mbps channel tier): under
# congestion the Eq. 17 queue term pushes plans device-side, segments
# really ship, and the engine's caches get hits
DEVICES = [DeviceProfile(f_clock=f) for f in (4e8, 1e9, 2e9)]
CHANNELS = [Channel(capacity_bps=c) for c in (2e6, 1e7, 2e8)]
WEIGHTS = ObjectiveWeights()
FLEET = [ServerProfile(f_clock=3e8)] * N_SERVERS


def _stub_server() -> QPARTServer:
    return stub_classifier_server([("mnist", MNIST_MLP)], server=FLEET[0],
                                  device=DEVICES[0], channel=CHANNELS[1],
                                  weights=WEIGHTS)


def _trace(n: int = N_REQUESTS, rate: float = ARRIVAL_RATE, seed: int = 0):
    # ~200 repeat requesters: the engine's segment caches amortize model
    # shipments across a device's later requests
    return poisson_trace("mnist", n, rate, DEVICES, CHANNELS, WEIGHTS,
                         budgets=(0.004, 0.01, 0.02), deadlines=DEADLINES_S,
                         batches=BATCHES, device_pool=200, seed=seed)


def fleet():
    srv = _stub_server()
    trace = _trace()
    rows = []
    for policy in POLICIES:
        engine = FleetEngine(srv, servers=FLEET, policy=policy,
                             slo="degrade", epoch_interval=EPOCH_S)
        t0 = time.perf_counter()
        metrics = engine.run(trace)
        wall = time.perf_counter() - t0
        s = metrics.summary()
        assert s["completed"] + s["rejected"] == len(trace)
        done = metrics.completed()
        cache_hits = sum(1 for r in done if r.deployment.plan.p > 0
                         and r.deployment.payload_bits
                         == r.deployment.plan.payload_x_bits)
        rows.append({
            "bench": "fleet_poisson",
            "policy": policy,
            "requests": s["requests"],
            "servers": N_SERVERS,
            "planned_rps_wall": round(len(trace) / wall, 1),
            "p50_latency_ms": round(s["p50_latency_s"] * 1e3, 3),
            "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
            "deadline_miss_rate": s["deadline_miss_rate"],
            "mean_queue_delay_ms": round(s["mean_queue_delay_s"] * 1e3, 3),
            "mean_queue_depth": s["mean_queue_depth"],
            "rejected": s["rejected"],
            "degraded": s["degraded"],
            "cache_hits": cache_hits,
            "utilization": round(float(np.mean(s["server_utilization"])), 4),
            "total_payload_Mbit": round(s["total_payload_bits"] / 1e6, 1),
        })
    assert rows[0]["requests"] >= 1000 and N_SERVERS >= 3
    update_bench_json(OUT_PATH, "fleet", {
        "requests": len(trace),
        "servers": N_SERVERS,
        "arrival_rate_rps": ARRIVAL_RATE,
        "epoch_ms": EPOCH_S * 1e3,
        "deadlines_ms": [d * 1e3 for d in DEADLINES_S],
        "rows": rows,
    })
    return rows


def _chaos_trace(n: int = N_REQUESTS, seed: int = 0):
    """Bursty MMPP arrivals (calm 200 rps / burst 1400 rps) decorated
    with the same device/channel/budget/deadline mix as the Poisson
    trace."""
    arrivals = mmpp_arrivals(n, rates=(200.0, 1400.0),
                             mean_dwell=(0.5, 0.1), seed=seed)
    return materialize("mnist", arrivals, DEVICES, CHANNELS, WEIGHTS,
                       budgets=(0.004, 0.01, 0.02), deadlines=DEADLINES_S,
                       batches=BATCHES, device_pool=200, seed=seed)


def _chaos_faults(horizon: float, device_pool: int = 200, seed: int = 0):
    """Seeded churn + channel drift + permanent loss over the requester
    population: a quarter of the devices flap (up ~0.35 s / down
    ~0.12 s), a quarter sees capacity-degradation episodes (× 0.1–0.5),
    and a handful die mid-trace and never reconnect — their surviving
    requests drain to the dead-letter queue as disconnect_abandoned."""
    flappy = [f"dev-{i}" for i in range(0, device_pool, 4)]
    drifty = [f"dev-{i}" for i in range(1, device_pool, 4)]
    doomed = [f"dev-{i}" for i in range(2, device_pool, 16)]
    rng = np.random.default_rng(seed + 2)
    deaths = FaultInjector([
        FaultEvent(float(rng.uniform(0.3 * horizon, 0.9 * horizon)),
                   DISCONNECT, d) for d in doomed])
    return (churn_trace(flappy, horizon, mean_uptime=0.35,
                        mean_downtime=0.12, seed=seed)
            + degrade_trace(drifty, horizon, mean_interval=1.0,
                            mean_duration=0.15, seed=seed + 1)
            + deaths)


def _targeted_cuts(baseline, n_cuts: int = 150, downtime: float = 0.03,
                   seed: int = 0) -> FaultInjector:
    """Disconnect/reconnect pairs aimed mid-window at the baseline run's
    longest in-flight radio transfers (the chaos-engineering staple:
    random micro-outages almost never intersect millisecond transfers,
    targeted ones guarantee the cancel -> retry path is exercised)."""
    done = [r for r in baseline.completed()
            if r.request.device_id is not None
            and r.timeline.transfer_done > r.timeline.admit]
    done.sort(key=lambda r: r.timeline.transfer_done - r.timeline.admit,
              reverse=True)
    rng = np.random.default_rng(seed)
    events = []
    for r in done[:n_cuts]:
        t0, t1 = r.timeline.admit, r.timeline.transfer_done
        cut = float(t0 + rng.uniform(0.25, 0.75) * (t1 - t0))
        events.append(FaultEvent(cut, DISCONNECT, r.request.device_id))
        events.append(FaultEvent(cut + downtime, RECONNECT,
                                 r.request.device_id))
    return FaultInjector(events)


def fleet_chaos():
    srv = _stub_server()
    trace = _chaos_trace()
    horizon = trace[-1].arrival_time + 0.5
    ambient = _chaos_faults(horizon)
    retry = RetryPolicy(max_attempts=3, base_backoff_s=0.01,
                        max_backoff_s=0.1, degrade_on_retry=True)
    rows = []
    for policy in POLICIES:
        base = FleetEngine(srv, servers=FLEET, policy=policy,
                           slo="degrade", epoch_interval=EPOCH_S)
        baseline = base.run(trace)
        s0 = baseline.summary()
        # ambient churn/drift plus cuts aimed at THIS policy's own
        # baseline schedule — each policy gets an equally hostile trace
        faults = ambient + _targeted_cuts(baseline)
        engine = FleetEngine(srv, servers=FLEET, policy=policy,
                             slo="degrade", epoch_interval=EPOCH_S,
                             retry=retry, faults=faults)
        t0 = time.perf_counter()
        metrics = engine.run(trace)
        wall = time.perf_counter() - t0
        metrics.assert_terminal()       # no lost requests, ever
        s = metrics.summary()
        if policy == "fcfs":            # determinism: replay the journal
            metrics.journal.verify_replay(srv, trace, servers=FLEET)
        rows.append({
            "bench": "fleet_chaos",
            "policy": policy,
            "requests": s["requests"],
            "fault_events": len(faults),
            "planned_rps_wall": round(len(trace) / wall, 1),
            "goodput_rps": s["goodput_rps"],
            "baseline_goodput_rps": s0["goodput_rps"],
            "retry_rate": round(metrics.retry_rate(), 4),
            "disrupted": s["disrupted"],
            "dead_letter_rate": round(s["dead_lettered"] / s["requests"], 4),
            "p99_latency_ms": round(s["p99_latency_s"] * 1e3, 3),
            "baseline_p99_ms": round(s0["p99_latency_s"] * 1e3, 3),
            "deadline_miss_rate": s["deadline_miss_rate"],
            "degraded": s["degraded"],
            "drop_reasons": s["drop_reasons"],
        })
    assert rows[0]["requests"] >= 1000
    update_bench_json(OUT_PATH, "fleet_chaos", {
        "requests": len(trace),
        "servers": N_SERVERS,
        "arrivals": "mmpp(200/1400 rps, dwell 0.5/0.1 s)",
        "ambient_fault_events": len(ambient),
        "targeted_cuts": 150,
        "retry": {"max_attempts": retry.max_attempts,
                  "base_backoff_s": retry.base_backoff_s,
                  "degrade_on_retry": retry.degrade_on_retry},
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    for row in fleet():
        print(row)
    for row in fleet_chaos():
        print(row)
