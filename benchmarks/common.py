"""Shared benchmark substrate: trained classifiers + calibrated QPART
servers, built once and cached across benchmark modules."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.classifier import CIFAR_CNN, MNIST_MLP, ClassifierConfig
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile, classifier_layer_specs)
from repro.data.pipeline import minibatches, synthetic_images, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.backends import ClassifierBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest

DEVICE = DeviceProfile()
SERVER = ServerProfile()
CHANNEL = Channel()
WEIGHTS = ObjectiveWeights()


def train_classifier(cfg: ClassifierConfig, data, steps: int = 400,
                     lr: float = 0.05, seed: int = 0):
    x_tr, y_tr, x_te, y_te = data
    params = init_classifier(jax.random.key(seed), cfg)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, cfg, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    it = minibatches(x_tr, y_tr, 128, seed=seed)
    for _ in range(steps):
        bx, by = next(it)
        params = step(params, bx, by)
    acc = float(jnp.mean(jnp.argmax(
        classifier_forward(params, cfg, jnp.asarray(x_te)), -1) == y_te))
    return params, acc


@functools.lru_cache(maxsize=None)
def mnist_setup():
    x_tr, y_tr, x_all, y_all = synthetic_mnist(n_train=8192, n_test=4096)
    # calibration uses HELD-OUT samples of the SAME distribution: on
    # training data the overfit margins saturate and Delta(a) degenerates
    data = (x_tr, y_tr, x_all[:2048], y_all[:2048])
    params, acc = train_classifier(MNIST_MLP, data)
    srv = QPARTServer()
    srv.register("mnist", ClassifierBackend(MNIST_MLP, params),
                 x_all[2048:3072], y_all[2048:3072])
    srv.calibrate("mnist")
    srv.build_store("mnist", DEVICE, CHANNEL, WEIGHTS)
    return srv, params, data, acc


@functools.lru_cache(maxsize=None)
def cnn_setup(name: str = "cifar", seed: int = 0):
    x_tr, y_tr, x_all, y_all = synthetic_images(
        CIFAR_CNN.input_shape, n_train=4096, n_test=2048, seed=seed,
        noise=0.65)
    data = (x_tr, y_tr, x_all[:1024], y_all[:1024])
    params, acc = train_classifier(CIFAR_CNN, data, steps=300, lr=0.01,
                                   seed=seed)
    srv = QPARTServer()
    srv.register(name, ClassifierBackend(CIFAR_CNN, params),
                 x_all[1024:1536], y_all[1024:1536])
    srv.calibrate(name)
    srv.build_store(name, DEVICE, CHANNEL, WEIGHTS)
    return srv, params, data, acc


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)                       # compile / warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, jax.Array) else None
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6                  # us


def update_bench_json(path, section: str, payload) -> None:
    """Merge-write one section of the shared BENCH_serving.json artifact
    so the serving bench (``serving`` section) and the fleet bench
    (``fleet`` section) can refresh independently without clobbering each
    other's trajectory."""
    import json
    import pathlib
    path = pathlib.Path(path)
    doc = {}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            doc = {}
    # migrate a v1 file (top-level serving rows) so no stale keys survive
    if "rows" in doc:
        doc["serving"] = {"smoke": doc.pop("smoke", None),
                          "rows": doc.pop("rows")}
    doc["schema"] = "qpart-serving-bench/v2"
    doc["backend"] = jax.default_backend()
    doc[section] = payload
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {path} [{section}]")
