"""Kernel microbenchmarks: the quantize / dequant-fused-matmul Pallas
kernels vs their jnp oracles, plus the payload arithmetic the paper's Eq.14
predicts. CPU wall-times are for the oracle path (interpret-mode Pallas is
a correctness harness, not a perf path); the derived column reports the
HBM-byte saving the kernel realizes on the TPU target."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core.quantizer import quantize
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas


def _decode_attn_rows(smoke: bool) -> list:
    """Scan-path softmax vs the flash decode kernel across context
    lengths (PR 9). Off-TPU the kernel column is the interpret-mode
    Pallas body — a correctness lane, so its wall time is reported but
    the speed story is the TPU one; the allclose check against the scan
    oracle runs either way."""
    rows = []
    b, kvp, gp, hd = 1, 4, 4, 64
    bufs = (128, 512) if smoke else (128, 512, 2048)
    on_tpu = jax.default_backend() == "tpu"
    for buf in bufs:
        kq, kk, kv = jax.random.split(jax.random.key(buf), 3)
        q = jax.random.normal(kq, (b, kvp, gp, hd), jnp.float32)
        ck = jax.random.normal(kk, (b, buf, kvp, hd), jnp.float32)
        cv = jax.random.normal(kv, (b, buf, kvp, hd), jnp.float32)
        pos = jnp.int32(buf - 1)                 # fully-written ring

        scan = jax.jit(ref.decode_attention_ref)
        o_scan, t_scan = timed(scan, q, ck, cv, pos)
        if on_tpu:
            kern = jax.jit(decode_attention_pallas)
            o_kern, t_kern = timed(kern, q, ck, cv, pos)
        else:
            kern = jax.jit(
                lambda *a: decode_attention_pallas(*a, interpret=True))
            o_kern = kern(q, ck, cv, pos)
            t_kern = None
        assert jnp.allclose(o_kern, o_scan, atol=2e-6), \
            f"decode kernel diverged from scan oracle at buf={buf}"
        rows.append({
            "bench": "kernel_decode_attn",
            "shape": f"b{b}xkv{kvp}xg{gp}x{hd}",
            "context": buf,
            "us_scan": round(t_scan, 1),
            "us_kernel": round(t_kern, 1) if t_kern is not None else None,
            "kernel_lane": "tpu" if on_tpu else "interpret",
            "kv_kib": round(2 * buf * kvp * hd * 4 / 1024, 1),
        })
    return rows


def kernels(smoke: bool = False):
    rows = []
    for m, k, n in [(256, 1024, 1024), (512, 2048, 2048)]:
        x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
        c8, s8, m8 = quantize(w, 8)
        c8 = c8.astype(jnp.uint8)
        c4, s4, m4 = quantize(w, 4)
        packed = ops.pack_int4(c4)

        f32 = jax.jit(lambda a, b: a @ b)
        _, t_f32 = timed(f32, x, w)
        q8 = jax.jit(lambda a, c: ref.qmatmul_ref(a, c, s8, m8, jnp.float32))
        _, t_q8 = timed(q8, x, c8)
        q4 = jax.jit(lambda a, p: ref.qmatmul4_ref(a, p, s4, m4, jnp.float32))
        _, t_q4 = timed(q4, x, packed)

        bytes_f32 = k * n * 4
        rows += [
            {"bench": "kernel_qmatmul", "shape": f"{m}x{k}x{n}",
             "variant": "f32", "us_per_call": round(t_f32, 1),
             "weight_bytes": bytes_f32, "hbm_saving_pct": 0.0},
            {"bench": "kernel_qmatmul", "shape": f"{m}x{k}x{n}",
             "variant": "w8", "us_per_call": round(t_q8, 1),
             "weight_bytes": k * n, "hbm_saving_pct": 75.0},
            {"bench": "kernel_qmatmul", "shape": f"{m}x{k}x{n}",
             "variant": "w4", "us_per_call": round(t_q4, 1),
             "weight_bytes": k * n // 2, "hbm_saving_pct": 87.5},
        ]
    rows += _decode_attn_rows(smoke)
    # one key union across both row shapes for the harness CSV printer
    keys = list(dict.fromkeys(k for r in rows for k in r))
    return [{k: r.get(k) for k in keys} for r in rows]
