"""Kernel microbenchmarks: the quantize / dequant-fused-matmul Pallas
kernels vs their jnp oracles, plus the payload arithmetic the paper's Eq.14
predicts. CPU wall-times are for the oracle path (interpret-mode Pallas is
a correctness harness, not a perf path); the derived column reports the
HBM-byte saving the kernel realizes on the TPU target."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core.quantizer import quantize
from repro.kernels import ops, ref


def kernels():
    rows = []
    for m, k, n in [(256, 1024, 1024), (512, 2048, 2048)]:
        x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
        c8, s8, m8 = quantize(w, 8)
        c8 = c8.astype(jnp.uint8)
        c4, s4, m4 = quantize(w, 4)
        packed = ops.pack_int4(c4)

        f32 = jax.jit(lambda a, b: a @ b)
        _, t_f32 = timed(f32, x, w)
        q8 = jax.jit(lambda a, c: ref.qmatmul_ref(a, c, s8, m8, jnp.float32))
        _, t_q8 = timed(q8, x, c8)
        q4 = jax.jit(lambda a, p: ref.qmatmul4_ref(a, p, s4, m4, jnp.float32))
        _, t_q4 = timed(q4, x, packed)

        bytes_f32 = k * n * 4
        rows += [
            {"bench": "kernel_qmatmul", "shape": f"{m}x{k}x{n}",
             "variant": "f32", "us_per_call": round(t_f32, 1),
             "weight_bytes": bytes_f32, "hbm_saving_pct": 0.0},
            {"bench": "kernel_qmatmul", "shape": f"{m}x{k}x{n}",
             "variant": "w8", "us_per_call": round(t_q8, 1),
             "weight_bytes": k * n, "hbm_saving_pct": 75.0},
            {"bench": "kernel_qmatmul", "shape": f"{m}x{k}x{n}",
             "variant": "w4", "us_per_call": round(t_q4, 1),
             "weight_bytes": k * n // 2, "hbm_saving_pct": 87.5},
        ]
    return rows
