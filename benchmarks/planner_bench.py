"""Planner hot-path benchmark (BENCH trajectory): offline-store build time
and request-window pricing throughput, vectorized vs the scalar reference.

Targets (ISSUE 1 acceptance): >= 10x for ``build_offline_store`` on an
L >= 32 layer config, >= 5x for pricing a 64-request window with
``serve_batch`` vs the per-request ``serve`` loop — while staying
bit-exact against the scalar path (asserted here, not just in tests).

  PYTHONPATH=src python -m benchmarks.run --only planner
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.solver import build_offline_store
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest

LEVELS = (0.001, 0.0025, 0.005, 0.01, 0.02)


def _best_of(fn, repeats: int = 15):
    """Best-of-N: robust against scheduler noise on shared machines."""
    fn()                                  # warm caches / lazy imports
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def _synthetic_layers(L: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return dict(
        layer_z_w=rng.uniform(1e3, 1e6, L),
        layer_z_x=rng.uniform(1e2, 1e4, L),
        layer_s_w=rng.uniform(1e-2, 1e2, L),
        layer_s_x=rng.uniform(1e-2, 1e2, L),
        layer_rho=rng.uniform(1e-3, 1e1, L),
        layer_o=rng.uniform(1e5, 1e7, L),
    )


def _store_rows():
    rows = []
    for L in (32, 64, 128):
        kw = dict(levels=LEVELS, budgets={a: a * 10 for a in LEVELS},
                  xi=1e-8, delta_cost=1e-9, eps=1e-8, input_z=784.0,
                  **_synthetic_layers(L))
        ref_store, t_ref = _best_of(
            lambda: build_offline_store(vectorized=False, **kw))
        vec_store, t_vec = _best_of(
            lambda: build_offline_store(vectorized=True, **kw))
        # equivalence guard: a benchmark of a wrong answer is meaningless
        for key in ref_store.plans:
            np.testing.assert_allclose(vec_store.plans[key].bits_w,
                                       ref_store.plans[key].bits_w,
                                       rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(vec_store.plans[key].objective,
                                       ref_store.plans[key].objective,
                                       rtol=1e-9)
        rows.append({"bench": "planner_store_build",
                     "config": f"L{L}x{len(LEVELS)}levels",
                     "scalar_ms": round(t_ref * 1e3, 3),
                     "vectorized_ms": round(t_vec * 1e3, 3),
                     "speedup": round(t_ref / t_vec, 1)})
    return rows


def _serve_rows():
    srv = QPARTServer(levels=LEVELS)
    from repro.serving.backends import ClassifierBackend
    x = np.zeros((4, 28, 28), np.float32)
    y = np.zeros(4, np.int32)
    srv.register("bench", ClassifierBackend(MNIST_MLP, None), x, y)
    # fabricate a calibration (pricing only exercises the store + cost
    # model; no accuracy is measured here)
    m = srv.models["bench"]
    L = MNIST_MLP.num_layers
    rng = np.random.default_rng(0)
    m.s_w = rng.uniform(0.5, 2.0, L)
    m.s_x = rng.uniform(0.1, 1.0, L)
    m.rho = rng.uniform(0.01, 0.5, L)
    m.delta_table = {a: a * 50 for a in LEVELS}
    dev, ch, w = DeviceProfile(), Channel(capacity_bps=2e6), ObjectiveWeights()
    srv.build_store("bench", dev, ch, w)

    strong = dataclasses.replace(dev, f_clock=2e9)
    fast = dataclasses.replace(ch, capacity_bps=100e6)
    budgets = (0.001, 0.004, 0.011, 0.05)
    rows = []
    for n in (64, 256):
        reqs = [InferenceRequest("bench", budgets[i % 4],
                                 strong if i % 3 == 0 else dev,
                                 fast if i % 2 else ch, w,
                                 batch=1 + (i % 2) * 3,
                                 segment_cached=bool(i % 5))
                for i in range(n)]
        loop_res, t_loop = _best_of(lambda: [srv.serve(r) for r in reqs])
        batch_res, t_batch = _best_of(lambda: srv.serve_batch(reqs))
        for a, b in zip(loop_res, batch_res):
            assert a.plan is b.plan
            np.testing.assert_allclose(a.objective, b.objective, rtol=1e-9)
        rows.append({"bench": "planner_serve_window",
                     "config": f"window{n}",
                     "scalar_ms": round(t_loop * 1e3, 3),
                     "vectorized_ms": round(t_batch * 1e3, 3),
                     "speedup": round(t_loop / t_batch, 1)})
    return rows


def planner():
    return _store_rows() + _serve_rows()


if __name__ == "__main__":
    for row in planner():
        print(row)
