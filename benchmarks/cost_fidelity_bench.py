"""Cost-fidelity benchmark — the CostModel v2 headline number: how far
each pricing provider's predicted serving time is from the WALL-CLOCK
time the deployments actually take (``Deployment.execute``'s
block_until_ready-fenced stage measurements).

Protocol:
  1. Train + calibrate the MNIST server (shared ``benchmarks.common``
     setup), serve a CALIBRATION window spanning budgets × batch sizes,
     execute every deployment twice (the first run pays XLA compiles)
     and feed the second run's measured stage timings into the server's
     ``CalibrationLedger``.
  2. Fit → ``CalibratedCost`` (per-device/per-server least-squares term
     rates).
  3. Serve a HELD-OUT evaluation window (different budgets/batches),
     execute, and score every provider by mean relative error of its
     predicted compute time (device + server stage; the radio is not
     measured) against the measured wall clock. ``CalibratedCost`` must
     beat ``AnalyticCost`` strictly — asserted, not just reported.
  4. A pricing-only PARTITION-FLIP scenario: a compute-rich but
     memory-starved device (high f_clock, tiny mem_bw). The analytic
     objective, blind to memory traffic, keeps the segment on-device;
     the roofline objective prices the weight stream and flips the
     choice toward the server. Both choices land in the bench record.

  PYTHONPATH=src python -m benchmarks.run --only cost_fidelity
"""
from __future__ import annotations

import dataclasses
import pathlib

import jax.numpy as jnp
import numpy as np

from benchmarks.common import mnist_setup, update_bench_json
from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (AnalyticCost, Channel, DeviceProfile,
                                   ObjectiveWeights, RooflineCost,
                                   plan_cost_terms)
from repro.serving.pricing import price_window
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_classifier_server

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

CALIB_BUDGETS = (0.001, 0.005, 0.02)
CALIB_BATCHES = (64, 256)
EVAL_BUDGETS = (0.0025, 0.01)
EVAL_BATCHES = (128, 512)


def _deployments(srv, dev, ch, w, budgets, batches, x, y):
    """serve → warm → execute(measure) one deployment per
    (budget, batch); returns the executed deployments."""
    deps = []
    for budget in budgets:
        for batch in batches:
            req = InferenceRequest("mnist", budget, dev, ch, w, batch=batch)
            dep = srv.serve(req)
            tx, ty = jnp.asarray(x[:batch]), y[:batch]
            dep.execute(tx, ty)          # warm: XLA compiles + caches
            dep.execute(tx, ty)          # measured run
            deps.append(dep)
    return deps


def _provider_error(provider, server, deps):
    """Mean relative error of predicted vs measured compute seconds
    (device + server stage) over executed deployments."""
    errs = []
    for dep in deps:
        meas = dep.result.extra["measured"]
        specs = dep.backend.layer_specs(batch=meas["batch"])
        o1, o2, dev_b, srv_b = plan_cost_terms(dep.plan, specs)
        pred = float(provider.device_seconds(dep.request.device, o1, dev_b)
                     + provider.server_seconds(server, o2, srv_b))
        measured = meas["t_device_s"] + meas["t_server_s"]
        errs.append(abs(pred - measured) / max(measured, 1e-12))
    return float(np.mean(errs))


def _partition_flip():
    """Memory-bound regime: analytic vs roofline pick different p."""
    # compute-rich, memory-starved edge device (4 GHz but a 50 MB/s
    # weight stream), cached segment, latency-only objective: analytic
    # sees near-free device compute and keeps every layer on-device;
    # roofline prices the quantized weight stream and offloads
    dev = DeviceProfile(f_clock=4e9, mem_bw=5e7)
    ch = Channel(capacity_bps=2e7)
    w = ObjectiveWeights(tau=0.0)
    srv = stub_classifier_server([("mnist", MNIST_MLP)], device=dev,
                                 channel=ch, weights=w)
    req = InferenceRequest("mnist", 0.01, dev, ch, w, segment_cached=True)
    choices = {}
    for provider in (AnalyticCost(), RooflineCost()):
        tab = price_window(srv.models, srv.server, [req], provider=provider)
        choices[provider.name] = int(tab.argmin_choices()[0])
    return choices


def cost_fidelity(smoke: bool = False):
    srv, _params, data, _acc = mnist_setup()
    _x_tr, _y_tr, x_te, y_te = data
    dev, ch, w = DeviceProfile(), Channel(), ObjectiveWeights()

    calib = _deployments(srv, dev, ch, w, CALIB_BUDGETS, CALIB_BATCHES,
                         x_te, y_te)
    for dep in calib:
        srv.record_execution(dep)
    calibrated = srv.calibrated_provider()

    evald = _deployments(srv, dev, ch, w, EVAL_BUDGETS, EVAL_BATCHES,
                         x_te, y_te)
    providers = (AnalyticCost(), RooflineCost(), calibrated)
    rows = []
    for provider in providers:
        err = _provider_error(provider, srv.server, evald)
        rows.append({"bench": "cost_fidelity", "provider": provider.name,
                     "eval_runs": len(evald),
                     "ledger_samples": len(srv.ledger),
                     "mean_rel_err": round(err, 4),
                     "p_analytic": None, "p_roofline": None})
    err_by = {r["provider"]: r["mean_rel_err"] for r in rows}
    # the acceptance bar: calibration must demonstrably close the loop
    assert err_by["calibrated"] < err_by["analytic"], err_by

    flip = _partition_flip()
    assert flip["roofline"] != flip["analytic"], flip
    rows.append({"bench": "partition_flip", "provider": "analytic_vs_roofline",
                 "eval_runs": 1, "ledger_samples": 0, "mean_rel_err": None,
                 "p_analytic": flip["analytic"],
                 "p_roofline": flip["roofline"]})

    update_bench_json(OUT_PATH, "cost_fidelity", {
        "calib_runs": len(calib),
        "eval_runs": len(evald),
        "mean_rel_err": err_by,
        "partition_flip": flip,
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    for row in cost_fidelity():
        print(row)
