"""Roofline report: aggregate the dry-run JSON records (§Roofline) into the
per-(arch x shape x mesh) three-term table."""
from __future__ import annotations

import os

from repro.roofline.analysis import load_records

RECORD_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def roofline():
    rows = []
    for r in load_records(RECORD_DIR):
        rows.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"],
            "t_compute_ms": round(r["t_compute"] * 1e3, 3),
            "t_memory_ms": round(r["t_memory"] * 1e3, 3),
            "t_collective_ms": round(r["t_collective"] * 1e3, 3),
            "bottleneck": r["bottleneck"],
            "useful_flop_frac": (round(r["useful_flop_frac"], 4)
                                 if r["useful_flop_frac"] else None),
            "hlo_gflops": round(r["hlo_gflops"], 1),
            "coll_gbytes": round(r["coll_gbytes"], 3),
        })
    return rows
