"""Fleet-engine scaling sweep (the BENCH_serving.json "fleet_scale"
trajectory; DESIGN.md §12).

Sweeps trace size x fleet size — N ∈ {10k, 100k, 1M} requests over
{3, 16, 64} servers — through the engine's scale configuration
(``journal="off"``, ``records="light"``, vectorized admission, cached
re-price ladders) and records, per grid point, the simulated-serving
throughput (requests planned per second of bench wall clock), the wall
time itself, and the process peak RSS. The 1M x 64 point is asserted to
complete: that is the scale contract the §12 rework buys.

Arrival rate scales with the fleet (~233 rps per server — the same ~0.85
utilization the ``fleet`` bench runs at 3 servers), so every grid point
exercises a loaded fleet rather than an idle one, and the per-point sim
horizon stays roughly constant down a column. Traces are generated
vectorized (one RNG draw per attribute column, not per request) so trace
construction doesn't drown the engine measurement at 10⁶.

  PYTHONPATH=src python -m benchmarks.run --only fleet_scale
  PYTHONPATH=src python benchmarks/fleet_scale_bench.py --smoke
"""
from __future__ import annotations

import pathlib
import resource
import time

import numpy as np

from benchmarks.common import update_bench_json
from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_classifier_server

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"

GRID_N = (10_000, 100_000, 1_000_000)
GRID_SERVERS = (3, 16, 64)
RATE_PER_SERVER = 700.0 / 3     # the fleet bench's ~0.85-utilization point
EPOCH_S = 0.005
DEADLINES_S = (0.020, 0.035, 0.060)
BATCHES = (1, 1, 4)
BUDGETS = (0.004, 0.01, 0.02)

# same hardware mix as fleet_bench: slow fleet, fast devices, a 200 Mbps
# channel tier — congestion pushes plans device-side and caches get hits
DEVICES = [DeviceProfile(f_clock=f) for f in (4e8, 1e9, 2e9)]
CHANNELS = [Channel(capacity_bps=c) for c in (2e6, 1e7, 2e8)]
WEIGHTS = ObjectiveWeights()
SERVER = ServerProfile(f_clock=3e8)

# CI latency contract for the --smoke point (50k x 16). The full 1M
# points size themselves by measurement, but the smoke tier asserts an
# absolute wall budget so a hot-path regression fails the build instead
# of silently doubling CI time. Generous vs the ~10-15s measured here.
SMOKE_N = 50_000
SMOKE_SERVERS = 16
SMOKE_WALL_BUDGET_S = 120.0


def _stub_server() -> QPARTServer:
    return stub_classifier_server([("mnist", MNIST_MLP)], server=SERVER,
                                  device=DEVICES[0], channel=CHANNELS[1],
                                  weights=WEIGHTS)


def scale_trace(n: int, rate: float, seed: int = 0,
                device_pool: int = 2000) -> list:
    """Poisson trace with every attribute drawn as one vectorized column
    (same request distribution family as ``fleet_bench._trace``)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    bud = rng.integers(len(BUDGETS), size=n)
    dev = rng.integers(len(DEVICES), size=n)
    ch = rng.integers(len(CHANNELS), size=n)
    bat = rng.integers(len(BATCHES), size=n)
    dl = rng.integers(len(DEADLINES_S), size=n)
    ids = rng.integers(device_pool, size=n)
    id_strs = [f"dev-{k}" for k in range(device_pool)]
    arrivals_l = arrivals.tolist()
    return [InferenceRequest(
        "mnist", BUDGETS[bud[i]], DEVICES[dev[i]], CHANNELS[ch[i]], WEIGHTS,
        batch=BATCHES[bat[i]], arrival_time=arrivals_l[i],
        deadline=DEADLINES_S[dl[i]], device_id=id_strs[ids[i]])
        for i in range(n)]


def _peak_rss_mb() -> float:
    # ru_maxrss is KiB on Linux; process-lifetime peak (monotone), so the
    # sweep runs small -> large and each reading reflects its own point
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _run_point(srv: QPARTServer, n: int, n_servers: int,
               seed: int = 0) -> dict:
    fleet = [ServerProfile(f_clock=SERVER.f_clock)] * n_servers
    rate = RATE_PER_SERVER * n_servers
    t0 = time.perf_counter()
    trace = scale_trace(n, rate, seed=seed,
                        device_pool=max(200, min(20_000, n // 50)))
    t_trace = time.perf_counter() - t0
    engine = FleetEngine(srv, servers=fleet, policy="fcfs", slo="degrade",
                         epoch_interval=EPOCH_S, journal="off",
                         records="light")
    t0 = time.perf_counter()
    metrics = engine.run(trace)
    wall = time.perf_counter() - t0
    s = metrics.summary()
    assert s["completed"] + s["rejected"] == n
    return {
        "bench": "fleet_scale",
        "requests": n,
        "servers": n_servers,
        "arrival_rate_rps": round(rate, 1),
        "wall_s": round(wall, 2),
        "trace_gen_s": round(t_trace, 2),
        "planned_rps_wall": round(n / wall, 1),
        "sim_horizon_s": round(s["horizon_s"], 2),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
        "completed": s["completed"],
        "rejected": s["rejected"],
        "deadline_miss_rate": s["deadline_miss_rate"],
        "utilization": round(float(np.mean(s["server_utilization"])), 4),
    }


def fleet_scale(smoke: bool = False):
    srv = _stub_server()
    rows = []
    if smoke:
        row = _run_point(srv, SMOKE_N, SMOKE_SERVERS)
        row["tier"] = "smoke"
        assert row["wall_s"] < SMOKE_WALL_BUDGET_S, (
            f"smoke point {SMOKE_N}x{SMOKE_SERVERS} took {row['wall_s']}s "
            f"(budget {SMOKE_WALL_BUDGET_S}s) — engine hot path regressed")
        rows.append(row)
    else:
        # small -> large so each point's peak-RSS reading is its own
        for n in GRID_N:
            for n_servers in GRID_SERVERS:
                rows.append(_run_point(srv, n, n_servers))
                print(f"  {n}x{n_servers}: {rows[-1]['wall_s']}s, "
                      f"{rows[-1]['planned_rps_wall']} req/s wall",
                      flush=True)
        # the §12 scale contract: the 10⁶-request, >=50-server point ran
        assert any(r["requests"] >= 1_000_000 and r["servers"] >= 50
                   for r in rows)
        assert len(rows) >= 9
    update_bench_json(OUT_PATH, "fleet_scale", {
        "tier": "smoke" if smoke else "full",
        "grid_requests": list(GRID_N),
        "grid_servers": list(GRID_SERVERS),
        "rate_per_server_rps": round(RATE_PER_SERVER, 1),
        "engine": {"journal": "off", "records": "light",
                   "admission": "vectorized", "policy": "fcfs",
                   "slo": "degrade", "epoch_ms": EPOCH_S * 1e3},
        "rows": rows,
    })
    return rows


if __name__ == "__main__":
    import sys
    for row in fleet_scale(smoke="--smoke" in sys.argv):
        print(row)
