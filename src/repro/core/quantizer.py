"""Uniform asymmetric quantizer (paper Eq. 9–10).

Given a tensor c and bit-width b the quantization set is the uniform grid
``Q = [mu : (phi-mu)/(2^b - 1) : phi]`` and ``Q(c) = argmin_{q in Q} |c-q|``
— i.e. round-to-nearest onto the grid. We expose:

  * ``quantize`` / ``dequantize``  — integer codes + (scale, zero) metadata,
  * ``fake_quant``                 — quantize-dequantize in one pass (what
                                      the accuracy/noise calibration uses),
  * ``payload_bits``               — exact wire size of a quantized tensor.

The optimizer's closed-form bit-widths are continuous; deployment rounds
them with ``round_bits`` (ceil preserves the accuracy constraint since
noise is monotonically decreasing in b).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qrange(x):
    """Tensor range (mu, phi) used by the asymmetric quantizer."""
    return jnp.min(x), jnp.max(x)


def quantize(x, bits: int, mu=None, phi=None):
    """-> (codes int32, scale, mu). codes in [0, 2^bits - 1]. Either end
    of the grid may be pinned by the caller; the other defaults to the
    tensor's own range."""
    if mu is None:
        mu = jnp.min(x)
    if phi is None:
        phi = jnp.max(x)
    levels = (1 << int(bits)) - 1
    scale = jnp.maximum((phi - mu) / levels, 1e-12)
    codes = jnp.clip(jnp.round((x - mu) / scale), 0, levels).astype(jnp.int32)
    return codes, scale, mu


def dequantize(codes, scale, mu, dtype=jnp.float32):
    return (codes.astype(jnp.float32) * scale + mu).astype(dtype)


def fake_quant(x, bits: int):
    """Quantize-dequantize; identity gradient (STE) for completeness."""
    codes, scale, mu = quantize(x, bits)
    return dequantize(codes, scale, mu, x.dtype)


def quant_noise_energy(x, bits: int) -> jnp.ndarray:
    """Measured ``||x - Q(x)||_2^2`` — the empirical LHS of Eq. 18/19."""
    err = x - fake_quant(x, bits)
    return jnp.sum(jnp.square(err.astype(jnp.float32)))


def analytic_noise_scale(x) -> jnp.ndarray:
    """Analytic s such that ||sigma(b)||^2 ~= s * e^(-ln4 * b).

    Uniform round-off noise has variance step^2/12 with
    step = range/(2^b - 1) ~= range * 2^-b, so the energy over n elements is
    ``n * range^2 / 12 * 4^-b`` — i.e. the paper's exponential law with
    s = n * range^2 / 12. Tests check the empirical fit matches.
    """
    mu, phi = qrange(x)
    n = x.size
    return n * jnp.square(phi - mu) / 12.0


def round_bits(b, lo: int = 2, hi: int = 16):
    """Continuous solver output -> deployable integer bit-widths."""
    return jnp.clip(jnp.ceil(b), lo, hi).astype(jnp.int32)


def payload_bits(num_elements: int, bits) -> jnp.ndarray:
    """Wire size in bits: Eq. 14 term ``b * z`` (+ f32 scale/zero header)."""
    return num_elements * bits + 2 * 32


def quantize_stacked(leaf, bits: int = 8, per_channel: bool = True,
                     use_pallas=None):
    """Real int8/int4-code quantization of a stacked (num_periods, ...)
    weight. Granularity: per-period AND (by default) per-output-column —
    scale/mu keep the leading period axis and the trailing channel axis,
    e.g. (P, 1, N) for a (P, K, N) leaf. Returns the wire representation
    ``{"codes", "scale", "mu"}`` the serving path stores in HBM and
    dequantizes at block entry (transformer._dequant_block); a period
    slice (``codes[i]``, ``scale[i]``, ``mu[i]``) feeds the per-channel
    Pallas qmatmul kernels directly (DESIGN.md §4).

    Metadata footprint: per-channel carries 2·32·N header bits per
    period vs the per-tensor 64 — a 64/(K·b) relative overhead (~3% for
    a 512-row int4 layer, ~0.4% int8). ``payload_bits`` and the
    planner's Eq. 14 accounting model the per-tensor header; pass
    ``per_channel=False`` where exact wire-size accounting outweighs
    the accuracy gain.

    bits <= 4 packs two codes per byte on the last dim (the qmatmul4
    kernel's wire layout: low nibble = even column) — the HBM weight
    footprint really halves vs int8. On TPU the quantize and the pack run
    as ONE fused Pallas pass per period (kernels.quantize_pack4_pallas)
    instead of materializing int8 codes and strided-slicing them;
    ``use_pallas`` requests the path (None = auto: TPU backend only) but
    leaves whose K/N don't tile the kernel blocks fall back to the jnp
    pack — same bytes, just not fused."""
    if per_channel and leaf.ndim >= 3:
        axes = tuple(range(1, leaf.ndim - 1))     # keep periods + channels
    else:
        axes = tuple(range(1, leaf.ndim))
    mu = jnp.min(leaf, axis=axes, keepdims=True)
    phi = jnp.max(leaf, axis=axes, keepdims=True)
    levels = (1 << int(bits)) - 1
    scale = jnp.maximum((phi - mu) / levels, 1e-12)
    meta = {"scale": scale.astype(jnp.float32),
            "mu": mu.astype(jnp.float32)}
    if bits <= 4 and leaf.shape[-1] % 2 == 0:
        # key name encodes the packing (static pytree structure, so the
        # dequant site can branch without tracing a flag)
        return {"codes_packed": _pack4(leaf, meta["scale"], meta["mu"],
                                       use_pallas), **meta}
    codes = jnp.clip(jnp.round((leaf - mu) / scale), 0, levels)
    return {"codes": codes.astype(jnp.uint8), **meta}


def _pack4(leaf, scale, mu, use_pallas):
    """Quantize to 4-bit codes and pack nibble pairs. Routes 2-D period
    slices through the fused Pallas kernel when possible; otherwise the
    jnp strided-slice fallback (also the interpret-mode oracle)."""
    from repro.kernels import ops  # late import: kernels pull in pallas
    from repro.kernels.quantize import DEFAULT_BLOCK

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    k, n = (leaf.shape[-2], leaf.shape[-1]) if leaf.ndim >= 3 else (0, 0)
    bm, bn = DEFAULT_BLOCK       # mirror quantize_pack4_pallas's asserts
    tileable = leaf.ndim >= 3 and k > 0 and \
        k % min(bm, k) == 0 and n % min(bn, n) == 0 and \
        min(bn, n) % 2 == 0
    if use_pallas and tileable:
        lead = leaf.shape[:-2]
        flat = leaf.reshape((-1,) + leaf.shape[-2:])
        n_sc = scale.shape[-1]
        s2 = jnp.broadcast_to(scale, lead + (1, n_sc)).reshape(-1, 1, n_sc)
        m2 = jnp.broadcast_to(mu, lead + (1, n_sc)).reshape(-1, 1, n_sc)
        # one batched dispatch over the period axis, not P kernel launches
        packed = jax.vmap(ops.quantize_pack4)(flat, s2, m2)
        return packed.reshape(lead + packed.shape[-2:])
    codes = jnp.clip(jnp.round((leaf - mu) / scale), 0, 15).astype(jnp.uint8)
    return codes[..., 0::2] | (codes[..., 1::2] << 4)


def stacked_wire_bits(q) -> int:
    """EXACT wire/HBM size in bits of a ``quantize_stacked`` struct —
    codes plus the real scale/zero metadata (which, per-channel, is
    2·32·N per period rather than the 64-bit header ``payload_bits``
    models). Use this when accounting for what serving actually ships."""
    codes = q["codes_packed"] if "codes_packed" in q else q["codes"]
    return int(codes.size) * 8 + 32 * (int(q["scale"].size)
                                       + int(q["mu"].size))


QUANTIZABLE = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
               "w_z", "w_x", "w_out", "w_B", "w_C", "w_dt")


def quantize_params_for_serving(params, bits: int = 8,
                                per_channel: bool = True):
    """Quantize every big block weight of a transformer param tree (the
    QPART device-segment quantization applied to the whole serving stack:
    weights live int8 in HBM, cutting the decode memory-roofline term).
    ``per_channel`` follows quantize_stacked: better accuracy for a
    2·32·N-bit-per-period metadata footprint (see its docstring)."""
    def walk(node, under_blocks=False):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if under_blocks and k in QUANTIZABLE and hasattr(v, "ndim") \
                        and v.ndim >= 3:
                    out[k] = quantize_stacked(v, bits, per_channel=per_channel)
                else:
                    out[k] = walk(v, under_blocks)
            return out
        if isinstance(node, list):
            return [walk(v, True) for v in node]
        return node

    return {k: ([walk(b, True) for b in v] if k == "blocks" else v)
            for k, v in params.items()}


def quantize_tree(params, bits_per_leaf):
    """Fake-quantize a parameter tree with per-leaf bit-widths (int or map
    keyed like the tree). Used to materialize the model segment QPART ships
    to the device."""
    leaves, treedef = jax.tree.flatten(params)
    if isinstance(bits_per_leaf, int):
        bits_list = [bits_per_leaf] * len(leaves)
    else:
        bits_list = jax.tree.flatten(bits_per_leaf)[0]
    out = [fake_quant(x, int(b)) for x, b in zip(leaves, bits_list)]
    return jax.tree.unflatten(treedef, out)
