"""Quantization-noise and accuracy-degradation model (paper Eq. 18–22,
following Zhou et al. AAAI'18 [33]).

Quantities per layer l of the model segment:

  s_l    — noise-energy scale at the OUTPUT (logits) caused by quantizing
           layer l: ``||sigma_l(b)||^2 = s_l * e^(-ln4 b)``. Calibrated by
           quantizing layer l at a probe bit-width b0 and measuring the
           output perturbation: s_l = E0 * 4^b0 (the exponential law is
           exact for uniform round-off noise; the linear propagation to the
           output preserves it in expectation).
  sigma* — adversarial noise: the minimal L2 perturbation of the final
           activation (logits) that flips the prediction. For an argmax
           classifier this has the closed form  (z_top1 - z_top2)/sqrt(2).
  rho_l  — robustness of layer l (Eq. 22): mean quantization noise energy
           over the calibration set / mean adversarial noise energy.
  psi_l  — accuracy-degradation measure (Eq. 20–21): ||sigma_l||^2 / rho_l,
           additive across layers.
  Delta(a) — constraint budget for accuracy degradation target a,
           calibrated by injecting output noise at increasing psi and
           measuring the empirical accuracy drop (Alg. 1 step 8).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import fake_quant

PROBE_BITS = 8
LN4 = float(np.log(4.0))


@dataclasses.dataclass
class LayerNoiseProfile:
    """Calibrated noise statistics for one partitionable layer."""
    s_w: float          # weight-quantization output-noise scale
    s_x: float          # activation-quantization output-noise scale
    rho: float          # robustness (Eq. 22)


@dataclasses.dataclass
class NoiseCalibration:
    layers: Sequence[LayerNoiseProfile]
    adv_noise_mean: float           # mean ||sigma*||^2 over the calib set
    delta_table: dict               # accuracy target a -> Delta budget

    def delta_for(self, a: float) -> float:
        """Largest tabulated budget whose degradation <= a (Alg. 2 step 1)."""
        keys = sorted(self.delta_table)
        best = self.delta_table[keys[0]]
        for k in keys:
            if k <= a:
                best = self.delta_table[k]
        return best


def adversarial_noise_energy(logits) -> jnp.ndarray:
    """||sigma*||^2 per example: minimal L2 logit perturbation flipping
    argmax = margin/sqrt(2), energy = margin^2/2."""
    top2 = jax.lax.top_k(logits, 2)[0]
    margin = top2[..., 0] - top2[..., 1]
    return jnp.square(margin) / 2.0


def output_noise_energy(apply_fn: Callable, params_clean, params_noisy, x):
    """||f(x; W') - f(x; W)||^2 summed over the batch."""
    clean = apply_fn(params_clean, x)
    noisy = apply_fn(params_noisy, x)
    d = (noisy - clean).astype(jnp.float32)
    return jnp.sum(jnp.square(d))


def calibrate_layer(apply_fn, params, x, layer_idx: int,
                    set_layer_weights, get_layer_weights,
                    activations, probe_bits: int = PROBE_BITS):
    """Measure (s_w, s_x) for one layer.

    ``set_layer_weights(params, idx, w)`` / ``get_layer_weights`` adapt the
    concrete parameter pytree; ``activations[idx]`` is the layer's input
    batch (for the activation-noise probe).
    """
    w = get_layer_weights(params, layer_idx)
    wq = jax.tree.map(lambda t: fake_quant(t, probe_bits), w)
    noisy = set_layer_weights(params, layer_idx, wq)
    e_w = output_noise_energy(apply_fn, params, noisy, x)
    s_w = float(e_w) * 4.0 ** probe_bits

    # activation probe: quantize the layer input, measure output deviation
    act = activations[layer_idx]
    act_q = fake_quant(act, probe_bits)

    def from_layer(a):
        return apply_fn(params, a, start=layer_idx)

    d = (from_layer(act_q) - from_layer(act)).astype(jnp.float32)
    e_x = float(jnp.sum(jnp.square(d)))
    s_x = e_x * 4.0 ** probe_bits
    return s_w, s_x


def backend_layer_energies(backend, x, probe_bits: int = PROBE_BITS):
    """Reference SCALAR probe loop for Alg. 1 steps 7–9 over a serving
    ``ModelBackend`` (duck-typed: only the protocol's forward family is
    touched). Per layer l: quantize the layer's weights / input
    activation at ``probe_bits`` and measure the squared logit
    perturbation — 1 full + 2 suffix forwards per layer, L times.

    This is the ground truth the backends' vectorized
    ``calibrate_probes`` overrides (one chunked ``lax.map`` over a
    "which layer is quantized" index, a single compiled program) are
    regression-locked against — tests and ``benchmarks/
    calibration_bench.py`` both compare against it.

    Returns (e_w (L,), e_x (L,), clean logits (B, C))."""
    acts, logits = backend.layer_activations(x)
    L = backend.num_layers
    e_w = np.zeros(L)
    e_x = np.zeros(L)
    for l in range(L):
        noisy = backend.with_layer_quantized(l, probe_bits)
        d_w = (backend.forward(x, params=noisy) - logits).astype(jnp.float32)
        e_w[l] = float(jnp.sum(jnp.square(d_w)))
        aq = fake_quant(acts[l], probe_bits)
        d = backend.forward_from_layer(aq, l) \
            - backend.forward_from_layer(acts[l], l)
        e_x[l] = float(jnp.sum(jnp.square(d.astype(jnp.float32))))
    return e_w, e_x, logits


def accuracy(apply_fn, params, x, y) -> float:
    logits = apply_fn(params, x)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def calibrate_delta(apply_fn, params, x, y, rhos, targets,
                    key=None, trials: int = 3):
    """Map accuracy-degradation targets -> psi budgets Delta (Alg.1 step 8).

    Injects Gaussian noise of increasing energy on the logits, converts each
    energy to the psi it represents, and records the largest psi whose
    measured degradation stays within each target.
    """
    key = key if key is not None else jax.random.key(0)
    base = accuracy(apply_fn, params, x, y)
    logits = apply_fn(params, x)
    mean_rho = float(np.mean(rhos)) if len(rhos) else 1.0

    # Adaptive grid: degradation switches on when the per-example noise
    # energy approaches the adversarial energy, i.e. psi* ~ adv_mean / rho
    # (by Eq. 20–22). Sweep four decades below to one above.
    adv_mean = float(jnp.mean(adversarial_noise_energy(logits)))
    psi_star = max(adv_mean / max(mean_rho, 1e-30), 1e-12)
    psis = psi_star * np.logspace(-4, 1, 60)
    degr = np.zeros_like(psis)
    for i, psi in enumerate(psis):
        # psi = ||sigma||^2 / rho -> per-example output-noise energy
        energy = psi * mean_rho
        accs = []
        for t in range(trials):
            k = jax.random.fold_in(key, i * trials + t)
            g = jax.random.normal(k, logits.shape)
            g = g / jnp.maximum(
                jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-12)
            noisy = logits + g * jnp.sqrt(energy)
            accs.append(float(jnp.mean(jnp.argmax(noisy, -1) == y)))
        degr[i] = base - float(np.mean(accs))
    # enforce monotonicity (measurement noise) then invert
    degr = np.maximum.accumulate(degr)
    table = {}
    for a in targets:
        ok = psis[degr <= a + 1e-9]
        table[a] = float(ok[-1]) if len(ok) else float(psis[0])
    return table, base
