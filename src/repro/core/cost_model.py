"""Analytic cost model (paper §III, Eq. 1–8 and 13–16, Table II defaults).

Layer granularity: a ``LayerSpec`` carries the three quantities the QPART
optimizer needs — parameter payload ``z_w``, cut-activation payload
``z_x`` and MAC count ``o``. Builders are provided for the paper's
classifiers (Eq. 1–2 exactly) and for every assigned transformer family
(per-block MACs; attention uses the causal-useful S^2/2 term).

The same objective can be instantiated with radio constants (paper
reproduction) or TPU ICI constants (deployment view, DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from repro.configs.base import ATTN, ModelConfig
from repro.configs.classifier import ClassifierConfig, DenseSpec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    z_w: float      # weight elements
    z_x: float      # output-activation elements (per request batch)
    o: float        # MAC operations (per request batch)


# ---------------------------------------------------------------------------
# Profiles (paper Table II defaults).

@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    f_clock: float = 200e6          # Hz
    gamma: float = 5.0              # cycles / MAC
    kappa: float = 3e-27            # energy-efficiency (J / cycle / Hz^2)
    tx_power: float = 1.0           # W
    memory_bytes: float = 512e6


@dataclasses.dataclass(frozen=True)
class ServerProfile:
    f_clock: float = 3e9
    gamma: float = 5.0 / 4.0
    eta_m: float = 3.75e-27
    zeta: float = 1e-2              # $ / s of server compute


@dataclasses.dataclass(frozen=True)
class Channel:
    bandwidth_hz: float = 40e6
    snr_db: Optional[float] = None
    capacity_bps: float = 200e6     # direct r (Table II); SNR overrides

    def capacity(self) -> float:
        if self.snr_db is None:
            return self.capacity_bps
        return self.bandwidth_hz * math.log2(1.0 + 10 ** (self.snr_db / 10))


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    omega: float = 1.0              # time
    tau: float = 1.0                # energy
    eta: float = 1e-6               # server cost (scales $ into the objective)


# ---------------------------------------------------------------------------
# Eq. 24–26 reduced coefficients.

def xi_coeff(w: ObjectiveWeights, d: DeviceProfile) -> float:
    return w.omega * d.gamma / d.f_clock + w.tau * d.gamma * d.kappa * d.f_clock ** 2


def delta_coeff(w: ObjectiveWeights, s: ServerProfile) -> float:
    return (w.omega + w.eta * s.zeta) * s.gamma / s.f_clock


def eps_coeff(w: ObjectiveWeights, d: DeviceProfile, ch: Channel) -> float:
    return (w.omega + d.tx_power * w.tau) / ch.capacity()


# ---------------------------------------------------------------------------
# Raw cost terms (Eq. 5–8, 15–16).

@dataclasses.dataclass
class CostBreakdown:
    t_local: float
    t_server: float
    t_tran: float
    e_local: float
    e_tran: float
    server_cost: float

    @property
    def t_total(self):
        return self.t_local + self.t_server + self.t_tran

    @property
    def e_total(self):
        return self.e_local + self.e_tran

    def objective(self, w: ObjectiveWeights) -> float:
        return (w.omega * self.t_total + w.tau * self.e_total
                + w.eta * self.server_cost)


def cost_breakdown(o1: float, o2: float, payload_bits: float,
                   d: DeviceProfile, s: ServerProfile, ch: Channel) -> CostBreakdown:
    r = ch.capacity()
    t_local = o1 * d.gamma / d.f_clock
    e_local = d.kappa * d.f_clock ** 2 * o1 * d.gamma
    t_server = o2 * s.gamma / s.f_clock
    c = o2 * s.gamma * s.zeta / s.f_clock
    t_tran = payload_bits / r
    e_tran = d.tx_power * t_tran
    return CostBreakdown(t_local, t_server, t_tran, e_local, e_tran, c)


# ---------------------------------------------------------------------------
# Layer specs: classifiers (paper Eq. 1–2).

def classifier_layer_specs(cfg: ClassifierConfig, batch: int = 1) -> List[LayerSpec]:
    specs = []
    for i, l in enumerate(cfg.layers):
        if isinstance(l, DenseSpec):
            o = l.in_dim * l.out_dim                       # Eq. 1
            z_w = l.in_dim * l.out_dim + l.out_dim
            z_x = l.out_dim
        else:
            o = l.c_in * l.c_out * l.f1 * l.f2 * l.u * l.v  # Eq. 2
            z_w = l.f1 * l.f2 * l.c_in * l.c_out + l.c_out
            u, v = l.u // l.pool, l.v // l.pool
            z_x = l.c_out * u * v
        specs.append(LayerSpec(f"layer{i + 1}", z_w, z_x * batch, o * batch))
    return specs


# ---------------------------------------------------------------------------
# Layer specs: assigned transformer families.

def transformer_layer_specs(cfg: ModelConfig, seq_len: int,
                            batch: int = 1, mode: str = "prefill") -> List[LayerSpec]:
    """Per-block specs. ``mode`` prefill counts the full sequence; decode
    counts one token against a seq_len context. The embedding table is
    layer 0 (always on-device: it starts the computation)."""
    d = cfg.d_model
    tokens = batch * (seq_len if mode != "decode" else 1)
    specs = [LayerSpec("embed", cfg.vocab_size * d, tokens * d, 0.0)]
    hd = cfg.resolved_head_dim()
    win = cfg.sliding_window
    for l in range(cfg.num_layers):
        z_w = float(cfg._block_params(l))
        o = 0.0
        if cfg.block_kind(l) == ATTN:
            proj = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd + cfg.num_heads * hd * d
            o += tokens * proj
            if mode == "decode":
                ctx = min(seq_len, win) if win else seq_len
                o += tokens * 2 * cfg.num_heads * hd * ctx
            else:
                ctx = min(seq_len, win) if win else seq_len
                avg_ctx = ctx if win else seq_len / 2
                o += tokens * 2 * cfg.num_heads * hd * avg_ctx
            z_x_state = 2 * cfg.num_kv_heads * hd * (min(seq_len, win) if win else seq_len)
        else:
            s = cfg.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            o += tokens * (d * (2 * di + 2 * s.d_state + nh) + di * d)
            o += tokens * s.conv_width * (di + 2 * s.d_state)
            # SSD: state update + readout + intra-chunk quadratic
            o += tokens * nh * (3 * s.d_state * s.head_dim
                                + (0 if mode == "decode" else s.chunk * (s.d_state + s.head_dim)))
            z_x_state = nh * s.d_state * s.head_dim + (s.conv_width - 1) * (di + 2 * s.d_state)
        if cfg.uses_moe(l):
            m = cfg.moe
            mult = 3 if cfg.mlp == "swiglu" else 2
            o += tokens * (d * m.num_experts + m.top_k * mult * d * m.d_ff)
        elif cfg.d_ff:
            mult = 3 if cfg.mlp == "swiglu" else 2
            o += tokens * mult * d * cfg.d_ff
        # cut activation: hidden state(s) crossing the partition
        z_x = tokens * d + (batch * z_x_state if mode == "decode" else 0)
        specs.append(LayerSpec(f"block{l}", z_w, float(z_x), float(o)))
    return specs


def layer_specs_for(cfg, seq_len: int = 1, batch: int = 1,
                    mode: str = "prefill") -> List[LayerSpec]:
    if isinstance(cfg, ClassifierConfig):
        return classifier_layer_specs(cfg, batch)
    return transformer_layer_specs(cfg, seq_len, batch, mode)
