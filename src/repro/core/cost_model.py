"""Analytic cost model (paper §III, Eq. 1–8 and 13–16, Table II defaults).

Layer granularity: a ``LayerSpec`` carries the three quantities the QPART
optimizer needs — parameter payload ``z_w``, cut-activation payload
``z_x`` and MAC count ``o``. Builders are provided for the paper's
classifiers (Eq. 1–2 exactly) and for every assigned transformer family
(per-block MACs; attention uses the causal-useful S^2/2 term).

The same objective can be instantiated with radio constants (paper
reproduction) or TPU ICI constants (deployment view, DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.configs.classifier import ClassifierConfig, DenseSpec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    z_w: float      # weight elements
    z_x: float      # output-activation elements (per request batch)
    o: float        # MAC operations (per request batch)
    # -- memory-traffic columns (CostModel v2, DESIGN.md §9). Defaults
    # derive from z_w/z_x at bf16 (2 B/elem; activations read + written);
    # builders or the HLO attribution helper may override with measured
    # numbers. The WEIGHT stream at the deployed (quantized) bit-widths
    # is plan-dependent and lives on PartitionPlan.device_memory_bytes;
    # w_bytes16 is the full-precision stream the SERVER side pays.
    w_bytes16: Optional[float] = None   # weight-stream bytes at bf16
    act_bytes: Optional[float] = None   # activation read+write bytes (bf16,
                                        # per request batch, like z_x/o)
    kv_bytes16: Optional[float] = None  # resident decode-cache footprint at
                                        # the context the specs were built
                                        # for (bf16 storage; per request
                                        # batch). 0.0 for cache-less layers
                                        # (classifiers, prefill-only views).

    def __post_init__(self):
        if self.w_bytes16 is None:
            object.__setattr__(self, "w_bytes16", 2.0 * self.z_w)
        if self.act_bytes is None:
            object.__setattr__(self, "act_bytes", 4.0 * self.z_x)
        if self.kv_bytes16 is None:
            object.__setattr__(self, "kv_bytes16", 0.0)


# ---------------------------------------------------------------------------
# Profiles (paper Table II defaults).

@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    f_clock: float = 200e6          # Hz
    gamma: float = 5.0              # cycles / MAC
    kappa: float = 3e-27            # energy-efficiency (J / cycle / Hz^2)
    tx_power: float = 1.0           # W
    memory_bytes: float = 512e6
    mem_bw: float = 25.6e9          # bytes/s memory bandwidth (LPDDR-class;
                                    # only the roofline/calibrated providers
                                    # read it)


@dataclasses.dataclass(frozen=True)
class ServerProfile:
    f_clock: float = 3e9
    gamma: float = 5.0 / 4.0
    eta_m: float = 3.75e-27
    zeta: float = 1e-2              # $ / s of server compute
    mem_bw: float = 100e9           # bytes/s memory bandwidth (DDR-class)


@dataclasses.dataclass(frozen=True)
class Channel:
    bandwidth_hz: float = 40e6
    snr_db: Optional[float] = None
    capacity_bps: float = 200e6     # direct r (Table II); SNR overrides

    def __post_init__(self):
        # memoized at construction: the SNR log2 path used to recompute
        # per capacity() call, and the pricing hot paths call it per
        # request per window
        if self.snr_db is None:
            cap = self.capacity_bps
        else:
            cap = self.bandwidth_hz * math.log2(1.0 + 10 ** (self.snr_db / 10))
        object.__setattr__(self, "_cap", cap)

    def capacity(self) -> float:
        return self._cap


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    omega: float = 1.0              # time
    tau: float = 1.0                # energy
    eta: float = 1e-6               # server cost (scales $ into the objective)


# ---------------------------------------------------------------------------
# Eq. 24–26 reduced coefficients.

def xi_coeff(w: ObjectiveWeights, d: DeviceProfile) -> float:
    return w.omega * d.gamma / d.f_clock + w.tau * d.gamma * d.kappa * d.f_clock ** 2


def delta_coeff(w: ObjectiveWeights, s: ServerProfile) -> float:
    return (w.omega + w.eta * s.zeta) * s.gamma / s.f_clock


def eps_coeff(w: ObjectiveWeights, d: DeviceProfile, ch: Channel) -> float:
    return (w.omega + d.tx_power * w.tau) / ch.capacity()


# ---------------------------------------------------------------------------
# Raw cost terms (Eq. 5–8, 15–16).

@dataclasses.dataclass
class CostBreakdown:
    t_local: float
    t_server: float
    t_tran: float
    e_local: float
    e_tran: float
    server_cost: float

    @property
    def t_total(self):
        return self.t_local + self.t_server + self.t_tran

    @property
    def e_total(self):
        return self.e_local + self.e_tran

    def objective(self, w: ObjectiveWeights) -> float:
        return (w.omega * self.t_total + w.tau * self.e_total
                + w.eta * self.server_cost)


def cost_breakdown(o1: float, o2: float, payload_bits: float,
                   d: DeviceProfile, s: ServerProfile, ch: Channel) -> CostBreakdown:
    r = ch.capacity()
    t_local = o1 * d.gamma / d.f_clock
    e_local = d.kappa * d.f_clock ** 2 * o1 * d.gamma
    t_server = o2 * s.gamma / s.f_clock
    c = o2 * s.gamma * s.zeta / s.f_clock
    t_tran = payload_bits / r
    e_tran = d.tx_power * t_tran
    return CostBreakdown(t_local, t_server, t_tran, e_local, e_tran, c)


# ---------------------------------------------------------------------------
# Layer specs: classifiers (paper Eq. 1–2).

def classifier_layer_specs(cfg: ClassifierConfig, batch: int = 1) -> List[LayerSpec]:
    specs = []
    for i, l in enumerate(cfg.layers):
        if isinstance(l, DenseSpec):
            o = l.in_dim * l.out_dim                       # Eq. 1
            z_w = l.in_dim * l.out_dim + l.out_dim
            z_x = l.out_dim
        else:
            o = l.c_in * l.c_out * l.f1 * l.f2 * l.u * l.v  # Eq. 2
            z_w = l.f1 * l.f2 * l.c_in * l.c_out + l.c_out
            u, v = l.u // l.pool, l.v // l.pool
            z_x = l.c_out * u * v
        specs.append(LayerSpec(f"layer{i + 1}", z_w, z_x * batch, o * batch))
    return specs


# ---------------------------------------------------------------------------
# Layer specs: assigned transformer families.

def transformer_layer_specs(cfg: ModelConfig, seq_len: int,
                            batch: int = 1, mode: str = "prefill") -> List[LayerSpec]:
    """Per-block specs. ``mode`` prefill counts the full sequence; decode
    counts one token against a seq_len context. The embedding table is
    layer 0 (always on-device: it starts the computation)."""
    d = cfg.d_model
    tokens = batch * (seq_len if mode != "decode" else 1)
    specs = [LayerSpec("embed", cfg.vocab_size * d, tokens * d, 0.0)]
    hd = cfg.resolved_head_dim()
    win = cfg.sliding_window
    kvp, _ = cfg.padded_heads()
    for l in range(cfg.num_layers):
        z_w = float(cfg._block_params(l))
        o = 0.0
        kv_rw_bytes = 0.0     # per-token decode cache read+write traffic
        kv_f16 = 0.0          # resident cache footprint (bf16 storage)
        if cfg.block_kind(l) == ATTN:
            proj = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd + cfg.num_heads * hd * d
            o += tokens * proj
            ctx = min(seq_len, win) if win else seq_len
            if mode == "decode":
                o += tokens * 2 * cfg.num_heads * hd * ctx
            else:
                avg_ctx = ctx if win else seq_len / 2
                o += tokens * 2 * cfg.num_heads * hd * avg_ctx
            z_x_state = 2 * cfg.num_kv_heads * hd * (min(seq_len, win) if win else seq_len)
            # ring buffer {k, v}: (B, ctx, KV_pad, hd) at 2 B/elem; one
            # decode step reads the whole ring and writes one slot
            kv_f16 = batch * 2.0 * (2 * kvp * hd * ctx)
            kv_rw_bytes = batch * 2.0 * (2 * kvp * hd * ctx + 2 * kvp * hd)
        else:
            s = cfg.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            o += tokens * (d * (2 * di + 2 * s.d_state + nh) + di * d)
            o += tokens * s.conv_width * (di + 2 * s.d_state)
            # SSD: state update + readout + intra-chunk quadratic
            o += tokens * nh * (3 * s.d_state * s.head_dim
                                + (0 if mode == "decode" else s.chunk * (s.d_state + s.head_dim)))
            z_x_state = nh * s.d_state * s.head_dim + (s.conv_width - 1) * (di + 2 * s.d_state)
            # recurrent state is f32 (4 B/elem) regardless of storage
            # dtype; the conv ring follows the cache dtype (2 B at bf16).
            # Both are read AND written every decode step.
            state_el = nh * s.d_state * s.head_dim
            conv_el = (s.conv_width - 1) * (di + 2 * s.d_state)
            kv_f16 = batch * (4.0 * state_el + 2.0 * conv_el)
            kv_rw_bytes = batch * (8.0 * state_el + 4.0 * conv_el)
        if cfg.uses_moe(l):
            m = cfg.moe
            mult = 3 if cfg.mlp == "swiglu" else 2
            o += tokens * (d * m.num_experts + m.top_k * mult * d * m.d_ff)
        elif cfg.d_ff:
            mult = 3 if cfg.mlp == "swiglu" else 2
            o += tokens * mult * d * cfg.d_ff
        # cut activation: hidden state(s) crossing the partition
        z_x = tokens * d + (batch * z_x_state if mode == "decode" else 0)
        # decode act_bytes made EXPLICIT: the default 4·z_x would charge
        # the full state transfer as per-layer traffic — the real per-
        # token traffic is the hidden r/w plus the cache r/w above
        ab = 4.0 * tokens * d + kv_rw_bytes if mode == "decode" else None
        specs.append(LayerSpec(f"block{l}", z_w, float(z_x), float(o),
                               act_bytes=ab, kv_bytes16=float(kv_f16)))
    return specs


def kv_bytes_row(specs: List[LayerSpec]) -> np.ndarray:
    """(P+1,) cumulative resident decode-cache footprint of the DEVICE
    segment — candidate c holds layers 1..c's caches for the lifetime of
    the stream (bf16-storage accounting; a quantized segment that stores
    its cache at a narrower dtype only shrinks this, so the feasibility
    mask stays conservative)."""
    return np.concatenate(
        [[0.0], np.cumsum([sp.kv_bytes16 for sp in specs])])


def layer_specs_for(cfg, seq_len: int = 1, batch: int = 1,
                    mode: str = "prefill") -> List[LayerSpec]:
    if isinstance(cfg, ClassifierConfig):
        return classifier_layer_specs(cfg, batch)
    return transformer_layer_specs(cfg, seq_len, batch, mode)


# ---------------------------------------------------------------------------
# CostModel v2: pluggable cost providers (DESIGN.md §9).
#
# Every online decision — Alg. 2 plan selection, ``price_window``'s
# matrix objective, the fleet engine's reservations and SLO admission —
# prices candidates through ONE linear contract:
#
#     obj[r, p] = sum_k  c_k[r] · T_k[p]
#
# where ``c_k`` are per-request coefficients (a provider's ``coeffs``)
# and ``T_k`` per-candidate term vectors (``CandidateRows`` → ``terms``).
# The paper's Eq. 17 is the K=3 instance (xi·O1 + delta·O2 + eps·wire);
# the roofline and calibrated providers extend K with memory-traffic
# terms without giving up the one-matrix-op-per-window hot path.

TERM_NAMES = ("o1", "o2", "wire", "dev_bytes", "srv_bytes")
TERM_O1, TERM_O2, TERM_WIRE, TERM_DEV_BYTES, TERM_SRV_BYTES = range(5)

_COEFF_CACHE_MAX = 4096


@dataclasses.dataclass
class CandidateRows:
    """Per-candidate term vectors of one (model, accuracy level, batch,
    cached) pricing profile; column c = partition point c (c=0 is full
    offload). The byte rows are ``None`` when the provider's term set
    does not use them (the analytic default)."""
    o1: np.ndarray                       # (P+1,) device-side MACs
    o2: np.ndarray                       # (P+1,) server-side MACs
    wire: np.ndarray                     # (P+1,) wire bits
    dev_bytes: Optional[np.ndarray] = None   # device memory traffic at the
    # deployed (quantized) bit-widths + activation read/write
    srv_bytes: Optional[np.ndarray] = None   # server tail traffic at bf16

    def bytes_at(self, c: int):
        """(dev_bytes, srv_bytes) scalars of candidate ``c`` (0.0 when
        the byte rows were not built)."""
        db = float(self.dev_bytes[c]) if self.dev_bytes is not None else 0.0
        sb = float(self.srv_bytes[c]) if self.srv_bytes is not None else 0.0
        return db, sb


def byte_term_rows(layer_act_bytes, layer_w_bytes16):
    """THE canonical byte-term row math, over raw per-layer arrays
    (shared by the online pricing helpers below and the offline solver —
    one implementation, so stored and runtime byte terms can never
    drift): returns ``(ab_cum, srv_row)`` — the cumulative device
    activation-traffic row and the server tail byte row, both (L+1,)
    with column c = partition point c."""
    ab = np.asarray(layer_act_bytes, np.float64)
    wb = np.asarray(layer_w_bytes16, np.float64)
    ab_cum = np.concatenate([[0.0], np.cumsum(ab)])
    tail = wb + ab
    srv = np.concatenate([[tail.sum()], tail.sum() - np.cumsum(tail)])
    return ab_cum, srv


def candidate_byte_rows(specs: List[LayerSpec], mem_row: np.ndarray,
                        ab_cum: np.ndarray):
    """(dev_bytes, srv_bytes) rows for one level/batch profile:
    ``mem_row`` is the store's deployed-bit weight footprint per
    candidate (``OfflineStore.level_memory_rows``), ``ab_cum`` the
    cumulative activation-traffic row for the batch
    (``act_bytes_row``)."""
    _, srv = byte_term_rows([sp.act_bytes for sp in specs],
                            [sp.w_bytes16 for sp in specs])
    return mem_row + ab_cum, srv


def act_bytes_row(specs: List[LayerSpec]) -> np.ndarray:
    """(P+1,) cumulative activation read+write bytes of the device
    segment — candidate c streams layers 1..c's activations."""
    return np.concatenate(
        [[0.0], np.cumsum([sp.act_bytes for sp in specs])])


def plan_cost_terms(plan, specs: List[LayerSpec]):
    """(o1, o2, dev_bytes, srv_bytes) scalars of one deployed plan —
    what the calibration ledger regresses measured stage times
    against."""
    o = np.array([sp.o for sp in specs], dtype=np.float64)
    p = plan.p
    o1, o2 = float(o[:p].sum()), float(o[p:].sum())
    dev_b = plan.device_memory_bytes \
        + float(sum(sp.act_bytes for sp in specs[:p]))
    srv_b = float(sum(sp.w_bytes16 + sp.act_bytes for sp in specs[p:]))
    return o1, o2, dev_b, srv_b


class CostProvider:
    """The pluggable pricing contract. A provider supplies

      * ``coeffs`` — the per-request coefficient vector c_k (cached per
        distinct (weights, device, channel, server) profile),
      * ``terms`` — the (K, P+1) term matrix from a ``CandidateRows``,
      * stage-time estimates (``device_seconds`` / ``server_seconds``)
        the fleet engine's SLO finish estimates, reservations and
        ``CostBreakdown`` assembly run on,
      * ``server_correction`` — the row addend that re-prices a
        candidate row against a different fleet server, and
      * ``wire_coeff`` — the coefficient on the wire term, which the
        engine's segment-cache repricing subtracts per cached candidate.

    Objective rows are accumulated term-by-term in declaration order
    (``objective_rows``), which keeps ``AnalyticCost`` bit-identical to
    the pre-provider ``xi·O1 + delta·O2 + eps·wire`` arithmetic.
    """

    name = "base"
    term_ids: tuple = (TERM_O1, TERM_O2, TERM_WIRE)

    # -- linear pricing contract ---------------------------------------
    def coeffs(self, w: ObjectiveWeights, d: DeviceProfile, ch: Channel,
               s: ServerProfile) -> np.ndarray:
        raise NotImplementedError

    def coeffs_cached(self, w, d, ch, s) -> np.ndarray:
        """One dict lookup per distinct (weights, device, channel,
        server) profile — windows re-use profiles heavily, so the hot
        path never recomputes the reduced coefficients per request."""
        cache = self.__dict__.setdefault("_coeff_cache", {})
        key = (w, d, ch, s)
        out = cache.get(key)
        if out is None:
            if len(cache) >= _COEFF_CACHE_MAX:
                cache.clear()
            out = cache[key] = self.coeffs(w, d, ch, s)
        return out

    @property
    def uses_bytes(self) -> bool:
        return TERM_DEV_BYTES in self.term_ids \
            or TERM_SRV_BYTES in self.term_ids

    def terms(self, rows: CandidateRows) -> List[np.ndarray]:
        """Term vectors in coefficient order (views, no copies)."""
        return [getattr(rows, TERM_NAMES[k]) for k in self.term_ids]

    @staticmethod
    def objective_rows(coeff: np.ndarray, terms) -> np.ndarray:
        """obj = sum_k coeff[k]·terms[k], accumulated left-to-right (the
        fixed association the bit-exactness lock relies on)."""
        obj = coeff[0] * terms[0]
        for k in range(1, len(terms)):
            obj = obj + coeff[k] * terms[k]
        return obj

    def wire_coeff(self, w: ObjectiveWeights, d: DeviceProfile,
                   ch: Channel) -> float:
        """Coefficient multiplying the wire-bits term (the engine's
        segment-cache repricing drops eps·(Z_w) per cached candidate)."""
        return eps_coeff(w, d, ch)

    def server_correction(self, w: ObjectiveWeights, ref: ServerProfile,
                          srv: ServerProfile,
                          rows: CandidateRows) -> np.ndarray:
        """Row addend pricing server ``srv`` from a table built against
        ``ref`` (the fleet's per-server re-pricing, one vector op)."""
        raise NotImplementedError

    # -- stage-time estimates ------------------------------------------
    def device_seconds(self, d: DeviceProfile, o1, dev_bytes=None):
        """Device-segment seconds (scalar or per-candidate vector)."""
        raise NotImplementedError

    def server_seconds(self, s: ServerProfile, o2, srv_bytes=None):
        """Server-segment seconds (scalar or per-candidate vector)."""
        raise NotImplementedError

    # -- cost assembly --------------------------------------------------
    def breakdown(self, o1: float, o2: float, payload_bits: float,
                  d: DeviceProfile, s: ServerProfile, ch: Channel,
                  dev_bytes: float = 0.0,
                  srv_bytes: float = 0.0) -> CostBreakdown:
        """Eq. 5–8/15–16 generalized: compute/memory stage times from
        the provider, transmission and energy kept analytic (the radio
        and the device energy model are not what providers disagree
        about)."""
        r = ch.capacity()
        t_local = self.device_seconds(d, o1, dev_bytes)
        e_local = d.kappa * d.f_clock ** 2 * o1 * d.gamma
        t_server = self.server_seconds(s, o2, srv_bytes)
        t_tran = payload_bits / r
        e_tran = d.tx_power * t_tran
        return CostBreakdown(float(t_local), float(t_server), t_tran,
                             e_local, e_tran, float(t_server) * s.zeta)

    # -- offline (Alg. 1) coefficients ---------------------------------
    _OFFLINE_KEYS = {TERM_O1: "xi", TERM_O2: "delta", TERM_WIRE: "eps",
                     TERM_DEV_BYTES: "c_dev_bytes",
                     TERM_SRV_BYTES: "c_srv_bytes"}

    def offline_coeffs(self, w: ObjectiveWeights, d: DeviceProfile,
                       ch: Channel, s: ServerProfile) -> dict:
        """Coefficients ``build_offline_store`` prices plans with —
        derived from the SAME ``coeffs`` vector the online paths use,
        so stored objectives and online pricing never drift. Terms the
        provider does not price default to 0.0."""
        out = {"xi": 0.0, "delta": 0.0, "eps": 0.0,
               "c_dev_bytes": 0.0, "c_srv_bytes": 0.0}
        for k, c in zip(self.term_ids, self.coeffs(w, d, ch, s)):
            out[self._OFFLINE_KEYS[k]] = float(c)
        return out


class AnalyticCost(CostProvider):
    """The paper's Table II math (Eq. 5–16, reduced coefficients
    Eq. 24–26) — the bit-exact default: every float it produces is
    identical to the pre-provider code path."""

    name = "analytic"
    term_ids = (TERM_O1, TERM_O2, TERM_WIRE)

    def coeffs(self, w, d, ch, s) -> np.ndarray:
        return np.array([xi_coeff(w, d), delta_coeff(w, s),
                         eps_coeff(w, d, ch)])

    def server_correction(self, w, ref, srv, rows) -> np.ndarray:
        return (delta_coeff(w, srv) - delta_coeff(w, ref)) * rows.o2

    def device_seconds(self, d, o1, dev_bytes=None):
        return o1 * d.gamma / d.f_clock

    def server_seconds(self, s, o2, srv_bytes=None):
        return o2 * s.gamma / s.f_clock

    def breakdown(self, o1, o2, payload_bits, d, s, ch,
                  dev_bytes=0.0, srv_bytes=0.0) -> CostBreakdown:
        return cost_breakdown(o1, o2, payload_bits, d, s, ch)


class RooflineCost(CostProvider):
    """Memory-roofline pricing (DESIGN.md §3 made a first-class cost):
    each compute stage pays an additive memory-traffic term on top of
    the analytic MAC term —

        t_local  = O1·gamma/f  +  dev_bytes / mem_bw_device
        t_server = O2·gamma/f  +  srv_bytes / mem_bw_server

    ``dev_bytes`` streams the QUANTIZED segment (the plan's deployed
    bit-widths — quantization's b/16 HBM cut shows up here, not just on
    the radio), ``srv_bytes`` the full-precision tail. Additive rather
    than max(): the objective stays linear in the term vectors, and the
    stage time is always lower-bounded by its compute-only term."""

    name = "roofline"
    term_ids = (TERM_O1, TERM_O2, TERM_WIRE, TERM_DEV_BYTES, TERM_SRV_BYTES)

    def coeffs(self, w, d, ch, s) -> np.ndarray:
        return np.array([xi_coeff(w, d), delta_coeff(w, s),
                         eps_coeff(w, d, ch),
                         w.omega / d.mem_bw,
                         (w.omega + w.eta * s.zeta) / s.mem_bw])

    def server_correction(self, w, ref, srv, rows) -> np.ndarray:
        corr = (delta_coeff(w, srv) - delta_coeff(w, ref)) * rows.o2
        c_sb = (w.omega + w.eta * srv.zeta) / srv.mem_bw \
            - (w.omega + w.eta * ref.zeta) / ref.mem_bw
        return corr + c_sb * rows.srv_bytes

    def device_seconds(self, d, o1, dev_bytes=0.0):
        dev_bytes = 0.0 if dev_bytes is None else dev_bytes
        return o1 * d.gamma / d.f_clock + dev_bytes / d.mem_bw

    def server_seconds(self, s, o2, srv_bytes=0.0):
        srv_bytes = 0.0 if srv_bytes is None else srv_bytes
        return o2 * s.gamma / s.f_clock + srv_bytes / s.mem_bw


@dataclasses.dataclass
class StageRates:
    """Fitted linear rates of one compute stage: seconds ≈
    r_mac·MACs + r_byte·bytes + r_const (the constant is per-dispatch
    overhead; it is charged only when the stage runs at all)."""
    r_mac: float
    r_byte: float
    r_const: float = 0.0

    def seconds(self, macs, nbytes):
        nbytes = 0.0 if nbytes is None else nbytes
        base = self.r_mac * macs + self.r_byte * nbytes
        return base + self.r_const * (np.asarray(macs) > 0)


class CalibratedCost(CostProvider):
    """Measurement-calibrated pricing: per-device/per-server
    ``StageRates`` fitted by the ``CalibrationLedger`` from wall-clock-
    fenced ``Deployment.execute`` stage timings. Coefficients keep the
    analytic energy/wire model (the radio is not measured) and replace
    the TIME rates with the fitted ones; the per-dispatch constants are
    priced into the stage estimates and breakdowns but not into the
    argmin row — a constant shifts every candidate that uses the stage
    equally, so it can only matter at the p=0 / p=L boundary (where one
    stage is skipped): a deliberate approximation that keeps the
    objective linear in the term vectors."""

    name = "calibrated"
    term_ids = (TERM_O1, TERM_O2, TERM_WIRE, TERM_DEV_BYTES, TERM_SRV_BYTES)

    def __init__(self, device_rates: dict, server_rates: dict,
                 default_device: StageRates, default_server: StageRates,
                 accept_rate: Optional[float] = None):
        self.device_rates = device_rates      # DeviceProfile -> StageRates
        self.server_rates = server_rates      # ServerProfile -> StageRates
        self.default_device = default_device
        self.default_server = default_server
        # pooled measured draft-acceptance rate (DESIGN.md §14) — what
        # the fleet engine's speculative lane resolves its default
        # ``accept_rate`` from when pricing through a calibrated
        # provider; None until a speculative generation was recorded
        self.mean_accept_rate = None if accept_rate is None \
            else float(accept_rate)

    def _dev(self, d: DeviceProfile) -> StageRates:
        return self.device_rates.get(d, self.default_device)

    def _srv(self, s: ServerProfile) -> StageRates:
        return self.server_rates.get(s, self.default_server)

    def coeffs(self, w, d, ch, s) -> np.ndarray:
        rd, rs = self._dev(d), self._srv(s)
        c_srv = w.omega + w.eta * s.zeta
        return np.array([
            w.omega * rd.r_mac + w.tau * d.gamma * d.kappa * d.f_clock ** 2,
            c_srv * rs.r_mac,
            eps_coeff(w, d, ch),
            w.omega * rd.r_byte,
            c_srv * rs.r_byte])

    def server_correction(self, w, ref, srv, rows) -> np.ndarray:
        r_ref, r_srv = self._srv(ref), self._srv(srv)
        c_ref, c_srv = w.omega + w.eta * ref.zeta, w.omega + w.eta * srv.zeta
        corr = (c_srv * r_srv.r_mac - c_ref * r_ref.r_mac) * rows.o2
        if rows.srv_bytes is not None:
            corr = corr + (c_srv * r_srv.r_byte
                           - c_ref * r_ref.r_byte) * rows.srv_bytes
        return corr

    def device_seconds(self, d, o1, dev_bytes=None):
        return self._dev(d).seconds(o1, dev_bytes)

    def server_seconds(self, s, o2, srv_bytes=None):
        return self._srv(s).seconds(o2, srv_bytes)


@dataclasses.dataclass
class _LedgerSample:
    device: DeviceProfile
    server: ServerProfile
    o1: float
    o2: float
    dev_bytes: float
    srv_bytes: float
    t_device: float
    t_server: float


class CalibrationLedger:
    """Least-squares closure of the predict → measure loop: collects
    (term scalars, measured stage seconds) samples from executed
    deployments and fits per-device/per-server ``StageRates``.

    The fit solves ``t ≈ r_mac·MACs + r_byte·bytes + r_const`` per
    group by non-negative-clipped least squares; groups (a distinct
    device or server profile) with fewer than ``min_samples`` samples
    fall back to the pooled global fit."""

    def __init__(self, min_samples: int = 3):
        self.samples: List[_LedgerSample] = []
        self.min_samples = min_samples
        # (drafts proposed, drafts accepted) per speculative generation —
        # pooled into ``mean_accept_rate`` (DESIGN.md §14)
        self.accept_samples: List[tuple] = []

    def __len__(self) -> int:
        return len(self.samples)

    def add(self, device: DeviceProfile, server: ServerProfile,
            o1: float, o2: float, dev_bytes: float, srv_bytes: float,
            t_device: float, t_server: float) -> None:
        self.samples.append(_LedgerSample(device, server, o1, o2,
                                          dev_bytes, srv_bytes,
                                          t_device, t_server))

    def record(self, deployment, server: ServerProfile) -> None:
        """Ingest one executed ``Deployment`` (its
        ``result.extra['measured']`` stage timings must exist — run
        ``Deployment.execute`` first). Terms are computed at the
        EXECUTED batch size, not the request's nominal one."""
        meas = deployment.result.extra.get("measured")
        if not meas:
            raise ValueError(
                "deployment has no measured stage timings — call "
                "Deployment.execute(test_x, test_y) before record()")
        specs = deployment.backend.layer_specs(batch=int(meas["batch"]))
        o1, o2, dev_b, srv_b = plan_cost_terms(deployment.plan, specs)
        self.add(deployment.request.device, server, o1, o2, dev_b, srv_b,
                 float(meas["t_device_s"]), float(meas["t_server_s"]))

    def record_decode(self, deployment, server: ServerProfile) -> None:
        """Ingest one streamed generation (``Deployment.generate`` fills
        ``result.extra['measured_decode']``): the aggregate decode stage
        seconds regress against N_tokens × the per-token decode terms —
        same linear model, so decode samples sharpen the same
        ``StageRates`` the prefill samples fit."""
        meas = deployment.result.extra.get("measured_decode")
        if not meas:
            raise ValueError(
                "deployment has no measured decode timings — call "
                "Deployment.generate(prompt, max_new_tokens) first")
        specs = deployment.backend.decode_layer_specs(
            batch=int(meas["batch"]))
        o1, o2, dev_b, srv_b = plan_cost_terms(deployment.plan, specs)
        n = float(meas["new_tokens"])
        self.add(deployment.request.device, server, o1 * n, o2 * n,
                 dev_b * n, srv_b * n,
                 float(meas["t_device_s"]), float(meas["t_server_s"]))
        if meas.get("accept_rate") is not None:
            self.accept_samples.append(
                (float(meas.get("drafts_proposed", 0)),
                 float(meas.get("drafts_accepted", 0))))

    @property
    def mean_accept_rate(self) -> Optional[float]:
        """Pooled measured draft acceptance (accepted / proposed over
        every recorded speculative generation); None until one lands."""
        proposed = sum(p for p, _ in self.accept_samples)
        if proposed <= 0:
            return None
        return sum(a for _, a in self.accept_samples) / proposed

    # ------------------------------------------------------------------
    @staticmethod
    def _fit_stage(macs, nbytes, secs) -> Optional[StageRates]:
        keep = np.asarray(macs) > 0          # stage actually ran
        macs = np.asarray(macs, np.float64)[keep]
        nbytes = np.asarray(nbytes, np.float64)[keep]
        secs = np.asarray(secs, np.float64)[keep]
        if len(secs) == 0:
            return None
        x = np.stack([macs, nbytes, np.ones_like(macs)], axis=1)
        sol, *_ = np.linalg.lstsq(x, secs, rcond=None)
        sol = np.maximum(sol, 0.0)           # rates are physical
        return StageRates(float(sol[0]), float(sol[1]), float(sol[2]))

    def fit(self) -> CalibratedCost:
        if not self.samples:
            raise ValueError("empty calibration ledger — record executed "
                             "deployments first")

        def stage(samples, attr_macs, attr_bytes, attr_t):
            return self._fit_stage(
                [getattr(s, attr_macs) for s in samples],
                [getattr(s, attr_bytes) for s in samples],
                [getattr(s, attr_t) for s in samples])

        glob_dev = stage(self.samples, "o1", "dev_bytes", "t_device") \
            or StageRates(0.0, 0.0, 0.0)
        glob_srv = stage(self.samples, "o2", "srv_bytes", "t_server") \
            or StageRates(0.0, 0.0, 0.0)
        by_dev: dict = {}
        by_srv: dict = {}
        for s in self.samples:
            by_dev.setdefault(s.device, []).append(s)
            by_srv.setdefault(s.server, []).append(s)
        dev_rates = {}
        for d, group in by_dev.items():
            if len(group) >= self.min_samples:
                r = stage(group, "o1", "dev_bytes", "t_device")
                if r is not None:
                    dev_rates[d] = r
        srv_rates = {}
        for sv, group in by_srv.items():
            if len(group) >= self.min_samples:
                r = stage(group, "o2", "srv_bytes", "t_server")
                if r is not None:
                    srv_rates[sv] = r
        return CalibratedCost(dev_rates, srv_rates, glob_dev, glob_srv,
                              accept_rate=self.mean_accept_rate)


def expected_tokens_per_round(draft_k: int, accept_rate: float) -> float:
    """Expected tokens one speculative decode round emits (DESIGN.md
    §14): the verified-prefix emission is 1 (the server's own sample) +
    the accepted drafts, so under a per-draft acceptance rate ``α`` the
    expectation is ``1 + α·k`` — the factor the per-round pricing terms
    divide by to get effective per-token cost, and the mean the fleet
    engine's deterministic fractional accumulator reproduces exactly
    over any window of rounds."""
    k = int(draft_k)
    if k < 0:
        raise ValueError("draft_k must be >= 0")
    a = float(accept_rate)
    if not 0.0 <= a <= 1.0:
        raise ValueError("accept_rate must be within [0, 1]")
    return 1.0 + a * k


ANALYTIC = AnalyticCost()       # the module-wide default provider
