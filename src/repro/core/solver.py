"""Closed-form QPART optimizer (paper §IV, Eq. 23–40).

Problem (per partition point p, Eq. 28 with the segment indices fixed —
the paper's Eq. 23 sums over l>=p but its own system description, Eq. 14
and Alg. 1 quantize the FIRST segment l=1..p; we implement the latter and
note the index typo in DESIGN.md):

    min_b   xi*O1(p) + delta*O2(p) + eps*( b_x * z_x(p) + sum_{l<=p} b_l z_l^w )
    s.t.    s_x(p) e^{-ln4 b_x}/rho_p + sum_{l<=p} s_l e^{-ln4 b_l}/rho_l <= Delta

KKT stationarity (Eq. 38) gives, for every quantized item i:

    eps * z_i = lambda * ln4 * (s_i/rho_i) * e^{-ln4 b_i}
    =>  z_i * rho_i / (s_i e^{-ln4 b_i}) = lambda * ln4 / eps = const   (Eq. 39)

i.e. equalized marginal payload-per-noise (water-filling). With the
constraint active, lambda has the closed form

    sum_i eps*z_i / (lambda ln4) = Delta   =>   lambda = eps * sum_i z_i / (Delta ln4)

and  b_i = log4( s_i ln4 lambda / (eps z_i rho_i) ). Items whose optimal
bit-width falls outside [b_min, b_max] are clamped and the multiplier is
re-solved on the active set (standard water-filling iteration; at most
n_items rounds).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

LN4 = math.log(4.0)


@dataclasses.dataclass
class SegmentItems:
    """Quantizable items of the device segment at partition p: the p weight
    tensors followed by the cut activation (the paper's z vector)."""
    z: np.ndarray        # payload sizes (elements)
    s: np.ndarray        # noise scales at output
    rho: np.ndarray      # robustness parameters


@dataclasses.dataclass
class BitSolution:
    bits: np.ndarray          # continuous optimal bit-widths, item-ordered
    lam: float                # KKT multiplier
    psi_total: float          # achieved constraint value
    payload_bits: float       # sum b_i z_i  (+ activation term)


def waterfill_bits(items: SegmentItems, delta: float,
                   b_min: float = 2.0, b_max: float = 16.0) -> BitSolution:
    """Equal-marginal closed form with active-set clamping."""
    z = np.asarray(items.z, dtype=np.float64)
    s = np.asarray(items.s, dtype=np.float64)
    rho = np.asarray(items.rho, dtype=np.float64)
    n = len(z)
    assert len(s) == n and len(rho) == n and delta > 0

    free = np.ones(n, dtype=bool)
    bits = np.zeros(n)
    budget = delta
    for _ in range(n + 1):
        if not free.any():
            break
        # noise contributed by clamped items
        clamped_noise = np.sum((s[~free] / rho[~free]) * np.exp(-LN4 * bits[~free]))
        rem = budget - clamped_noise
        if rem <= 0:
            # infeasible at current clamps: push everything to b_max
            bits[free] = b_max
            free[:] = False
            break
        lam = np.sum(z[free]) / (rem * LN4)          # eps cancels in bits
        with np.errstate(divide="ignore"):
            b_free = np.log(s[free] * LN4 * lam / (z[free] * rho[free])) / LN4
        lo, hi = b_free < b_min, b_free > b_max
        newly = np.zeros(n, dtype=bool)
        newly[np.where(free)[0][lo]] = True
        bits[np.where(free)[0][lo]] = b_min
        newly2 = np.zeros(n, dtype=bool)
        newly2[np.where(free)[0][hi]] = True
        bits[np.where(free)[0][hi]] = b_max
        if not (lo.any() or hi.any()):
            bits[free] = b_free
            free[:] = False
            break
        free &= ~(newly | newly2)
    psi = float(np.sum((s / rho) * np.exp(-LN4 * bits)))
    payload = float(np.sum(bits * z))
    return BitSolution(bits=bits, lam=float(lam) if n else 0.0,
                       psi_total=psi, payload_bits=payload)


# ---------------------------------------------------------------------------
# Joint (b, p) search: the paper's Alg. 1 (offline) + Alg. 2 (online).

@dataclasses.dataclass
class PartitionPlan:
    p: int                     # partition point (device runs layers 1..p)
    bits_w: np.ndarray         # per-layer weight bit-widths (len p)
    bits_x: float              # activation bit-width at the cut
    objective: float           # Eq. 17/23 value
    psi_total: float
    payload_bits: float
    breakdown: dict
    payload_w_bits: float = 0.0   # weight share of the wire (Eq. 14 Z_w)
    payload_x_bits: float = 0.0   # activation share (Z_x) — all that is
                                  # left when the device cached the segment


def plan_for_partition(p: int, layer_z_w, layer_z_x, layer_s_w, layer_s_x,
                       layer_rho, o_cum, o_total, xi, delta_cost, eps,
                       psi_budget, b_min=2.0, b_max=16.0,
                       input_z: float = 0.0) -> PartitionPlan:
    """Optimal bits for a fixed partition point p (1-indexed; p=0 means the
    whole model runs on the server: the device uploads the raw input at
    full precision and nothing is quantized)."""
    if p == 0:
        o1, o2 = 0.0, o_total
        obj = xi * o1 + delta_cost * o2 + eps * 32.0 * input_z
        return PartitionPlan(0, np.zeros(0), 32.0, float(obj), 0.0,
                             32.0 * input_z,
                             {"compute_local": 0.0,
                              "compute_server": delta_cost * o2,
                              "payload": eps * 32.0 * input_z},
                             payload_w_bits=0.0,
                             payload_x_bits=32.0 * input_z)
    items = SegmentItems(
        z=np.array(list(layer_z_w[:p]) + [layer_z_x[p - 1]], dtype=np.float64),
        s=np.array(list(layer_s_w[:p]) + [layer_s_x[p - 1]], dtype=np.float64),
        rho=np.array(list(layer_rho[:p]) + [layer_rho[p - 1]], dtype=np.float64),
    )
    sol = waterfill_bits(items, psi_budget, b_min, b_max)
    o1 = o_cum[p - 1]
    o2 = o_total - o1
    payload = sol.payload_bits
    payload_x = float(sol.bits[-1] * items.z[-1])
    obj = xi * o1 + delta_cost * o2 + eps * payload
    return PartitionPlan(
        p=p, bits_w=sol.bits[:-1], bits_x=float(sol.bits[-1]),
        objective=float(obj), psi_total=sol.psi_total, payload_bits=payload,
        breakdown={"compute_local": xi * o1, "compute_server": delta_cost * o2,
                   "payload": eps * payload},
        payload_w_bits=payload - payload_x, payload_x_bits=payload_x)


def solve_joint(layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                layer_o, xi, delta_cost, eps, psi_budget,
                allow_full_offload: bool = True,
                b_min=2.0, b_max=16.0, input_z: float = 0.0):
    """Enumerate partition points (Alg. 2 step 2–5), closed-form bits at
    each, return (best plan, all plans)."""
    L = len(layer_o)
    o_cum = np.cumsum(layer_o)
    o_total = float(o_cum[-1])
    plans = []
    start = 0 if allow_full_offload else 1
    for p in range(start, L + 1):
        plans.append(plan_for_partition(
            p, layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
            o_cum, o_total, xi, delta_cost, eps, psi_budget, b_min, b_max,
            input_z=input_z))
    best = min(plans, key=lambda pl: pl.objective)
    return best, plans


# ---------------------------------------------------------------------------
# Offline pattern store (Alg. 1) + online lookup (Alg. 2).

@dataclasses.dataclass
class OfflineStore:
    """{(accuracy_level, p) -> PartitionPlan} plus the per-level budgets."""
    levels: Sequence[float]
    plans: dict                 # (a, p) -> PartitionPlan
    budgets: dict               # a -> Delta

    def lookup(self, a: float, objective_fn) -> PartitionPlan:
        """Alg. 2: pick the largest tabulated level <= a, then the partition
        point minimizing the runtime objective (which may differ from the
        offline objective because the channel/device changed)."""
        feas = [lv for lv in self.levels if lv <= a]
        a_star = max(feas) if feas else min(self.levels)
        cands = [pl for (lv, _), pl in self.plans.items() if lv == a_star]
        return min(cands, key=objective_fn)


def build_offline_store(levels, budgets, layer_z_w, layer_z_x, layer_s_w,
                        layer_s_x, layer_rho, layer_o, xi, delta_cost, eps,
                        b_min=2.0, b_max=16.0, input_z: float = 0.0) -> OfflineStore:
    o_cum = np.cumsum(layer_o)
    o_total = float(o_cum[-1])
    plans = {}
    for a in levels:
        for p in range(0, len(layer_o) + 1):
            plans[(a, p)] = plan_for_partition(
                p, layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                o_cum, o_total, xi, delta_cost, eps, budgets[a], b_min, b_max,
                input_z=input_z)
    return OfflineStore(levels=list(levels), plans=plans, budgets=dict(budgets))
