"""Closed-form QPART optimizer (paper §IV, Eq. 23–40).

Problem (per partition point p, Eq. 28 with the segment indices fixed —
the paper's Eq. 23 sums over l>=p but its own system description, Eq. 14
and Alg. 1 quantize the FIRST segment l=1..p; we implement the latter and
note the index typo in DESIGN.md):

    min_b   xi*O1(p) + delta*O2(p) + eps*( b_x * z_x(p) + sum_{l<=p} b_l z_l^w )
    s.t.    s_x(p) e^{-ln4 b_x}/rho_p + sum_{l<=p} s_l e^{-ln4 b_l}/rho_l <= Delta

KKT stationarity (Eq. 38) gives, for every quantized item i:

    eps * z_i = lambda * ln4 * (s_i/rho_i) * e^{-ln4 b_i}
    =>  z_i * rho_i / (s_i e^{-ln4 b_i}) = lambda * ln4 / eps = const   (Eq. 39)

i.e. equalized marginal payload-per-noise (water-filling). With the
constraint active, lambda has the closed form

    sum_i eps*z_i / (lambda ln4) = Delta   =>   lambda = eps * sum_i z_i / (Delta ln4)

and  b_i = log4( s_i ln4 lambda / (eps z_i rho_i) ). Items whose optimal
bit-width falls outside [b_min, b_max] are clamped and the multiplier is
re-solved on the active set (standard water-filling iteration; at most
n_items rounds).

Two execution forms of the same math (DESIGN.md §2):

  * ``waterfill_bits``       — scalar reference, one partition point.
  * ``waterfill_bits_batch`` — all partition points of an accuracy level
    as one (L, L+1) masked-matrix program: row r holds the ragged item
    set of partition p=r+1 (weights 1..p + the cut activation) and the
    active-set clamping iterates batched across the p axis. This is what
    ``build_offline_store`` / ``solve_joint`` run by default, turning
    Alg. 1 from O(levels × L) separate Python solves into O(levels)
    array programs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np

LN4 = math.log(4.0)


@dataclasses.dataclass
class SegmentItems:
    """Quantizable items of the device segment at partition p: the p weight
    tensors followed by the cut activation (the paper's z vector)."""
    z: np.ndarray        # payload sizes (elements)
    s: np.ndarray        # noise scales at output
    rho: np.ndarray      # robustness parameters


@dataclasses.dataclass
class BitSolution:
    bits: np.ndarray          # continuous optimal bit-widths, item-ordered
    lam: float                # KKT multiplier
    psi_total: float          # achieved constraint value
    payload_bits: float       # sum b_i z_i  (+ activation term)


def waterfill_bits(items: SegmentItems, delta: float,
                   b_min: float = 2.0, b_max: float = 16.0) -> BitSolution:
    """Equal-marginal closed form with active-set clamping (scalar
    reference; the batched twin is ``waterfill_bits_batch``)."""
    z = np.asarray(items.z, dtype=np.float64)
    s = np.asarray(items.s, dtype=np.float64)
    rho = np.asarray(items.rho, dtype=np.float64)
    n = len(z)
    assert len(s) == n and len(rho) == n and delta > 0

    free = np.ones(n, dtype=bool)
    bits = np.zeros(n)
    budget = delta
    # lam stays +inf when the budget is infeasible before the first
    # multiplier solve (everything clamps to b_max immediately)
    lam = math.inf
    for _ in range(n + 1):
        if not free.any():
            break
        # noise contributed by clamped items
        clamped_noise = np.sum((s[~free] / rho[~free]) * np.exp(-LN4 * bits[~free]))
        rem = budget - clamped_noise
        if rem <= 0:
            # infeasible at current clamps: push everything to b_max
            bits[free] = b_max
            free[:] = False
            break
        lam = np.sum(z[free]) / (rem * LN4)          # eps cancels in bits
        with np.errstate(divide="ignore"):
            b_free = np.log(s[free] * LN4 * lam / (z[free] * rho[free])) / LN4
        lo, hi = b_free < b_min, b_free > b_max
        newly = np.zeros(n, dtype=bool)
        newly[np.where(free)[0][lo]] = True
        bits[np.where(free)[0][lo]] = b_min
        newly2 = np.zeros(n, dtype=bool)
        newly2[np.where(free)[0][hi]] = True
        bits[np.where(free)[0][hi]] = b_max
        if not (lo.any() or hi.any()):
            bits[free] = b_free
            free[:] = False
            break
        free &= ~(newly | newly2)
    psi = float(np.sum((s / rho) * np.exp(-LN4 * bits)))
    payload = float(np.sum(bits * z))
    return BitSolution(bits=bits, lam=float(lam) if n else 0.0,
                       psi_total=psi, payload_bits=payload)


def _waterfill_invariants(z, s, rho, valid):
    """Per-item loop invariants of the batched solve: masked payloads,
    noise-over-robustness, and the additive log term of Eq. 39
    (b_i = log4(lambda) + C_i on the free set)."""
    z = np.where(valid, np.asarray(z, np.float64), 1.0)
    s = np.where(valid, np.asarray(s, np.float64), 1.0)
    rho = np.where(valid, np.asarray(rho, np.float64), 1.0)
    sr = s / rho
    with np.errstate(divide="ignore", invalid="ignore"):
        c_item = np.log(s * LN4 / (z * rho)) / LN4
    return z, sr, c_item


def waterfill_bits_batch(z, s, rho, valid, delta,
                         b_min: float = 2.0, b_max: float = 16.0,
                         _tile: int = 1):
    """R independent water-filling problems in one vectorized pass.

    ``z``, ``s``, ``rho`` are (R, I) matrices; ``valid`` (R, I) masks the
    ragged item sets; ``delta`` is a scalar or (R,) budget vector. Entries
    outside ``valid`` are ignored (they may hold arbitrary placeholders).
    ``_tile=G`` solves the SAME item matrices under G stacked budget
    groups (delta of length G*R, group-major) while computing the
    transcendental invariants only once on the base — the Alg. 1 case
    where every accuracy level shares the layer profile.

    Returns ``(bits (G*R, I), lam, psi, payload)`` matching
    ``waterfill_bits`` row-by-row to float precision: the active-set
    trajectory (multiplier solve, lo/hi clamping, infeasibility bail-out)
    is replicated per row, just batched across rows (DESIGN.md §2).
    """
    valid = np.asarray(valid, bool)
    z, sr, c_item = _waterfill_invariants(z, s, rho, valid)
    if _tile > 1:
        z, sr, c_item, valid = (np.tile(m, (_tile, 1))
                                for m in (z, sr, c_item, valid))
    R, I = z.shape
    deltas = np.broadcast_to(np.asarray(delta, np.float64), (R,)).copy()
    assert np.all(deltas > 0)
    # a clamped item's noise is its s/rho times a CONSTANT factor
    # (e^{-ln4 b_min} or e^{-ln4 b_max}), so the backlog accumulates
    # incrementally — no per-iteration exp/log over the full matrix
    e_min, e_max = math.exp(-LN4 * b_min), math.exp(-LN4 * b_max)

    out_bits = np.zeros((R, I))
    out_lam = np.full(R, np.inf)
    # compact working set: rows leave it (and are emitted to out_*) as
    # soon as they converge, so late clamp rounds — where only a handful
    # of tight-budget rows remain — run on tiny arrays
    idx = np.flatnonzero(valid.any(axis=1))
    if len(idx) == R:       # common case: no empty rows, skip the gather
        zc, src, cc = z, sr, c_item
        free = valid.copy()
    else:
        zc, src, cc, deltas = z[idx], sr[idx], c_item[idx], deltas[idx]
        free = valid[idx].copy()
    bits = np.zeros((len(idx), I))
    lam = np.full(len(idx), np.inf)
    clamped_noise = np.zeros(len(idx))
    for _ in range(I + 1):
        alive = free.any(axis=1)
        if not alive.all():
            done_rows = ~alive
            out_bits[idx[done_rows]] = bits[done_rows]
            out_lam[idx[done_rows]] = lam[done_rows]
            idx = idx[alive]
            zc, src, cc = zc[alive], src[alive], cc[alive]
            deltas, free, bits = deltas[alive], free[alive], bits[alive]
            lam, clamped_noise = lam[alive], clamped_noise[alive]
        if not len(idx):
            break
        rem = deltas - clamped_noise
        infeas = rem <= 0.0
        if infeas.any():
            bits = np.where(free & infeas[:, None], b_max, bits)
            free &= ~infeas[:, None]
        act = ~infeas
        zsum = np.where(free, zc, 0.0).sum(axis=1)
        with np.errstate(divide="ignore", invalid="ignore"):
            lam_r = zsum / (rem * LN4)
            b_cand = (np.log(lam_r) / LN4)[:, None] + cc
        lam = np.where(act, lam_r, lam)
        lo = free & act[:, None] & (b_cand < b_min)
        hi = free & act[:, None] & (b_cand > b_max)
        if lo.any() or hi.any():
            bits = np.where(lo, b_min, np.where(hi, b_max, bits))
            clamped_noise = clamped_noise \
                + np.where(lo, src, 0.0).sum(axis=1) * e_min \
                + np.where(hi, src, 0.0).sum(axis=1) * e_max
            done = act & ~(lo | hi).any(axis=1)
        else:
            done = act
        bits = np.where(free & done[:, None], b_cand, bits)
        free &= ~(lo | hi | done[:, None])
    if len(idx):                                    # safety net: emit rest
        out_bits[idx] = bits
        out_lam[idx] = lam
    # psi over the valid entries only (exp is the dominant cost here)
    row_idx, col_idx = np.nonzero(valid)
    noise = sr[row_idx, col_idx] * np.exp(-LN4 * out_bits[row_idx, col_idx])
    psi = np.bincount(row_idx, weights=noise, minlength=R)
    payload = np.bincount(
        row_idx,
        weights=out_bits[row_idx, col_idx] * z[row_idx, col_idx],
        minlength=R)
    return out_bits, out_lam, psi, payload


# ---------------------------------------------------------------------------
# Joint (b, p) search: the paper's Alg. 1 (offline) + Alg. 2 (online).

@dataclasses.dataclass(slots=True)
class PartitionPlan:
    p: int                     # partition point (device runs layers 1..p)
    bits_w: np.ndarray         # per-layer weight bit-widths (len p)
    bits_x: float              # activation bit-width at the cut
    objective: float           # Eq. 17/23 value
    psi_total: float
    payload_bits: float
    breakdown: dict
    payload_w_bits: float = 0.0   # weight share of the wire (Eq. 14 Z_w)
    payload_x_bits: float = 0.0   # activation share (Z_x) — all that is
                                  # left when the device cached the segment
    device_memory_bytes: float = 0.0   # quantized-segment footprint at the
                                       # DEPLOYED (ceil-rounded) bit-widths —
                                       # what DeviceProfile.memory_bytes is
                                       # checked against at plan time


def _byte_rows(layer_act_bytes, layer_w_bytes16):
    """The canonical byte-term rows (``cost_model.byte_term_rows``) for
    the optional memory-roofline objective terms — imported lazily so
    this module keeps no import-time dependency on the cost model."""
    from repro.core.cost_model import byte_term_rows
    return byte_term_rows(layer_act_bytes, layer_w_bytes16)


def plan_for_partition(p: int, layer_z_w, layer_z_x, layer_s_w, layer_s_x,
                       layer_rho, o_cum, o_total, xi, delta_cost, eps,
                       psi_budget, b_min=2.0, b_max=16.0,
                       input_z: float = 0.0,
                       c_dev_bytes: float = 0.0, c_srv_bytes: float = 0.0,
                       ab_cum=None, srv_byte_row=None) -> PartitionPlan:
    """Optimal bits for a fixed partition point p (1-indexed; p=0 means the
    whole model runs on the server: the device uploads the raw input at
    full precision and nothing is quantized). With nonzero
    ``c_dev_bytes``/``c_srv_bytes`` (a roofline/calibrated provider's
    offline coefficients) the objective additionally prices memory
    traffic: the deployed quantized segment + activations on the device,
    the bf16 tail on the server (rows from ``_byte_rows``)."""
    price_bytes = (c_dev_bytes != 0.0 or c_srv_bytes != 0.0) \
        and ab_cum is not None
    if p == 0:
        o1, o2 = 0.0, o_total
        obj = xi * o1 + delta_cost * o2 + eps * 32.0 * input_z
        breakdown = {"compute_local": 0.0,
                     "compute_server": delta_cost * o2,
                     "payload": eps * 32.0 * input_z}
        if price_bytes:
            breakdown["memory_device"] = 0.0
            breakdown["memory_server"] = c_srv_bytes * srv_byte_row[0]
            obj = obj + breakdown["memory_server"]
        return PartitionPlan(0, np.zeros(0), 32.0, float(obj), 0.0,
                             32.0 * input_z, breakdown,
                             payload_w_bits=0.0,
                             payload_x_bits=32.0 * input_z)
    items = SegmentItems(
        z=np.array(list(layer_z_w[:p]) + [layer_z_x[p - 1]], dtype=np.float64),
        s=np.array(list(layer_s_w[:p]) + [layer_s_x[p - 1]], dtype=np.float64),
        rho=np.array(list(layer_rho[:p]) + [layer_rho[p - 1]], dtype=np.float64),
    )
    sol = waterfill_bits(items, psi_budget, b_min, b_max)
    o1 = o_cum[p - 1]
    o2 = o_total - o1
    payload = sol.payload_bits
    payload_x = float(sol.bits[-1] * items.z[-1])
    obj = xi * o1 + delta_cost * o2 + eps * payload
    mem = float(np.sum(np.clip(np.ceil(sol.bits[:-1]), 2, 16)
                       * items.z[:-1]) / 8.0)
    breakdown = {"compute_local": xi * o1, "compute_server": delta_cost * o2,
                 "payload": eps * payload}
    if price_bytes:
        breakdown["memory_device"] = c_dev_bytes * (mem + ab_cum[p])
        breakdown["memory_server"] = c_srv_bytes * srv_byte_row[p]
        obj = obj + breakdown["memory_device"] + breakdown["memory_server"]
    return PartitionPlan(
        p=p, bits_w=sol.bits[:-1], bits_x=float(sol.bits[-1]),
        objective=float(obj), psi_total=sol.psi_total, payload_bits=payload,
        breakdown=breakdown,
        payload_w_bits=payload - payload_x, payload_x_bits=payload_x,
        device_memory_bytes=mem)


def _segment_matrices(layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho):
    """(L, L+1) item matrices for all partitions p=1..L at once: row r is
    partition p=r+1, columns 0..L-1 the weight items (valid for j <= r),
    column L the cut activation at layer p."""
    z_w = np.asarray(layer_z_w, np.float64)
    z_x = np.asarray(layer_z_x, np.float64)
    s_w = np.asarray(layer_s_w, np.float64)
    s_x = np.asarray(layer_s_x, np.float64)
    rho_l = np.asarray(layer_rho, np.float64)
    L = len(z_w)
    valid = np.zeros((L, L + 1), bool)
    valid[:, :L] = np.tril(np.ones((L, L), bool))
    valid[:, L] = True
    z = np.ones((L, L + 1))
    s = np.ones((L, L + 1))
    rho = np.ones((L, L + 1))
    z[:, :L], z[:, L] = z_w[None, :], z_x
    s[:, :L], s[:, L] = s_w[None, :], s_x
    rho[:, :L], rho[:, L] = rho_l[None, :], rho_l
    return z, s, rho, valid


def _plans_from_rows(bits, psi, payload, layer_z_w, layer_z_x, o_cum,
                     o_total, xi, delta_cost, eps,
                     c_dev_bytes: float = 0.0, c_srv_bytes: float = 0.0,
                     ab_cum=None, srv_byte_row=None) -> List[PartitionPlan]:
    """Materialize PartitionPlans for p=1..L from one batched solution
    block (row r = partition p=r+1)."""
    L = bits.shape[0]
    z_w = np.asarray(layer_z_w, np.float64)
    z_x = np.asarray(layer_z_x, np.float64)
    o_cum = np.asarray(o_cum, np.float64)
    payload_x = bits[:, L] * z_x
    o1 = o_cum
    o2 = o_total - o1
    obj = xi * o1 + delta_cost * o2 + eps * payload
    # deployed (ceil-rounded) segment footprint, weight columns 0..r only
    tril = np.tril(np.ones((L, L), bool))
    mem = np.where(tril, np.clip(np.ceil(bits[:, :L]), 2, 16) * z_w[None, :],
                   0.0).sum(axis=1) / 8.0
    price_bytes = (c_dev_bytes != 0.0 or c_srv_bytes != 0.0) \
        and ab_cum is not None
    if price_bytes:
        mem_dev = c_dev_bytes * (mem + ab_cum[1:])
        mem_srv = c_srv_bytes * srv_byte_row[1:]
        obj = obj + mem_dev + mem_srv
        mem_dev_l, mem_srv_l = mem_dev.tolist(), mem_srv.tolist()
    # bulk scalar extraction (tolist) beats per-element numpy-scalar float()
    bits_x_l = bits[:, L].tolist()
    obj_l, psi_l, pay_l = obj.tolist(), psi.tolist(), payload.tolist()
    pay_x_l = payload_x.tolist()
    loc_l, srv_l = (xi * o1).tolist(), (delta_cost * o2).tolist()
    eps_pay_l = (eps * payload).tolist()
    mem_l = mem.tolist()
    plans = []
    for r in range(L):
        p = r + 1
        breakdown = {"compute_local": loc_l[r],
                     "compute_server": srv_l[r],
                     "payload": eps_pay_l[r]}
        if price_bytes:
            breakdown["memory_device"] = mem_dev_l[r]
            breakdown["memory_server"] = mem_srv_l[r]
        plans.append(PartitionPlan(
            p=p, bits_w=bits[r, :p].copy(), bits_x=bits_x_l[r],
            objective=obj_l[r], psi_total=psi_l[r],
            payload_bits=pay_l[r],
            breakdown=breakdown,
            payload_w_bits=pay_l[r] - pay_x_l[r],
            payload_x_bits=pay_x_l[r],
            device_memory_bytes=mem_l[r]))
    return plans


def plan_all_partitions(layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                        o_cum, o_total, xi, delta_cost, eps, psi_budget,
                        b_min=2.0, b_max=16.0,
                        input_z: float = 0.0,
                        c_dev_bytes: float = 0.0, c_srv_bytes: float = 0.0,
                        ab_cum=None, srv_byte_row=None) -> List[PartitionPlan]:
    """All partition points p=0..L of one accuracy level as a single
    vectorized solve — the hot path of Alg. 1 (DESIGN.md §2). Plan-for-plan
    equal to ``[plan_for_partition(p, ...) for p in 0..L]``."""
    L = len(layer_z_w)
    plans = [plan_for_partition(0, layer_z_w, layer_z_x, layer_s_w,
                                layer_s_x, layer_rho, o_cum, o_total, xi,
                                delta_cost, eps, psi_budget, b_min, b_max,
                                input_z=input_z, c_dev_bytes=c_dev_bytes,
                                c_srv_bytes=c_srv_bytes, ab_cum=ab_cum,
                                srv_byte_row=srv_byte_row)]
    if L == 0:
        return plans
    z, s, rho, valid = _segment_matrices(layer_z_w, layer_z_x, layer_s_w,
                                         layer_s_x, layer_rho)
    bits, _lam, psi, payload = waterfill_bits_batch(
        z, s, rho, valid, psi_budget, b_min, b_max)
    plans += _plans_from_rows(bits, psi, payload, layer_z_w, layer_z_x,
                              o_cum, o_total, xi, delta_cost, eps,
                              c_dev_bytes=c_dev_bytes,
                              c_srv_bytes=c_srv_bytes, ab_cum=ab_cum,
                              srv_byte_row=srv_byte_row)
    return plans


def solve_joint(layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                layer_o, xi, delta_cost, eps, psi_budget,
                allow_full_offload: bool = True,
                b_min=2.0, b_max=16.0, input_z: float = 0.0,
                vectorized: bool = True,
                c_dev_bytes: float = 0.0, c_srv_bytes: float = 0.0,
                layer_act_bytes=None, layer_w_bytes16=None):
    """Enumerate partition points (Alg. 2 step 2–5), closed-form bits at
    each, return (best plan, all plans)."""
    L = len(layer_o)
    o_cum = np.cumsum(layer_o)
    o_total = float(o_cum[-1])
    ab_cum = srv_byte_row = None
    if layer_act_bytes is not None and layer_w_bytes16 is not None:
        ab_cum, srv_byte_row = _byte_rows(layer_act_bytes, layer_w_bytes16)
    if vectorized:
        plans = plan_all_partitions(
            layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho, o_cum,
            o_total, xi, delta_cost, eps, psi_budget, b_min, b_max,
            input_z=input_z, c_dev_bytes=c_dev_bytes,
            c_srv_bytes=c_srv_bytes, ab_cum=ab_cum,
            srv_byte_row=srv_byte_row)
        if not allow_full_offload:
            plans = plans[1:]
    else:
        plans = []
        start = 0 if allow_full_offload else 1
        for p in range(start, L + 1):
            plans.append(plan_for_partition(
                p, layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                o_cum, o_total, xi, delta_cost, eps, psi_budget, b_min, b_max,
                input_z=input_z, c_dev_bytes=c_dev_bytes,
                c_srv_bytes=c_srv_bytes, ab_cum=ab_cum,
                srv_byte_row=srv_byte_row))
    best = min(plans, key=lambda pl: pl.objective)
    return best, plans


# ---------------------------------------------------------------------------
# Offline pattern store (Alg. 1) + online lookup (Alg. 2).

@dataclasses.dataclass
class OfflineStore:
    """{(accuracy_level, p) -> PartitionPlan} plus the per-level budgets."""
    levels: Sequence[float]
    plans: dict                 # (a, p) -> PartitionPlan
    budgets: dict               # a -> Delta

    def __post_init__(self):
        self._level_plans_cache: dict = {}
        self._payload_rows_cache: dict = {}
        self._memory_rows_cache: dict = {}

    # -- fast accessors for the batched online path (DESIGN.md §5) ------
    def level_for(self, a: float) -> float:
        """Alg. 2 step 1: largest tabulated level <= a (min level when
        nothing qualifies)."""
        feas = [lv for lv in self.levels if lv <= a]
        return max(feas) if feas else min(self.levels)

    def level_plans(self, a_star: float) -> List[PartitionPlan]:
        """Candidate plans of one level, ordered by partition point."""
        if a_star not in self._level_plans_cache:
            cands = sorted(((p, pl) for (lv, p), pl in self.plans.items()
                            if lv == a_star), key=lambda t: t[0])
            self._level_plans_cache[a_star] = [pl for _, pl in cands]
        return self._level_plans_cache[a_star]

    def level_payload_rows(self, a_star: float):
        """(payload_bits (P+1,), payload_x_bits (P+1,)) of one level's
        candidates, column c = partition point c. Cached: the batched
        online paths (serve_batch / WorkloadBalancer) gather these rows
        instead of walking plan attributes per request."""
        if a_star not in self._payload_rows_cache:
            cands = self.level_plans(a_star)
            self._payload_rows_cache[a_star] = (
                np.array([pl.payload_bits for pl in cands]),
                np.array([pl.payload_x_bits for pl in cands]))
        return self._payload_rows_cache[a_star]

    def level_memory_rows(self, a_star: float) -> np.ndarray:
        """(P+1,) deployed device-segment memory (bytes) of one level's
        candidates — what the plan-time DeviceProfile.memory_bytes check
        compares against (p=0 holds no weights on the device)."""
        if a_star not in self._memory_rows_cache:
            self._memory_rows_cache[a_star] = np.array(
                [pl.device_memory_bytes for pl in self.level_plans(a_star)])
        return self._memory_rows_cache[a_star]

    def lookup(self, a: float, objective_fn,
               feasible_fn=None) -> PartitionPlan:
        """Alg. 2: pick the largest tabulated level <= a, then the partition
        point minimizing the runtime objective (which may differ from the
        offline objective because the channel/device changed).
        ``feasible_fn(plan) -> bool`` drops candidates before the argmin
        (e.g. quantized segments that exceed the device memory); the
        first-minimum tie-break over the surviving candidates matches the
        masked-argmin of the batched window path."""
        cands = self.level_plans(self.level_for(a))
        if feasible_fn is not None:
            cands = [pl for pl in cands if feasible_fn(pl)]
            if not cands:
                raise ValueError("no feasible partition candidate")
        return min(cands, key=objective_fn)


def build_offline_store(levels, budgets, layer_z_w, layer_z_x, layer_s_w,
                        layer_s_x, layer_rho, layer_o, xi, delta_cost, eps,
                        b_min=2.0, b_max=16.0, input_z: float = 0.0,
                        vectorized: bool = True,
                        c_dev_bytes: float = 0.0, c_srv_bytes: float = 0.0,
                        layer_act_bytes=None,
                        layer_w_bytes16=None) -> OfflineStore:
    """Alg. 1 as ONE stacked array program: the (level, partition) grid
    becomes a (levels*L, L+1) batched water-filling solve — every level's
    item matrices are identical, only the budget row-vector differs
    (``vectorized=False`` keeps the O(levels × L) scalar reference the
    equivalence tests and benchmarks compare against). The optional
    ``c_dev_bytes``/``c_srv_bytes`` coefficients (a provider's
    ``offline_coeffs``) add the memory-traffic terms to the stored
    objectives; the water-filling bits are unaffected (the noise budget
    constraint does not price time)."""
    o_cum = np.cumsum(layer_o)
    o_total = float(o_cum[-1])
    L = len(layer_o)
    ab_cum = srv_byte_row = None
    if layer_act_bytes is not None and layer_w_bytes16 is not None:
        ab_cum, srv_byte_row = _byte_rows(layer_act_bytes, layer_w_bytes16)
    byte_kw = dict(c_dev_bytes=c_dev_bytes, c_srv_bytes=c_srv_bytes,
                   ab_cum=ab_cum, srv_byte_row=srv_byte_row)
    plans = {}
    if vectorized and L > 0:
        z, s, rho, valid = _segment_matrices(layer_z_w, layer_z_x, layer_s_w,
                                             layer_s_x, layer_rho)
        A = len(levels)
        deltas = np.repeat([budgets[a] for a in levels], L)
        bits, _lam, psi, payload = waterfill_bits_batch(
            z, s, rho, valid, deltas, b_min, b_max, _tile=A)
        for i, a in enumerate(levels):
            plans[(a, 0)] = plan_for_partition(
                0, layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                o_cum, o_total, xi, delta_cost, eps, budgets[a],
                b_min, b_max, input_z=input_z, **byte_kw)
            rows = slice(i * L, (i + 1) * L)
            for p, plan in enumerate(_plans_from_rows(
                    bits[rows], psi[rows], payload[rows], layer_z_w,
                    layer_z_x, o_cum, o_total, xi, delta_cost, eps,
                    **byte_kw), start=1):
                plans[(a, p)] = plan
    else:
        for a in levels:
            for p in range(0, L + 1):
                plans[(a, p)] = plan_for_partition(
                    p, layer_z_w, layer_z_x, layer_s_w, layer_s_x, layer_rho,
                    o_cum, o_total, xi, delta_cost, eps, budgets[a],
                    b_min, b_max, input_z=input_z, **byte_kw)
    return OfflineStore(levels=list(levels), plans=plans, budgets=dict(budgets))
