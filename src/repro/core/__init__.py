from repro.core.cost_model import (  # noqa: F401
    Channel, CostBreakdown, DeviceProfile, LayerSpec, ObjectiveWeights,
    ServerProfile, cost_breakdown, classifier_layer_specs, delta_coeff,
    eps_coeff, layer_specs_for, transformer_layer_specs, xi_coeff,
)
from repro.core.noise import (  # noqa: F401
    LayerNoiseProfile, NoiseCalibration, adversarial_noise_energy,
    calibrate_delta, output_noise_energy,
)
from repro.core.partition import DeviceSegment, split_classifier  # noqa: F401
from repro.core.quantizer import (  # noqa: F401
    analytic_noise_scale, dequantize, fake_quant, payload_bits,
    quant_noise_energy, quantize, quantize_tree, round_bits,
)
from repro.core.solver import (  # noqa: F401
    BitSolution, OfflineStore, PartitionPlan, SegmentItems,
    build_offline_store, plan_for_partition, solve_joint, waterfill_bits,
)
