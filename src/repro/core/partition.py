"""Model-segment splitting: materialize the (quantized) device segment and
the server segment at a partition point.

Two views of the same abstraction (DESIGN.md §3):
  * edge view  — classifier params split into python lists; the device list
                 is fake-quantized at the plan's per-layer bit-widths;
  * pod view   — a mesh-sharding split for transformers where the "device"
                 maps to a mesh slice (used by the serving engine).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.quantizer import fake_quant, payload_bits, round_bits
from repro.core.solver import PartitionPlan


@dataclasses.dataclass
class DeviceSegment:
    params: list                 # quantized layer params (layers 1..p)
    bits_w: np.ndarray
    bits_x: int
    payload_bits: float          # exact wire size (Eq. 14)


def split_blocks(layer_params: List, plan: PartitionPlan,
                 layer_specs) -> DeviceSegment:
    """Split + quantize a per-layer parameter list (classifier layer
    dicts, transformer block pytrees — any pytree per layer) at plan.p.
    Only the device segment is materialized; the server side keeps the
    caller's full-precision params."""
    import jax
    p = plan.p
    bits_int = np.asarray(round_bits(plan.bits_w)) if p else np.zeros(0, int)
    dev_params = []
    wire = 0.0
    for i in range(p):
        b = int(bits_int[i])
        dev_params.append(jax.tree.map(lambda t, b=b: fake_quant(t, b),
                                       layer_params[i]))
        n = sum(int(np.prod(v.shape))
                for v in jax.tree.leaves(layer_params[i]))
        wire += float(payload_bits(n, b))
    bits_x = int(round_bits(np.array([plan.bits_x]))[0]) if p else 32
    # activation payload counted when the device sends the cut activation
    wire_x = float(payload_bits(int(layer_specs[p - 1].z_x), bits_x)) if p else 0.0
    return DeviceSegment(dev_params, bits_int, bits_x, wire + wire_x)


def split_classifier(params: List[dict], plan: PartitionPlan,
                     layer_specs) -> tuple[DeviceSegment, List[dict]]:
    """Split + quantize a classifier at plan.p. Returns (device, server)."""
    seg = split_blocks(params, plan, layer_specs)
    return seg, list(params[plan.p:])


def segment_memory_bytes(seg: DeviceSegment) -> float:
    """Device memory footprint of the quantized segment (packed codes)."""
    import jax
    total = 0.0
    for i, lp in enumerate(seg.params):
        n = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(lp))
        total += n * int(seg.bits_w[i]) / 8.0
    return total


def plan_memory_bytes(plan: PartitionPlan, layer_specs) -> float:
    """Analytic device memory (bytes) a plan's quantized segment occupies
    at the deployed (ceil-rounded) bit-widths — the quantity serve-time
    admission checks against ``DeviceProfile.memory_bytes``. Equals
    ``plan.device_memory_bytes`` when the plan came out of the solver;
    provided for plans built elsewhere (baseline stubs, tests)."""
    if plan.p == 0:
        return 0.0
    bits = np.clip(np.ceil(np.asarray(plan.bits_w, np.float64)), 2, 16)
    z_w = np.array([sp.z_w for sp in layer_specs[:plan.p]], np.float64)
    return float(np.sum(bits * z_w) / 8.0)
