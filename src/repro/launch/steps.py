"""Step functions + input specs for every (architecture x input-shape)
combination — what the dry-run lowers and the launchers execute.

Shape kinds map to steps (DESIGN.md §4, decode semantics):
  train_4k    -> train_step   (fwd + bwd + AdamW update)
  prefill_32k -> prefill_step (full-sequence forward + cache build)
  decode_*    -> serve_step   (ONE token against a seq_len cache)

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct and
shardable, no device allocation — for every model input of the step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig, for_shape
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step as _make_train_step


# ---------------------------------------------------------------------------
# Step builders (cfg baked in via closure; all-jit-able).

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    remat: bool = True, accum_steps: int = 1) -> Callable:
    return _make_train_step(cfg, opt_cfg or AdamWConfig(), remat=remat,
                            accum_steps=accum_steps)


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        logits, caches, aux = T.prefill(
            params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), max_len=max_len)
        return logits, caches

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, token, caches, pos):
        return T.decode_step(params, cfg, token, caches, pos)

    return serve_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins.

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg: ModelConfig, dtype=None):
    """dtype: cast float params (serving runs bf16/int8-quantized weights;
    training keeps f32 masters)."""
    sds = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
    if dtype is not None:
        sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), sds)
    return sds


def opt_specs(params_sds):
    return jax.eval_shape(init_opt_state, params_sds)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len, dtype))


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Training / prefill batch: tokens for text archs, frontend-stub
    embeddings (+ M-RoPE position triples) for audio / VLM backbones."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {}
    if cfg.frontend != "none":
        specs["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
    if cfg.rope == "mrope":
        specs["positions"] = _sds((3, b, s), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = _sds((b, s), jnp.int32)
    return specs


@dataclasses.dataclass
class StepSpec:
    """Everything the dry-run needs for one (arch x shape): the step
    callable, example-arg SDS tree, and the donate/output structure."""
    kind: str
    fn: Callable
    args: tuple
    cfg: ModelConfig


def build_step(cfg: ModelConfig, shape: InputShape,
               opt_cfg: AdamWConfig | None = None,
               accum_steps: int = 1, serve_dtype=None,
               serve_quant: int = 0) -> StepSpec:
    cfg = for_shape(cfg, shape)

    def serving_params():
        p = param_specs(cfg, dtype=serve_dtype)
        if serve_quant:
            from repro.core.quantizer import quantize_params_for_serving
            p = jax.eval_shape(
                lambda pp: quantize_params_for_serving(pp, serve_quant), p)
        return p
    if shape.kind == "train":
        fn = make_train_step(cfg, opt_cfg, accum_steps=accum_steps)
        p = param_specs(cfg)
        o = opt_specs(p)
        batch = batch_specs(cfg, shape)
        return StepSpec("train", fn, (p, o, batch), cfg)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, max_len=shape.seq_len)
        p = serving_params()
        batch = batch_specs(cfg, shape)
        return StepSpec("prefill", fn, (p, batch), cfg)
    # decode: ONE token against a seq_len cache
    fn = make_serve_step(cfg)
    p = serving_params()
    caches = cache_specs(cfg, shape.global_batch, shape.seq_len)
    token = _sds((shape.global_batch, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return StepSpec("decode", fn, (p, token, caches, pos), cfg)
