"""Training launcher: run a (reduced or full) architecture on the local
mesh with the same sharded step functions the dry-run lowers.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \\
      --reduced --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.data.pipeline import TokenStream, TokenStreamConfig
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1))
    step_fn = make_train_step(cfg, opt_cfg, remat=args.remat)

    key = jax.random.key(0)
    params = T.init_params(key, cfg)
    opt_state = init_opt_state(params)
    p_specs = shard_lib.param_pspecs(cfg, params, mesh=mesh)
    o_specs = shard_lib.opt_pspecs(p_specs)
    b_specs = shard_lib.batch_pspecs(mesh, args.batch, has_embeds=False,
                                     has_positions=False)
    to_sh = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
    with mesh:
        params = jax.device_put(params, to_sh(p_specs))
        opt_state = jax.device_put(opt_state, to_sh(o_specs))
        jstep = jax.jit(step_fn, in_shardings=(to_sh(p_specs), to_sh(o_specs),
                                               to_sh(b_specs)),
                        out_shardings=(to_sh(p_specs), to_sh(o_specs), None),
                        donate_argnums=(0, 1))
        stream = TokenStream(TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
            batch_size=args.batch))
        t0 = time.time()
        losses = []
        for step, batch in enumerate(stream.batches()):
            if step >= args.steps:
                break
            params, opt_state, metrics = jstep(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"xent {float(metrics['xent']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({dt:.1f}s)")
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, opt_state, step=args.steps,
                        metadata={"arch": args.arch})
        print("checkpoint saved:", args.checkpoint)
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
