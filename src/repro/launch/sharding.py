"""Sharding rules: parameter / optimizer / cache / batch PartitionSpecs.

Layout (DESIGN.md §5) — activations are sharded over ``data`` on batch and
replicated over ``model``; weights follow Megatron column->row TP pairs so
each block needs exactly one psum on its output projection:

  embed          (V, D)            -> (model, None)        vocab-sharded
  lm_head        (D, V)            -> (None, model)        logits vocab-sharded
  attn wq/wk/wv  (P, D, H*hd)      -> (None, None, model)  head-sharded
  attn wo        (P, H*hd, D)      -> (None, model, None)  row-parallel psum
  mlp  gate/up   (P, D, F)         -> (None, None, model)
  mlp  down      (P, F, D)         -> (None, model, None)
  moe  experts   (P, E, D, F)      -> (None, model, None, None)  expert-parallel
  ssm  w_z/w_x   (P, D, di)        -> (None, None, model)  head-sharded
  ssm  w_out     (P, di, D)        -> (None, model, None)
  ssm  B/C/dt    small, shared across heads -> replicated
  norms / scalars                  -> replicated

``P`` is the stacked num_periods axis (scan over depth), never sharded.
Optimizer mu/nu mirror the parameter specs; ZeRO-style sharding of the
optimizer over ``data`` is a §Perf hillclimb (see fsdp=True).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS


def data_axes(mesh) -> tuple:
    """Axes that carry the global batch (pod included when present)."""
    if POD_AXIS in mesh.axis_names:
        return (POD_AXIS, DATA_AXIS)
    return (DATA_AXIS,)


def _leaf_spec(path, leaf, kv_sharded: bool) -> P:
    keys = [k.key for k in path if hasattr(k, "key")]
    name = keys[-1]
    ndim = leaf.ndim
    M = MODEL_AXIS
    in_block = "blocks" in keys
    # int8 serving weights: {codes, scale, mu} under the weight's name —
    # codes shard like the weight itself; per-period scale/mu replicate
    if name in ("codes", "codes_packed"):   # packing is on the LAST dim,
        name = keys[-2]                     # never a sharded one
    elif name in ("scale", "mu") and len(keys) >= 2 and keys[-2] != name:
        from repro.core.quantizer import QUANTIZABLE
        if keys[-2] in QUANTIZABLE:
            return P(*([None] * ndim))

    def stacked(*spec):
        """Params under blocks/ carry the leading num_periods axis."""
        return P(None, *spec) if in_block else P(*spec)

    if name == "embed":
        return P(M, None)
    if name == "lm_head":
        return P(None, M)
    if name in ("scale", "bias"):                 # norms
        return stacked(None)
    # attention (flat padded-head layout, DESIGN.md §5) -------------------
    if name == "wq":                              # (D, H_pad, hd)
        return stacked(None, M, None)
    if name == "wo":                              # (H_pad, hd, D)
        return stacked(M, None, None)
    if name == "bq":                              # (H_pad, hd)
        return stacked(M, None)
    if name in ("wk", "wv"):                      # (D, KV_pad, hd)
        return stacked(None, M, None) if kv_sharded else \
            stacked(None, None, None)
    if name in ("bk", "bv"):                      # (KV_pad, hd)
        return stacked(M, None) if kv_sharded else stacked(None, None)
    if name in ("q_norm", "k_norm"):
        return stacked(None)
    # moe / mlp ---------------------------------------------------------
    if name == "w_router":
        return stacked(None, None)
    if name in ("w_gate", "w_up"):
        if ndim == 4:                             # (P, E, D, F) expert-parallel
            return stacked(M, None, None)
        return stacked(None, M)                   # dense mlp (P, D, F)
    if name == "w_down":
        if ndim == 4:                             # (P, E, F, D)
            return stacked(M, None, None)
        return stacked(M, None)                   # dense mlp (P, F, D)
    # ssm ----------------------------------------------------------------
    if name in ("w_z", "w_x"):
        return stacked(None, M)
    if name in ("w_B", "w_C", "w_dt"):
        return stacked(None, None)
    if name == "conv_wx":
        return stacked(None, M)
    if name == "conv_bx":
        return stacked(M)
    if name in ("conv_wB", "conv_wC"):
        return stacked(None, None)
    if name in ("conv_bB", "conv_bC"):
        return stacked(None)
    if name in ("dt_bias", "A_log", "D"):
        return stacked(None)
    if name == "gate_norm":
        return stacked(M)
    if name == "w_out":
        return stacked(M, None)
    raise ValueError(f"no sharding rule for param {'/'.join(map(str, keys))} "
                     f"with ndim={ndim}")


def _with_fsdp(spec: P, leaf, mesh) -> P:
    """ZeRO-3 flavour: additionally shard the largest unsharded dim over
    ``data`` when it divides evenly (hillclimb candidate, DESIGN.md §5)."""
    ndim = leaf.ndim
    dsize = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    parts = list(spec) + [None] * (ndim - len(spec))
    # pick the largest dim not already sharded
    cand = [(leaf.shape[i], i) for i in range(ndim) if parts[i] is None]
    for size, i in sorted(cand, reverse=True):
        if size % dsize == 0 and size >= dsize:
            parts[i] = data_axes(mesh) if len(data_axes(mesh)) > 1 else DATA_AXIS
            break
    return P(*parts)


def param_pspecs(cfg: ModelConfig, params_shape, *, fsdp: bool = False,
                 mesh=None) -> Any:
    """PartitionSpec tree matching ``transformer.init_params`` output."""
    msize = mesh.shape[MODEL_AXIS] if mesh is not None else 16
    kv_sharded = bool(cfg.num_heads) and cfg.padded_heads()[0] % msize == 0

    def rule(path, leaf):
        spec = _leaf_spec(path, leaf, kv_sharded)
        if fsdp:
            assert mesh is not None
            spec = _with_fsdp(spec, leaf, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_pspecs(param_specs) -> Any:
    """mu / nu mirror the params; the step counter is replicated."""
    return {"mu": param_specs, "nu": param_specs, "step": P()}


def cache_pspecs(cfg: ModelConfig, cache_shape, mesh, batch: int) -> Any:
    """KV / SSM cache specs. Leaves (stacked over periods):
      attn k/v   (P, B, buf, KV, hd) -> (None, data, None, model?, None)
                 (KV sharded only when attn_shard_dim == 'kv'; when the
                  G dim carries TP the small KV cache replicates)
      ssm state  (P, B, H, N, hd)    -> (None, data, model, None, None)
      ssm conv   (P, B, W-1, C)      -> (None, data, None, None)   (packed)
    Batch replicates when it cannot split over data (long_500k B=1)."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    b_ax = daxes if batch % dsize == 0 and batch >= dsize else None
    b_ax = b_ax if b_ax is None or len(daxes) > 1 else DATA_AXIS
    kv_ax = None
    if cfg.num_heads and cfg.padded_heads()[0] % mesh.shape[MODEL_AXIS] == 0:
        kv_ax = MODEL_AXIS

    def rule(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        if name in ("k", "v"):
            if kv_ax is None and leaf.shape[2] % mesh.shape[MODEL_AXIS] == 0:
                # GQA kv-heads don't divide the model axis: shard the
                # SEQUENCE (ring-buffer) dim instead of replicating — the
                # cache dominates decode memory (42.5 GiB/device replicated
                # for qwen3 decode_32k; §Perf pair C). The flash-decode
                # softmax runs distributed over sequence shards (psum of
                # max/sum stats), a tiny collective vs a 16x cache read.
                return P(None, b_ax, MODEL_AXIS, None, None)
            return P(None, b_ax, None, kv_ax, None)
        if name == "state":
            return P(None, b_ax, MODEL_AXIS, None, None)
        if name == "conv":
            return P(None, b_ax, None, None)
        raise ValueError(f"no cache rule for {keys}")

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def batch_pspecs(mesh, batch: int, has_embeds: bool, has_positions: bool) -> dict:
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    b_ax = daxes if batch % dsize == 0 and batch >= dsize else None
    b_ax = b_ax if b_ax is None or len(daxes) > 1 else DATA_AXIS
    specs = {"labels": P(b_ax, None)}
    if has_embeds:
        specs["embeds"] = P(b_ax, None, None)
    else:
        specs["tokens"] = P(b_ax, None)
    if has_positions:
        specs["positions"] = P(None, b_ax, None)
    return specs


def shardings_of(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
