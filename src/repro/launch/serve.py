"""Serving launcher: batched prefill + decode on the local mesh, driving
the same serve_step the decode dry-runs lower. Doubles as the end-to-end
"serve a small model with batched requests" example driver.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \\
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def generate(params, cfg, prompt, max_len: int, gen: int, *,
             temperature: float = 0.0, key=None):
    """Greedy / sampled generation: prefill then decode_step x gen."""
    b, s = prompt.shape
    logits, caches, _ = T.prefill(params, cfg, prompt, max_len=max_len)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]

    jstep = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))
    for i in range(gen - 1):
        logits, caches = jstep(params, tok, caches, jnp.array(s + i, jnp.int32))
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, 0] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, 0:1], -1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", type=int, default=0,
                    help="serve with int-N weights (8 or 4, QPART wire "
                         "format; 0 = full precision)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    key = jax.random.key(0)
    params = T.init_params(key, cfg)
    if args.quant:
        from repro.core.quantizer import quantize_params_for_serving
        params = quantize_params_for_serving(params, args.quant)
        print(f"serving with int{args.quant} block weights")
    p_specs = shard_lib.param_pspecs(cfg, params, mesh=mesh)
    with mesh:
        params = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), p_specs,
            is_leaf=lambda x: isinstance(x, P)))
        prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                    0, cfg.vocab_size, jnp.int32)
        t0 = time.time()
        toks = generate(params, cfg, prompt,
                        max_len=args.prompt_len + args.gen, gen=args.gen,
                        temperature=args.temperature, key=key)
        dt = time.time() - t0
    toks = jax.device_get(toks)
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("first row:", toks[0][:16], "...")
    assert toks.shape == (args.batch, args.gen)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
