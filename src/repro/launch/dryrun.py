import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers AND compiles under the production sharding, and emit
the roofline terms (deliverables e + g).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--fsdp]

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count on first init. Smoke tests / benches never import this
module, so they see the real single CPU device.

(No ``from __future__ import annotations`` here: the XLA_FLAGS assignment
must stay the first statement of the module.)
"""
import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ASSIGNED_ARCHS, INPUT_SHAPES, ModelConfig,
                                for_shape, get_config)
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_production_mesh, mesh_num_chips
from repro.launch.steps import StepSpec, build_step
from repro.roofline.analysis import analyze, model_flops_for, save_record

RECORD_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def step_in_shardings(spec: StepSpec, mesh, shape, *, fsdp: bool = False):
    """in_shardings pytree matching spec.args."""
    cfg = spec.cfg
    p_specs = shard_lib.param_pspecs(cfg, spec.args[0], fsdp=fsdp, mesh=mesh)
    daxes = shard_lib.data_axes(mesh)
    import numpy as np
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    gb = shape.global_batch
    b_ax = daxes if gb % dsize == 0 and gb >= dsize else None
    b_ax = b_ax if b_ax is None or len(daxes) > 1 else daxes[0]

    if spec.kind == "train":
        o_specs = shard_lib.opt_pspecs(p_specs)
        b_specs = shard_lib.batch_pspecs(
            mesh, gb, has_embeds="embeds" in spec.args[2],
            has_positions="positions" in spec.args[2])
        b_specs = {k: b_specs[k] for k in spec.args[2]}
        return (p_specs, o_specs, b_specs)
    if spec.kind == "prefill":
        b_specs = shard_lib.batch_pspecs(
            mesh, gb, has_embeds="embeds" in spec.args[1],
            has_positions="positions" in spec.args[1])
        b_specs = {k: b_specs[k] for k in spec.args[1]}
        return (p_specs, b_specs)
    # decode: (params, token, caches, pos)
    c_specs = shard_lib.cache_pspecs(cfg, spec.args[2], mesh, gb)
    return (p_specs, P(b_ax, None), c_specs, P())


def lower_and_compile(arch: str, shape_name: str, *, multi_pod: bool = False,
                      fsdp: bool = False, accum_steps: int = 1,
                      serve_dtype=None, serve_quant: int = 0,
                      verbose: bool = True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if skip_reason(cfg, shape):
        raise SkipCombo(skip_reason(cfg, shape))
    mesh = make_production_mesh(multi_pod=multi_pod)
    import jax.numpy as jnp
    sd = {None: None, "bf16": jnp.bfloat16, "f32": jnp.float32}[serve_dtype]
    spec = build_step(cfg, shape, accum_steps=accum_steps, serve_dtype=sd,
                      serve_quant=serve_quant)
    in_specs = step_in_shardings(spec, mesh, shape, fsdp=fsdp)
    in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs,
                         is_leaf=lambda x: isinstance(x, P))
    t0 = time.time()
    with mesh:
        lowered = jax.jit(spec.fn, in_shardings=in_sh).lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    roof = analyze(compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
                   chips=mesh_num_chips(mesh),
                   model_flops=model_flops_for(for_shape(cfg, shape), shape))
    if verbose:
        ma = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory/device: args {ma.argument_size_in_bytes/2**30:.2f} GiB"
              f" temp {ma.temp_size_in_bytes/2**30:.2f} GiB"
              f" out {ma.output_size_in_bytes/2**30:.2f} GiB")
        print(f"  HLO: {roof.hlo_gflops:.1f} GFLOP {roof.hlo_gbytes:.1f} GB"
              f" coll {roof.coll_gbytes:.3f} GB -> bottleneck {roof.bottleneck}")
        print(f"  terms: compute {roof.t_compute*1e3:.3f} ms"
              f" memory {roof.t_memory*1e3:.3f} ms"
              f" collective {roof.t_collective*1e3:.3f} ms"
              f" useful-flop-frac {roof.useful_flop_frac}")
    return compiled, roof


class SkipCombo(Exception):
    pass


def skip_reason(cfg: ModelConfig, shape) -> str | None:
    """No combination is skipped: dense archs run long_500k through the
    sliding-window variant (DESIGN.md §4). Kept as an explicit hook so any
    future inapplicable pair is documented, not silently dropped."""
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-style extra sharding over data (perf variant)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatch steps (perf)")
    ap.add_argument("--serve-dtype", choices=["bf16", "f32"], default=None,
                    help="weight dtype for prefill/decode steps (perf)")
    ap.add_argument("--serve-quant", type=int, default=0,
                    help="int-quantize serving weights to N bits (perf)")
    ap.add_argument("--record-dir", default=RECORD_DIR)
    args = ap.parse_args(argv)

    os.makedirs(args.record_dir, exist_ok=True)
    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in combos:
        try:
            compiled, roof = lower_and_compile(
                arch, shape_name, multi_pod=args.multipod, fsdp=args.fsdp,
                accum_steps=args.accum, serve_dtype=args.serve_dtype,
                serve_quant=args.serve_quant)
            tag = "multipod" if args.multipod else "pod"
            tag += "_fsdp" if args.fsdp else ""
            tag += f"_accum{args.accum}" if args.accum > 1 else ""
            tag += f"_{args.serve_dtype}" if args.serve_dtype else ""
            tag += f"_w{args.serve_quant}" if args.serve_quant else ""
            save_record(roof, os.path.join(
                args.record_dir, f"{arch}_{shape_name}_{tag}.json"))
        except SkipCombo as e:
            print(f"[{arch} x {shape_name}] SKIP: {e}")
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"\nall {len(combos)} combos lowered + compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
