"""Production mesh definitions (DESIGN.md §5).

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
pure data parallelism whose gradient all-reduce crosses the inter-pod
links (DCN/ICI depending on deployment; the roofline uses the ICI figure
as the optimistic bound and reports it separately).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS *before* jax initializes).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (one direction)

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod else (DATA_AXIS, MODEL_AXIS)
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1x1 (data, model) mesh slice —
    lets the smoke tests exercise the same sharded step functions."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), (DATA_AXIS, MODEL_AXIS))


def mesh_num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
