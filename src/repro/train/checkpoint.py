"""Checkpointing: pytree -> flat .npz + msgpack metadata. No orbax in the
container; this covers save/restore/resume for the training examples."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np

SEP = "%%"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    metadata: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    meta = {"step": step, **(metadata or {})}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def _restore_into(template, flat: dict):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_checkpoint(path: str, params_template,
                    opt_state_template=None) -> Tuple[Any, Any, dict]:
    flat = dict(np.load(os.path.join(path, "params.npz")))
    params = _restore_into(params_template, flat)
    opt_state = None
    opt_file = os.path.join(path, "opt_state.npz")
    if opt_state_template is not None and os.path.exists(opt_file):
        opt_state = _restore_into(opt_state_template, dict(np.load(opt_file)))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta
