"""Training step: LM cross-entropy + router aux losses, remat-able,
pjit-compatible (the launch layer supplies shardings)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def lm_loss(params, cfg, batch, remat: bool = True):
    """batch: {tokens | embeds, labels[, positions]} — embeds is the
    frontend-stub path (audio/VLM backbones), positions carries M-RoPE
    triples when present."""
    logits, aux = T.forward(params, cfg, batch.get("tokens"),
                            embeds=batch.get("embeds"),
                            positions=batch.get("positions"), remat=remat)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    xent = jnp.mean(logz - gold)
    zloss = 1e-4 * jnp.mean(jnp.square(logz))
    total = xent + zloss
    if cfg.moe is not None:
        total = total + cfg.moe.aux_loss_weight * aux["lb_loss"] \
            + 1e-3 * aux["z_loss"]
    metrics = {"xent": xent, "zloss": zloss,
               "dropped_frac": aux["dropped_frac"]}
    return total, metrics


def make_train_step(cfg, opt_cfg: AdamWConfig, remat: bool = True,
                    accum_steps: int = 1):
    """accum_steps > 1 scans over microbatches (global_batch must divide):
    live activation memory scales with the microbatch while the gradient
    buffer is accumulated in f32 — the §Perf lever that brings the 72B
    train_4k temp footprint under HBM (EXPERIMENTS.md §Perf pair B)."""
    def grads_of(params, batch):
        return jax.value_and_grad(lm_loss, has_aux=True)(
            params, cfg, batch, remat)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            # split the batch as (B/A, A) then move A to front: this split
            # keeps each microbatch's batch rows aligned with the data-axis
            # sharding (an (A, B/A) reshape interleaves shards and forces
            # GSPMD to reshard every microbatch — measured 16x collective
            # blowup on the 72B train_4k dry-run, EXPERIMENTS.md §Perf B)
            def split(t):
                a = accum_steps
                t = t.reshape((t.shape[0] // a, a) + t.shape[1:])
                return jnp.swapaxes(t, 0, 1)

            micro = {k: split(v) for k, v in batch.items()
                     if k != "positions"}
            # positions (3, B, S) carry the batch on axis 1
            if "positions" in batch:
                pos = batch["positions"]
                pos = pos.reshape(3, pos.shape[1] // accum_steps, accum_steps,
                                  pos.shape[-1])
                micro["positions"] = pos.transpose(2, 0, 1, 3)

            def accum(carry, mb):
                g_acc, l_acc, m_acc = carry
                (loss, metrics), grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss,
                        jax.tree.map(jnp.add, m_acc, metrics)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"xent": jnp.zeros((), jnp.float32),
                  "zloss": jnp.zeros((), jnp.float32),
                  "dropped_frac": jnp.zeros((), jnp.float32)}
            (grads, loss, metrics), _ = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32), m0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = jax.tree.map(lambda m: m / accum_steps, metrics)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        _, metrics = lm_loss(params, cfg, batch, remat=False)
        return metrics

    return eval_step


def init_train_state(key, cfg):
    params = T.init_params(key, cfg)
    return params, init_opt_state(params)
