"""AdamW + schedules + grad clipping, built from scratch (no optax here)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state["nu"], grads)
    lr = cosine_lr(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
