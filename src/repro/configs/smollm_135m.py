"""SmolLM-135M — llama-arch small dense model.

[hf:HuggingFaceTB/SmolLM-135M]: 30 layers, d_model=576, 9 query heads with
GQA kv=3, d_ff=1536, vocab 49152, tied embeddings, RMSNorm + SwiGLU.
"""
from repro.configs.base import ModelConfig, register

SMOLLM_135M = register(ModelConfig(
    name="smollm-135m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49_152,
    tie_embeddings=True,
))
