"""Jamba-v0.1-52B — hybrid Mamba + attention (1:7) with 16-expert top-2 MoE.

[arXiv:2403.19887]: 32 layers, d_model=4096; attention blocks have 32 heads
(GQA kv=8, head_dim=128); Mamba blocks use d_state=16, expand=2; MoE
(16e top-2, d_ff=14336) every other layer; vocab 65536. One attention block
per period of 8 (1 attn : 7 mamba).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

JAMBA_V0_1_52B = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14_336, every=2),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4),
))
