"""Qwen3-14B — dense with qk-norm and GQA.

[hf:Qwen/Qwen3-8B family, 14B point]: 40 layers, d_model=5120, 40 heads
(GQA kv=8, head_dim=128), d_ff=17408, vocab 151936, qk_norm.
"""
from repro.configs.base import ModelConfig, register

QWEN3_14B = register(ModelConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
))
