"""DBRX-132B — fine-grained 16-expert top-4 MoE.

[hf:databricks/dbrx-base]: 40 layers, d_model=6144, 48 heads (GQA kv=8,
head_dim=128), per-expert d_ff=10752, vocab 100352, MoE on every layer.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

DBRX_132B = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=100_352,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff=10_752, every=1),
))
