"""Config system: architecture descriptions + input-shape suite + registry.

Every assigned architecture is a ``ModelConfig`` (one module per arch under
``repro/configs``). Configs are pure data — models are built from them by
``repro.models.transformer.Transformer``; the QPART decision layer reads
``layer_specs()`` derived from the same config, so the paper's algorithms
apply uniformly across families.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Block kinds making up a decoder stack.
ATTN = "attn"
MAMBA = "mamba"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden dim
    every: int = 1               # MoE replaces the MLP every `every`-th block
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256             # SSD chunk length for the blocked scan

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    source: str                  # citation for the config values
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int                    # dense-MLP hidden (0 if none / MoE-only)
    vocab_size: int

    head_dim: int = 0            # 0 -> d_model // num_heads
    rope: str = "rope"           # rope | rope2d | mrope | none
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1          # hybrid: 1 attention block per `attn_every`
                                 # blocks, the rest are mamba blocks.
                                 # attn_every=0 -> attention-free (pure SSM).
    sliding_window: Optional[int] = None   # None = full causal attention
    frontend: str = "none"       # none | audio | vision  (stub embeddings)
    dtype: str = "bfloat16"

    # TP head padding (Megatron/MaxText practice): query heads are padded
    # to a multiple of the model-axis size so the head dim shards evenly;
    # padded heads are masked to exact zero in the output projection, so
    # the function computed is exactly the unpadded architecture's.
    tp_pad: int = 16             # model-axis size to pad heads for (1 = off)

    # ---- derived -----------------------------------------------------
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def padded_vocab(self) -> int:
        """Vocab rounded up to the model-axis multiple (Megatron practice);
        padded logit columns are masked to -inf in the unembed."""
        if self.tp_pad <= 1:
            return self.vocab_size
        r = self.vocab_size % self.tp_pad
        return self.vocab_size + (self.tp_pad - r if r else 0)

    def padded_heads(self) -> "tuple[int, int]":
        """(KV_pad, G_pad): smallest padded GQA grouping with
        KV_pad*G_pad % tp_pad == 0, KV_pad >= KV, G_pad >= G."""
        kv = self.num_kv_heads
        g = max(self.num_heads // max(kv, 1), 1)
        if self.tp_pad <= 1 or (kv * g) % self.tp_pad == 0:
            return kv, g
        best = None
        for kvp in range(kv, kv + self.tp_pad + 1):
            for gp in range(g, g + self.tp_pad + 1):
                if (kvp * gp) % self.tp_pad == 0:
                    if best is None or kvp * gp < best[0] * best[1]:
                        best = (kvp, gp)
        return best

    def block_kind(self, layer: int) -> str:
        """Which block occupies position `layer` (0-based) of the stack."""
        if self.attn_every == 0:
            return MAMBA
        if self.attn_every == 1:
            return ATTN
        # Jamba-style: one attention block per period, at the middle slot.
        return ATTN if layer % self.attn_every == self.attn_every // 2 else MAMBA

    def uses_moe(self, layer: int) -> bool:
        return self.moe is not None and (layer % self.moe.every == self.moe.every - 1)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        total += self.d_model  # final norm
        for l in range(self.num_layers):
            total += self._block_params(l)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k only)."""
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        total += self.d_model
        for l in range(self.num_layers):
            total += self._block_params(l, active=True)
        return total

    def _block_params(self, layer: int, active: bool = False) -> int:
        d = self.d_model
        n = 0
        if self.block_kind(layer) == ATTN:
            hd = self.resolved_head_dim()
            n += d * self.num_heads * hd            # q
            n += 2 * d * self.num_kv_heads * hd     # k, v
            n += self.num_heads * hd * d            # o
            if self.qkv_bias:
                n += (self.num_heads + 2 * self.num_kv_heads) * hd
            n += d                                   # pre-norm
        else:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            n += d * (2 * di + 2 * s.d_state + nh)   # in_proj (x,z,B,C,dt)
            n += s.conv_width * (di + 2 * s.d_state) # conv over x,B,C
            n += nh * 2                              # A_log, D
            n += di * d                              # out_proj
            n += d                                   # pre-norm
        # feed-forward half
        if self.uses_moe(layer):
            m = self.moe
            per_expert = 3 * d * m.d_ff if self.mlp == "swiglu" else 2 * d * m.d_ff
            n += (m.top_k if active else m.num_experts) * per_expert
            n += d * m.num_experts                   # router
            n += d
        elif self.d_ff:
            n += (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
            n += d
        return n

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = 64
        heads = max(1, min(self.num_heads, d // hd)) if self.num_heads else 0
        kv = max(1, min(self.num_kv_heads, heads)) if heads else 0
        # keep the GQA ratio flavour when possible
        if heads and self.num_kv_heads < self.num_heads:
            kv = max(1, heads // 2)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff=min(self.moe.d_ff, 2 * d))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=32)
        # Hybrids keep both block kinds in 2 layers by tightening the
        # interleave to 1:1 (layer 0 mamba, layer 1 attention).
        attn_every = 2 if self.attn_every > 1 else self.attn_every
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=2, d_model=d,
            attn_every=attn_every, tp_pad=1,
            num_heads=heads, num_kv_heads=kv, head_dim=hd if heads else 0,
            d_ff=min(self.d_ff, 2 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            moe=moe, ssm=ssm,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
        )


# ---------------------------------------------------------------------------
# Input-shape suite (assigned).
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}

# Sliding window used when a full-attention arch is asked for long_500k.
LONG_CONTEXT_WINDOW = 4_096


def for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt a config to an input shape (sub-quadratic variant for 500k)."""
    if shape.name == "long_500k" and cfg.attn_every >= 1 and cfg.sliding_window is None:
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


# ---------------------------------------------------------------------------
# Registry.
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "smollm_135m", "olmoe_1b_7b", "qwen3_14b", "musicgen_medium",
    "mamba2_1_3b", "qwen2_vl_72b", "dbrx_132b", "chatglm3_6b",
    "qwen1_5_4b", "jamba_v0_1_52b", "mnist_mlp", "cifar_cnn",
]

ASSIGNED_ARCHS = [
    "smollm-135m", "olmoe-1b-7b", "qwen3-14b", "musicgen-medium",
    "mamba2-1.3b", "qwen2-vl-72b", "dbrx-132b", "chatglm3-6b",
    "qwen1.5-4b", "jamba-v0.1-52b",
]


def _load_all() -> None:
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
