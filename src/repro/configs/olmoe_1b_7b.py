"""OLMoE-1B-7B — 64-expert top-8 MoE with 1B active / 7B total params.

[arXiv:2409.02060]: 16 layers, d_model=2048, 16 heads (kv=16), per-expert
d_ff=1024, vocab 50304, MoE on every layer.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

OLMOE_1B_7B = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50_304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff=1024, every=1),
))
