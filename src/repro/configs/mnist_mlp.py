"""Alias module: the paper's MNIST 6-FC classifier lives in classifier.py."""
from repro.configs.classifier import MNIST_MLP  # noqa: F401
