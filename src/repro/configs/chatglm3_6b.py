"""ChatGLM3-6B — dense, 2d-RoPE (partial rotary), extreme GQA (kv=2).

[arXiv:2406.12793]: 28 layers, d_model=4096, 32 heads (GQA kv=2,
head_dim=128), d_ff=13696, vocab 65024, QKV bias, rotary applied to half
the head dims (GLM's 2d RoPE).
"""
from repro.configs.base import ModelConfig, register

CHATGLM3_6B = register(ModelConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab_size=65_024,
    qkv_bias=True,
    rope="rope2d",
))
