"""Qwen1.5-4B — dense with QKV bias, MHA (kv=heads).

[hf:Qwen/Qwen1.5-0.5B family, 4B point]: 40 layers, d_model=2560, 20 heads
(kv=20, head_dim=128), d_ff=6912, vocab 151936, QKV bias.
"""
from repro.configs.base import ModelConfig, register

QWEN1_5_4B = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151_936,
    qkv_bias=True,
))
