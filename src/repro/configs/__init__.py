from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS, INPUT_SHAPES, LONG_CONTEXT_WINDOW, InputShape,
    ModelConfig, MoEConfig, SSMConfig, for_shape, get_config, list_configs,
    register,
)
from repro.configs.classifier import CIFAR_CNN, MNIST_MLP  # noqa: F401
