"""Qwen2-VL-72B — VLM language backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191]: 80 layers, d_model=8192, 64 heads (GQA kv=8,
head_dim=128), d_ff=29568, vocab 152064, QKV bias, M-RoPE (3-section
multimodal rotary embedding). The ViT vision encoder + projector is a stub
per the assignment — ``input_specs`` feeds precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig, register

QWEN2_VL_72B = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    rope="mrope",
    rope_theta=1_000_000.0,
    frontend="vision",
))
