"""MusicGen-medium — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284]: 48 layers, d_model=1536, 24 heads (MHA, kv=24),
d_ff=6144, vocab 2048 (EnCodec codebook). GeLU MLP + LayerNorm (the
original is a vanilla transformer decoder). The EnCodec conv frontend is a
stub per the assignment — ``input_specs`` feeds precomputed frame
embeddings.
"""
from repro.configs.base import ModelConfig, register

MUSICGEN_MEDIUM = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    mlp="gelu",
    norm="layernorm",
    frontend="audio",
))
