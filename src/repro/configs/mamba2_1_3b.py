"""Mamba2-1.3B — attention-free SSM using SSD (state-space duality).

[arXiv:2405.21060]: 48 layers, d_model=2048, expand=2 (d_inner=4096),
ssm_state=128, head_dim=64 (64 SSD heads), conv width 4, vocab 50280.
No MLP (d_ff=0): every block is a Mamba2 mixer.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

MAMBA2_1_3B = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    rope="none",
    attn_every=0,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4),
))
