"""Classifier configs for the paper's own evaluation models (§V).

The QPART paper evaluates on a 6-fully-connected-layer MNIST classifier
(Fig. 4) plus CNN/ResNet image classifiers. These are *classifiers*, not
decoder LMs, so they get their own light config type. The QPART decision
layer consumes ``layer_specs()`` from either kind.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class DenseSpec:
    """Fully connected layer: in_dim -> out_dim."""
    in_dim: int
    out_dim: int


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Conv layer: C_in x C_out, F1 x F2 filter over U x V input (Eq. 2)."""
    c_in: int
    c_out: int
    f1: int
    f2: int
    u: int
    v: int
    stride: int = 1
    pool: int = 1   # max-pool applied after activation


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    name: str
    source: str
    input_shape: Tuple[int, ...]
    num_classes: int
    layers: Sequence[object]      # DenseSpec | ConvSpec, topologically ordered

    @property
    def num_layers(self) -> int:
        return len(self.layers)


# Paper Fig. 4: DNN with six fully connected layers for MNIST (28x28 -> 10).
MNIST_MLP = ClassifierConfig(
    name="mnist-mlp6",
    source="QPART paper Fig.4 (6 FC layers, MNIST)",
    input_shape=(28, 28),
    num_classes=10,
    layers=(
        DenseSpec(784, 512),
        DenseSpec(512, 256),
        DenseSpec(256, 128),
        DenseSpec(128, 64),
        DenseSpec(64, 32),
        DenseSpec(32, 10),
    ),
)

# Paper §V: "a CNN on SVHN/CIFAR10/CIFAR100" — a compact VGG-ish CNN.
CIFAR_CNN = ClassifierConfig(
    name="cifar-cnn",
    source="QPART paper §V (CNN on SVHN/CIFAR)",
    input_shape=(32, 32, 3),
    num_classes=10,
    layers=(
        ConvSpec(3, 32, 3, 3, 32, 32, pool=2),
        ConvSpec(32, 64, 3, 3, 16, 16, pool=2),
        ConvSpec(64, 128, 3, 3, 8, 8, pool=2),
        DenseSpec(128 * 4 * 4, 256),
        DenseSpec(256, 10),
    ),
)
