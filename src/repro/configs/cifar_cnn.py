"""Alias module: the paper's CIFAR CNN lives in classifier.py."""
from repro.configs.classifier import CIFAR_CNN  # noqa: F401
