"""Pallas TPU kernel: tiled asymmetric quantize / dequantize.

TPU mapping: the tensor streams HBM -> VMEM in (block_m, block_n) tiles
(lane-dim 128-aligned); each tile is rounded onto the quantization grid on
the VPU and written back as int8 codes. scale/mu ride in SMEM as (1, 1)
scalars. This is the execution form of paper Eq. 10 — the server quantizes
a model segment before "transmitting" it (on TPU: before writing the
compact weights to HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)


def _quantize_kernel(x_ref, scale_ref, mu_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    scale = scale_ref[0, 0]
    mu = mu_ref[0, 0]
    q = jnp.round((x - mu) / scale)
    q = jnp.clip(q, 0.0, float(levels))
    o_ref[...] = q.astype(jnp.uint8)


def _dequantize_kernel(c_ref, scale_ref, mu_ref, o_ref, *, out_dtype):
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (c * scale_ref[0, 0] + mu_ref[0, 0]).astype(out_dtype)


def quantize_pallas(x, scale, mu, bits: int, block=DEFAULT_BLOCK,
                    interpret: bool = False):
    """x (M, N) float -> uint8 codes. bits <= 8."""
    assert bits <= 8
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    grid = (m // bm, n // bn)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, levels=(1 << bits) - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint8),
        interpret=interpret,
    )(x, scale, mu)


def dequantize_pallas(codes, scale, mu, out_dtype=jnp.bfloat16,
                      block=DEFAULT_BLOCK, interpret: bool = False):
    m, n = codes.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    mu = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(codes, scale, mu)
