"""Pallas TPU kernels: tiled asymmetric quantize / dequantize, plus a
fused quantize-and-pack-int4 kernel.

TPU mapping: the tensor streams HBM -> VMEM in (block_m, block_n) tiles
(lane-dim 128-aligned); each tile is rounded onto the quantization grid on
the VPU and written back as int8 codes — or, for the fused int4 kernel,
two adjacent columns are packed into one byte before the writeback, so the
codes never round-trip through HBM at int8 width. scale/mu ride either as
(1, 1) scalar blocks (per-tensor) or as (1, block_n) VMEM tiles indexed by
the n grid axis (per-output-column; DESIGN.md §4). This is the execution
form of paper Eq. 10 — the server quantizes a model segment before
"transmitting" it (on TPU: before writing the compact weights to HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)


def _prep_scale_mu(scale, mu, n: int, bn: int, grid_rank: int = 2):
    """Normalize scale/mu to the (1, 1) per-tensor or (1, N) per-channel
    form and build the matching BlockSpec. Shared by every kernel in
    this package; grid axis 1 always walks the n tiles (``grid_rank`` is
    the kernel's grid arity — 2 for elementwise, 3 for matmul)."""
    scale = jnp.asarray(scale, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    per_channel = scale.size > 1 or mu.size > 1
    if per_channel:
        scale = jnp.broadcast_to(scale.reshape(-1), (n,)).reshape(1, n)
        mu = jnp.broadcast_to(mu.reshape(-1), (n,)).reshape(1, n)
        block = (1, bn)
        idx = (lambda i, j, kk: (0, j)) if grid_rank == 3 \
            else (lambda i, j: (0, j))
    else:
        scale = scale.reshape(1, 1)
        mu = mu.reshape(1, 1)
        block = (1, 1)
        idx = (lambda i, j, kk: (0, 0)) if grid_rank == 3 \
            else (lambda i, j: (0, 0))
    return scale, mu, pl.BlockSpec(block, idx)


def _quantize_kernel(x_ref, scale_ref, mu_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.round((x - mu_ref[...]) / scale_ref[...])
    q = jnp.clip(q, 0.0, float(levels))
    o_ref[...] = q.astype(jnp.uint8)


def _dequantize_kernel(c_ref, scale_ref, mu_ref, o_ref, *, out_dtype):
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (c * scale_ref[...] + mu_ref[...]).astype(out_dtype)


def _quantize_pack4_kernel(x_ref, scale_ref, mu_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round((x - mu_ref[...]) / scale_ref[...]), 0.0, 15.0)
    q = q.astype(jnp.uint8)
    bm, bn = q.shape
    # pair adjacent columns: byte j = col 2j (low nibble) | col 2j+1 << 4
    pairs = q.reshape(bm, bn // 2, 2)
    o_ref[...] = pairs[..., 0] | (pairs[..., 1] << 4)


def quantize_pallas(x, scale, mu, bits: int, block=DEFAULT_BLOCK,
                    interpret: bool = False):
    """x (M, N) float -> uint8 codes. bits <= 8. scale/mu per-tensor or
    per-output-column (broadcastable to (1, N))."""
    assert bits <= 8
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    grid = (m // bm, n // bn)
    scale, mu, smspec = _prep_scale_mu(scale, mu, n, bn)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, levels=(1 << bits) - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            smspec,
            smspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint8),
        interpret=interpret,
    )(x, scale, mu)


def dequantize_pallas(codes, scale, mu, out_dtype=jnp.bfloat16,
                      block=DEFAULT_BLOCK, interpret: bool = False):
    m, n = codes.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    scale, mu, smspec = _prep_scale_mu(scale, mu, n, bn)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            smspec,
            smspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(codes, scale, mu)


def quantize_pack4_pallas(x, scale, mu, block=DEFAULT_BLOCK,
                          interpret: bool = False):
    """Fused Eq. 10 + int4 wire packing: x (M, N) float -> (M, N//2) uint8,
    two 4-bit codes per byte (low nibble = even column — the qmatmul4
    layout). One VMEM pass; replaces the strided-slice packing that
    round-tripped int8 codes through HBM."""
    m, n = x.shape
    assert n % 2 == 0, "int4 packing pairs adjacent columns"
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0 and bn % 2 == 0, (x.shape, block)
    grid = (m // bm, n // bn)
    scale, mu, smspec = _prep_scale_mu(scale, mu, n, bn)
    return pl.pallas_call(
        _quantize_pack4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            smspec,
            smspec,
        ],
        out_specs=pl.BlockSpec((bm, bn // 2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n // 2), jnp.uint8),
        interpret=interpret,
    )(x, scale, mu)
