"""Pallas TPU kernel: causal flash attention (forward).

This is the structural fix for the dominant roofline term found in §Perf
pair A: the pure-JAX blocked attention materializes every (BQ, BK) f32
score tile to HBM (85 % of the train-step bytes), while this kernel keeps
the tile, the online-softmax stats and the output accumulator in VMEM —
HBM traffic collapses to the Q/K/V/O tensors themselves.

TPU mapping:
  grid = (heads_total, nq, nk), sequential in the last dim so the VMEM
  scratch (acc, m, l) persists across the k-blocks of one q-block.
  Blocks are MXU-aligned (block_q x head_dim and block_k x head_dim tiles,
  head_dim 64/128 = lane-width multiples). Strictly-masked causal blocks
  are skipped with pl.when (the §Perf A1 optimization, in-kernel).
  GQA: the K/V BlockSpec index map sends query-head h to its kv group
  h // group_size — no repeated K/V materialization.

Validated in interpret mode against the pure-jnp oracle
(`ref.flash_attention_ref` == `models.attention._blocked_causal_attention`
semantics) over shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, nk: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # k block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: k block j overlaps q block i iff j*block_k <= i*block_q+bq-1
    @pl.when(j * block_k <= i * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)              # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
        sc = jnp.where(qpos >= kpos, sc, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """Causal flash attention.

    q (B, S, KV, G, hd), k/v (B, S, KV, hd)  ->  (B, S, KV, G, hd)
    (the grouped GQA layout of models/attention; padded heads included).
    """
    b, s, kvh, g, hd = q.shape
    scale = hd ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k

    # head-major flat layouts: q (B*KV*G, S, hd), k/v (B*KV, S, hd)
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)

    grid = (b * kvh * g, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh * g, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, kvh, g, s, hd).transpose(0, 3, 1, 2, 4)
