"""Pallas TPU kernel: dequantize-fused matmul (W8A16 / W4A16).

The QPART-quantized weights stay packed in HBM (int8 codes, or two 4-bit
codes per byte); each (block_k, block_n) weight tile is dequantized in VMEM
right before an MXU dot with the (block_m, block_k) activation tile, and
partial products accumulate in a VMEM f32 scratch across the k grid
dimension. HBM traffic for weights is b/16 of the bf16 baseline — the
paper's payload saving (Eq. 14) re-expressed for the TPU memory hierarchy
(DESIGN.md §3).

Scale/zero granularity (DESIGN.md §4): ``scale``/``mu`` may be

  * scalars (any size-1 shape)  — per-tensor, rides as a (1, 1) block, or
  * per-output-column vectors   — any shape broadcastable to (1, N);
    streamed through VMEM as (1, block_n) tiles indexed by the n grid
    axis, so ``quantize_stacked``'s per-channel metadata (a period slice
    ``scale[i]`` of shape (1, N)) feeds the kernel without reformatting.

The kernel body is granularity-agnostic: the dequant is a broadcast
multiply-add of the scale/zero block over the (block_k, block_n) tile.

Blocks are MXU-aligned: (bm, bk, bn) multiples of (8, 128, 128); defaults
(256, 512, 256) keep the working set (x 256x512 bf16 + w 512x256 int8 +
acc 256x256 f32) ~ 0.6 MB, far under the ~16 MB v5e VMEM so the pipeline
can run double-buffered.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quantize import _prep_scale_mu

BM, BK, BN = 256, 512, 256


def _qmm_kernel(x_ref, w_ref, scale_ref, mu_ref, o_ref, acc_ref, *,
                n_k: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # scale/mu block is (1, 1) or (1, bn): broadcasts over the weight tile
    w = w_ref[...].astype(jnp.float32) * scale_ref[...] + mu_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def qmatmul_pallas(x, w_codes, scale, mu, out_dtype=jnp.bfloat16,
                   bm=BM, bk=BK, bn=BN, interpret: bool = False):
    """x (M, K) bf16/f32 @ dequant(w_codes (K, N) int8) -> (M, N).
    scale/mu: per-tensor scalars or per-output-column (1, N) / (N,)."""
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, w_codes.shape)
    grid = (m // bm, n // bn, k // bk)
    scale, mu, smspec = _prep_scale_mu(scale, mu, n, bn, grid_rank=3)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=k // bk, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            smspec,
            smspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, scale, mu)


def _qmm4_kernel(x_ref, wp_ref, scale_ref, mu_ref, o_ref, acc_ref, *,
                 n_k: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = wp_ref[...]
    lo = (packed & 0xF).astype(jnp.float32)
    hi = ((packed >> 4) & 0xF).astype(jnp.float32)
    # packed (bk, bn//2): interleave nibbles back to (bk, bn)
    bk, half = packed.shape
    w = jnp.stack([lo, hi], axis=-1).reshape(bk, half * 2)
    w = w * scale_ref[...] + mu_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def qmatmul4_pallas(x, packed, scale, mu, out_dtype=jnp.bfloat16,
                    bm=BM, bk=BK, bn=BN, interpret: bool = False):
    """x (M, K) @ dequant(packed (K, N//2) uint8, 2 nibbles/byte) -> (M, N).
    scale/mu: per-tensor scalars or per-output-column (1, N) / (N,),
    indexed in UNPACKED column space."""
    m, k = x.shape
    k2, half = packed.shape
    n = half * 2
    assert k == k2
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    grid = (m // bm, n // bn, k // bk)
    scale, mu, smspec = _prep_scale_mu(scale, mu, n, bn, grid_rank=3)
    return pl.pallas_call(
        functools.partial(_qmm4_kernel, n_k=k // bk, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),
            smspec,
            smspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scale, mu)
