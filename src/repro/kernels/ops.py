"""jit'd public wrappers for the Pallas kernels.

On a real TPU backend the kernels compile to Mosaic; on the CPU container
they run in interpret mode (the kernel body executed in Python), which is
how the test-suite validates them against ``ref.py``. ``use_pallas=False``
falls back to the pure-jnp oracle — the mode the dry-run uses so the
lowered HLO stays portable.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.qmatmul import BK, BM, BN, qmatmul4_pallas, qmatmul_pallas
from repro.kernels.quantize import (dequantize_pallas, quantize_pack4_pallas,
                                    quantize_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Execution-mode dispatch (PR 9). models/ call these entry points instead of
# branching on the backend themselves; one env var picks the lane for the
# whole decode path.

KERNEL_MODES = ("auto", "kernel", "interpret", "reference")


def kernel_mode() -> str:
    """Resolve ``REPRO_KERNELS`` to the lane model code should execute.

    ``auto`` (default) -> compiled Pallas on TPU, pure-jnp ``ref``/scan
    path on CPU — the CPU default stays bit-for-bit the pre-kernel
    behavior. ``kernel`` forces compiled Pallas, ``interpret`` runs the
    kernel bodies in Python (the CI correctness lane), ``reference``
    forces the jnp oracles everywhere.
    """
    mode = os.environ.get("REPRO_KERNELS", "").strip().lower() or "auto"
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"REPRO_KERNELS={mode!r}: expected one of {KERNEL_MODES}")
    if mode == "auto":
        return "kernel" if _on_tpu() else "reference"
    return mode


def decode_attention(q, ck, cv, pos):
    """Single-token decode attention over a ring-buffer cache, dispatched
    by :func:`kernel_mode`. q (B, KVp, Gp, hd); ck/cv (B, buf, KVp, hd)
    post-write; pos scalar absolute position -> (B, KVp, Gp, hd)."""
    mode = kernel_mode()
    if mode == "reference":
        return ref.decode_attention_ref(q, ck, cv, pos)
    return decode_attention_pallas(q, ck, cv, pos,
                                   interpret=mode == "interpret")


def _tile(dim: int, pref: int) -> int:
    """Largest block size <= pref that divides dim (model dims are not
    always multiples of the MXU-optimal defaults — e.g. d_model 576)."""
    if dim % pref == 0:
        return pref
    for t in range(min(pref, dim), 0, -1):
        if dim % t == 0:
            return t
    return dim


def is_wire_struct(w) -> bool:
    """True for a quantized wire struct ({codes|codes_packed, scale, mu})."""
    return isinstance(w, dict) and ("codes" in w or "codes_packed" in w)


def qdense(x, w, n_contract: int = 1, out_dtype=None):
    """Quantized dense contraction: trailing axes of ``x`` against the
    ``n_contract`` leading axes of wire-struct ``w``, through the
    dequantize-fused qmatmul/qmatmul4 kernels (by :func:`kernel_mode`).

    ``w`` is {codes (K..., N...) uint8 | codes_packed (..., N/2), scale,
    mu} with per-tensor (size-1) or per-output-column metadata. The
    trailing axes of ``x`` whose product equals prod(K...) are the
    contraction; output is x-batch-axes + (N...) in ``out_dtype``
    (default ``x.dtype``).
    """
    out_dtype = out_dtype or x.dtype
    packed = "codes_packed" in w
    codes = w["codes_packed"] if packed else w["codes"]
    k = math.prod(codes.shape[:n_contract])
    out_tail = list(codes.shape[n_contract:])
    if packed:
        out_tail[-1] *= 2
    # peel trailing x axes until they cover the contraction size
    i, tail = x.ndim, 1
    while tail < k:
        i -= 1
        tail *= x.shape[i]
    assert tail == k, (x.shape, codes.shape, n_contract)
    batch = x.shape[:i]
    x2 = x.reshape(-1, k)
    codes2 = codes.reshape(k, -1)
    n = codes2.shape[1] * (2 if packed else 1)

    def _meta2d(v):
        """scale/mu -> the (1, 1) / (1, N) layout qmatmul expects. The
        quantize_stacked metadata keeps size-1 contraction axes and
        broadcasts over the flattened output columns (e.g. per-head-dim
        scale for a (D, H, hd) weight)."""
        if v.size == 1:
            return v.reshape(1, 1)
        v = v[(0,) * n_contract]               # drop contraction axes
        return jnp.broadcast_to(v, tuple(out_tail)).reshape(1, n)

    scale, mu = _meta2d(w["scale"]), _meta2d(w["mu"])

    mode = kernel_mode()
    if mode == "reference":
        out = (ref.qmatmul4_ref(x2, codes2, scale, mu, out_dtype) if packed
               else ref.qmatmul_ref(x2, codes2, scale, mu, out_dtype))
    else:
        m = x2.shape[0]
        bm, bk = _tile(m, BM), _tile(k, BK)
        bn = _tile(n, BN)
        if packed and bn % 2:                  # packed tile is (bk, bn // 2)
            bn = next((t for t in range(min(BN, n), 1, -1)
                       if n % t == 0 and t % 2 == 0), n)
        fn = qmatmul4_pallas if packed else qmatmul_pallas
        out = fn(x2, codes2, scale, mu, out_dtype, bm=bm, bk=bk, bn=bn,
                 interpret=mode == "interpret")
    return out.reshape(batch + tuple(out_tail))


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def quantize_tensor(x, scale, mu, bits: int = 8, use_pallas: bool = True):
    if use_pallas and x.ndim == 2:
        return quantize_pallas(x, scale, mu, bits, interpret=not _on_tpu())
    return ref.quantize_ref(x, scale, mu, bits)


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas"))
def dequantize_tensor(codes, scale, mu, out_dtype=jnp.bfloat16,
                      use_pallas: bool = True):
    if use_pallas and codes.ndim == 2:
        return dequantize_pallas(codes, scale, mu, out_dtype,
                                 interpret=not _on_tpu())
    return ref.dequantize_ref(codes, scale, mu, out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas"))
def qmatmul(x, w_codes, scale, mu, out_dtype=jnp.bfloat16,
            use_pallas: bool = True):
    """Quantized matmul: x @ dequant(w_codes)."""
    if use_pallas:
        return qmatmul_pallas(x, w_codes, scale, mu, out_dtype,
                              interpret=not _on_tpu())
    return ref.qmatmul_ref(x, w_codes, scale, mu, out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas"))
def qmatmul4(x, packed, scale, mu, out_dtype=jnp.bfloat16,
             use_pallas: bool = True):
    """int4-packed quantized matmul."""
    if use_pallas:
        return qmatmul4_pallas(x, packed, scale, mu, out_dtype,
                               interpret=not _on_tpu())
    return ref.qmatmul4_ref(x, packed, scale, mu, out_dtype)


def pack_int4(codes):
    return ref.pack_int4_ref(codes)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def quantize_pack4(x, scale, mu, use_pallas: bool = True):
    """Fused quantize + int4 wire packing: (M, N) float -> (M, N//2)
    uint8, two codes per byte. scale/mu per-tensor or per-column."""
    if use_pallas and x.ndim == 2:
        return quantize_pack4_pallas(x, scale, mu, interpret=not _on_tpu())
    return ref.quantize_pack4_ref(x, scale, mu)
