"""jit'd public wrappers for the Pallas kernels.

On a real TPU backend the kernels compile to Mosaic; on the CPU container
they run in interpret mode (the kernel body executed in Python), which is
how the test-suite validates them against ``ref.py``. ``use_pallas=False``
falls back to the pure-jnp oracle — the mode the dry-run uses so the
lowered HLO stays portable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.qmatmul import qmatmul4_pallas, qmatmul_pallas
from repro.kernels.quantize import (dequantize_pallas, quantize_pack4_pallas,
                                    quantize_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def quantize_tensor(x, scale, mu, bits: int = 8, use_pallas: bool = True):
    if use_pallas and x.ndim == 2:
        return quantize_pallas(x, scale, mu, bits, interpret=not _on_tpu())
    return ref.quantize_ref(x, scale, mu, bits)


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas"))
def dequantize_tensor(codes, scale, mu, out_dtype=jnp.bfloat16,
                      use_pallas: bool = True):
    if use_pallas and codes.ndim == 2:
        return dequantize_pallas(codes, scale, mu, out_dtype,
                                 interpret=not _on_tpu())
    return ref.dequantize_ref(codes, scale, mu, out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas"))
def qmatmul(x, w_codes, scale, mu, out_dtype=jnp.bfloat16,
            use_pallas: bool = True):
    """Quantized matmul: x @ dequant(w_codes)."""
    if use_pallas:
        return qmatmul_pallas(x, w_codes, scale, mu, out_dtype,
                              interpret=not _on_tpu())
    return ref.qmatmul_ref(x, w_codes, scale, mu, out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas"))
def qmatmul4(x, packed, scale, mu, out_dtype=jnp.bfloat16,
             use_pallas: bool = True):
    """int4-packed quantized matmul."""
    if use_pallas:
        return qmatmul4_pallas(x, packed, scale, mu, out_dtype,
                               interpret=not _on_tpu())
    return ref.qmatmul4_ref(x, packed, scale, mu, out_dtype)


def pack_int4(codes):
    return ref.pack_int4_ref(codes)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def quantize_pack4(x, scale, mu, use_pallas: bool = True):
    """Fused quantize + int4 wire packing: (M, N) float -> (M, N//2)
    uint8, two codes per byte. scale/mu per-tensor or per-column."""
    if use_pallas and x.ndim == 2:
        return quantize_pack4_pallas(x, scale, mu, interpret=not _on_tpu())
    return ref.quantize_pack4_ref(x, scale, mu)
