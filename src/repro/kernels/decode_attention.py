"""Pallas TPU kernel: single-query (decode) flash attention over a
cached K/V prefix.

One autoregressive decode step attends ONE query token against the ring
buffer holding every previous position — the dominant per-token memory
term of the serving decode lane. The pure-JAX path
(``kernels.ref.decode_attention_ref``, the math inlined in
``models.attention.attention_decode`` until PR 9) materializes the full
(B, KVp, Gp, buf) score row in f32 through HBM; this kernel streams the
cache once, keeping the score tile, the online-softmax stats and the
output accumulator in VMEM — per-step HBM traffic collapses to the K/V
bytes themselves, which is exactly the ``kv_rw_bytes`` term the cost
model charges (``cost_model.transformer_layer_specs(mode="decode")``).

TPU mapping:
  grid = (B * KVp, nk), sequential in the k-block dim so the VMEM
  scratch (acc, m, l) persists across the cache blocks of one
  (batch, kv-head) pair. GQA comes for free in the layout: the query
  block of program h is that kv head's WHOLE query group (Gp, hd), so
  the score tile is a (Gp, block_k) MXU dot and K/V are read once per
  group — never re-materialized per query head.

  The absolute position rides as a scalar-prefetch operand
  (``pltpu.PrefetchScalarGridSpec``): the ring-validity mask
  ``(pos + 1 >= buf) | (idx <= pos % buf)`` — identical to the
  reference's — is computed in-kernel from SMEM, so one compiled
  program serves every decode step of every stream.

  The cache may arrive in any storage dtype (bf16, float8_e4m3fn for
  quantized device segments): tiles are upcast to f32 on the VPU before
  the dot, matching the reference's compute-in-query-dtype discipline
  within accumulation tolerance.

Validated in interpret mode against ``ref.decode_attention_ref`` over
shape/dtype/GQA sweeps (incl. float8 caches) in
tests/test_decode_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 256


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, block_k: int, nk: int, buf: int):
    j = pl.program_id(1)                    # cache block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    slot = jax.lax.rem(pos, buf)
    q = q_ref[0].astype(jnp.float32)        # (Gp, hd)
    k = k_ref[0].astype(jnp.float32)        # (BK, hd)
    v = v_ref[0].astype(jnp.float32)
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (Gp, BK)
    # ring validity (the reference's mask): wrapped ring -> every slot
    # live; otherwise only slots 0..pos%buf have been written
    idx = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], block_k), 1)
    valid = (pos + 1 >= buf) | (idx <= slot)
    sc = jnp.where(valid, sc, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=-1))
    p = jnp.exp(sc - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def decode_attention_pallas(q, ck, cv, pos, *,
                            block_k: int = DEFAULT_BLOCK_K,
                            interpret: bool = False):
    """Single-token decode attention against a ring-buffer cache.

    q (B, KVp, Gp, hd) post-RoPE query; ck/cv (B, buf, KVp, hd) the
    cache AFTER the step's K/V write (any storage dtype); pos scalar
    int32 absolute position. -> (B, KVp, Gp, hd) in the query dtype.
    """
    b, kvp, gp, hd = q.shape
    buf = ck.shape[1]
    scale = hd ** -0.5
    block_k = min(block_k, buf)
    assert buf % block_k == 0, (buf, block_k)
    nk = buf // block_k

    qf = q.reshape(b * kvp, gp, hd)
    kf = ck.transpose(0, 2, 1, 3).reshape(b * kvp, buf, hd)
    vf = cv.transpose(0, 2, 1, 3).reshape(b * kvp, buf, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * kvp, nk),
        in_specs=[
            pl.BlockSpec((1, gp, hd), lambda h, j, pos_ref: (h, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, j, pos_ref: (h, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, j, pos_ref: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, gp, hd), lambda h, j, pos_ref: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, hd), jnp.float32),
            pltpu.VMEM((gp,), jnp.float32),
            pltpu.VMEM((gp,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                          nk=nk, buf=buf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvp, gp, hd), q.dtype),
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return out.reshape(b, kvp, gp, hd)
