"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x, scale, mu, bits: int):
    """Asymmetric uniform quantization to int codes (int8 storage)."""
    levels = (1 << bits) - 1
    codes = jnp.clip(jnp.round((x.astype(jnp.float32) - mu) / scale), 0, levels)
    # unsigned storage: 8-bit codes span 0..255 and WRAP in int8
    return codes.astype(jnp.uint8 if bits <= 8 else jnp.int32)


def dequantize_ref(codes, scale, mu, dtype=jnp.bfloat16):
    return (codes.astype(jnp.float32) * scale + mu).astype(dtype)


def qmatmul_ref(x, w_codes, scale, mu, out_dtype=jnp.float32):
    """x (M,K) x dequant(w_codes (K,N)) -> (M,N)."""
    w = w_codes.astype(jnp.float32) * scale + mu
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def pack_int4_ref(codes):
    """(..., N) int codes in [0,15] -> (..., N//2) packed bytes (low
    nibble = even column)."""
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4_ref(packed):
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    half = packed.shape[-1]
    out = jnp.zeros(packed.shape[:-1] + (half * 2,), jnp.int32)
    out = out.at[..., 0::2].set(lo)
    out = out.at[..., 1::2].set(hi)
    return out


def quantize_pack4_ref(x, scale, mu):
    """Oracle for the fused quantize-and-pack-int4 kernel."""
    return pack_int4_ref(quantize_ref(x, scale, mu, 4))


def qmatmul4_ref(x, packed, scale, mu, out_dtype=jnp.float32):
    codes = unpack_int4_ref(packed)
    w = codes.astype(jnp.float32) * scale + mu
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


NEG_INF = -1e30


def decode_attention_ref(q, ck, cv, pos):
    """Single-token decode attention over a ring-buffer KV cache — the
    ``lax.scan``-path math of ``models.attention.attention_decode``,
    extracted verbatim (the Pallas decode kernel's allclose target).

    q (B, KVp, Gp, hd) the post-RoPE query of ONE token; ck/cv
    (B, buf, KVp, hd) the cache AFTER the current token's K/V were
    written at slot ``pos % buf`` (any storage dtype — bf16 / float8 for
    quantized device segments); ``pos`` the scalar absolute position.
    Returns (B, KVp, Gp, hd) in the query dtype.
    """
    hd = q.shape[-1]
    buf = ck.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    slot = pos % buf
    sc = jnp.einsum("bkgd,bskd->bkgs", q, ck.astype(q.dtype),
                    preferred_element_type=jnp.float32) * hd ** -0.5
    # validity: once the ring has wrapped (pos+1 >= buf) every slot is
    # live; before that only slots 0..slot have been written.
    idx = jnp.arange(buf)
    valid = (pos + 1 >= buf) | (idx <= slot)
    sc = jnp.where(valid[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    # PV in the QUERY dtype: the cache may hold low-precision storage
    # dtypes that are fine as storage but catastrophic as accumulators
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype),
                     cv.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
