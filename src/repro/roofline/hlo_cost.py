"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts every while-loop body ONCE — useless
for scanned layer stacks (all our models scan over depth, flash-attention
blocks and SSD chunks). This module re-derives FLOPs / bytes-accessed /
collective-bytes by walking the compiled HLO text and scaling each
computation by its loop trip count, which XLA records on every ``while``
op as ``backend_config={"known_trip_count":{"n": ...}}``.

Accounting rules:
  * dot        -> 2 x prod(output dims) x prod(contracting dim sizes)
  * fusion     -> FLOPs of the fused computation; BYTES of the fusion op's
                  operands + output only (internal traffic stays in VMEM /
                  registers — matches the memory-roofline meaning)
  * while      -> body x trip + cond x trip
  * conditional-> max over branches (pessimistic)
  * elementwise/other -> 1 FLOP per output element (dots dominate anyway)
  * collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
                 collective-permute) -> moved bytes x trips, by kind
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "erf",
    "reduce", "compare", "select", "clamp", "convert", "exponential-minus-one",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "CostSummary":
        return CostSummary(self.flops * k, self.bytes * k,
                           {n: v * k for n, v in self.collectives.items()})

    def add(self, other: "CostSummary") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for n, v in other.collectives.items():
            self.collectives[n] = self.collectives.get(n, 0.0) + v

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_op(line: str) -> Optional[Op]:
    line = line.strip()
    is_root = line.startswith("ROOT ")
    if is_root:
        line = line[5:]
    if not line.startswith("%") or "=" not in line:
        return None
    name, rest = line.split(" = ", 1)
    name = name.strip().lstrip("%")
    rest = rest.strip()
    # result type: tuple "(...)" or single "dt[dims]{layout}"
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rest[:i + 1], rest[i + 1:].strip()
    else:
        sp = rest.index(" ")
        type_str, rest = rest[:sp], rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    # operand segment = balanced parens after opcode
    start = rest.index("(")
    depth = 0
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    operand_str = rest[start + 1:i]
    attrs = rest[i + 1:]
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return Op(name, type_str, opcode, operands, attrs, is_root)


def parse_computations(hlo_text: str) -> tuple[Dict[str, List[Op]], str]:
    comps: Dict[str, List[Op]] = {}
    entry = None
    current: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if current is None:
            m = _COMP_HEADER.match(line)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if line == "}":
            current = None
            continue
        op = _parse_op(line)
        if op is not None:
            comps[current].append(op)
    if entry is None:                       # fall back: last computation
        entry = list(comps)[-1] if comps else ""
    return comps, entry


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        self._shape: Dict[str, Dict[str, str]] = {
            cname: {op.name: op.type_str for op in ops}
            for cname, ops in self.comps.items()
        }
        self._memo: Dict[str, CostSummary] = {}

    # ------------------------------------------------------------------
    def cost(self) -> CostSummary:
        return self._comp_cost(self.entry)

    def _comp_cost(self, cname: str) -> CostSummary:
        if cname in self._memo:
            return self._memo[cname]
        total = CostSummary()
        for op in self.comps.get(cname, []):
            total.add(self._op_cost(cname, op))
        self._memo[cname] = total
        return total

    # ------------------------------------------------------------------
    def _called(self, attrs: str, key: str) -> List[str]:
        m = re.search(key + r"=\{([^}]*)\}", attrs)
        if m:
            return re.findall(r"%([\w.\-]+)", m.group(1))
        m = re.search(key + r"=%([\w.\-]+)", attrs)
        return [m.group(1)] if m else []

    def _op_cost(self, cname: str, op: Op) -> CostSummary:
        oc = op.opcode
        if oc == "while":
            trip = 1
            m = _TRIP_RE.search(op.attrs)
            if m:
                trip = int(m.group(1))
            body = self._called(op.attrs, "body")
            cond = self._called(op.attrs, "condition")
            c = CostSummary()
            for b in body:
                c.add(self._comp_cost(b).scaled(trip))
            for cd in cond:
                c.add(self._comp_cost(cd).scaled(trip))
            return c
        if oc == "fusion":
            c = CostSummary()
            slice_adjust = 0.0
            for called in self._called(op.attrs, "calls"):
                inner = self._comp_cost(called)
                # fused internal traffic never leaves VMEM: keep FLOPs and
                # collectives, charge bytes at the fusion boundary only.
                c.add(CostSummary(inner.flops, 0.0, dict(inner.collectives)))
                slice_adjust += self._dus_adjustment(called)
            c.bytes += max(self._io_bytes(cname, op) - slice_adjust, 0.0)
            return c
        if oc in ("call", "async-start"):
            c = CostSummary()
            for called in self._called(op.attrs, "calls") or \
                    self._called(op.attrs, "to_apply"):
                c.add(self._comp_cost(called))
            return c
        if oc == "conditional":
            branches = re.findall(r"%([\w.\-]+)", op.attrs)
            if not branches:
                return CostSummary()
            costs = [self._comp_cost(b) for b in branches
                     if b in self.comps]
            if not costs:
                return CostSummary()
            worst = max(costs, key=lambda c: c.flops + c.bytes)
            return worst
        if oc in COLLECTIVE_KINDS or any(oc == k + "-start"
                                         for k in COLLECTIVE_KINDS):
            kind = oc.replace("-start", "")
            moved = max(self._operand_bytes(cname, op), _type_bytes(op.type_str))
            c = CostSummary(0.0, self._io_bytes(cname, op), {kind: float(moved)})
            return c
        if oc == "dot":
            return CostSummary(self._dot_flops(cname, op),
                               self._io_bytes(cname, op))
        if oc == "convolution":
            return CostSummary(self._conv_flops(cname, op),
                               self._io_bytes(cname, op))
        if oc in _ELEMENTWISE_FLOP_OPS:
            return CostSummary(float(_type_elems(op.type_str)),
                               self._io_bytes(cname, op))
        if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            return CostSummary()
        if oc == "dynamic-slice":
            # reads only the extracted window (XLA does not touch the rest
            # of the buffer): read + write of the slice
            return CostSummary(0.0, 2.0 * _type_bytes(op.type_str))
        if oc == "dynamic-update-slice":
            # in-place on an aliased buffer: read + write the UPDATE window
            table = self._shape.get(cname, {})
            upd = _type_bytes(table.get(op.operands[1], "")) \
                if len(op.operands) > 1 else 0
            return CostSummary(0.0, 2.0 * upd)
        # copies, reshape/transpose/broadcast, gather, scatter, iota, pad,
        # concatenate ... : bytes only
        return CostSummary(0.0, self._io_bytes(cname, op))

    # ------------------------------------------------------------------
    def _dus_adjustment(self, called: str) -> float:
        """Fusions rooted in dynamic-(update-)slice run in place on the
        aliased buffer (scan xs/ys threading, KV-cache writes): the fusion
        boundary must charge the moved WINDOW, not the whole buffer.
        Returns the byte amount to subtract from the boundary I/O."""
        ops = self.comps.get(called, [])
        if not ops:
            return 0.0
        root = next((o for o in ops if o.is_root), ops[-1])
        table = self._shape.get(called, {})
        out_bytes = _type_bytes(root.type_str)
        # any DUS whose buffer is (close to) the fusion output is the scan
        # xs/ys threading or a KV-cache write — in place on the aliased
        # buffer. Epilogue converts over the same buffer are CPU-backend
        # f32-promotion artifacts (TPU keeps bf16 dots native), so the
        # buffer read+write is subtracted and only the window is charged.
        adjust = 0.0
        best_dus = 0.0
        for o in ops:
            if o.opcode == "dynamic-update-slice":
                buf = _type_bytes(o.type_str)
                if buf * 2 < out_bytes:    # small DUS inside a big fusion
                    continue
                upd = _type_bytes(table.get(o.operands[1], "")) \
                    if len(o.operands) > 1 else 0
                best_dus = max(best_dus, 2.0 * buf - 2.0 * upd)
            elif o.opcode == "dynamic-slice":
                # reading a window of a big (scan xs / cache) buffer that
                # is a fusion operand: only the window is touched
                src = max((_type_bytes(table.get(x, ""))
                           for x in o.operands), default=0)
                sl = _type_bytes(o.type_str)
                if src >= 2 * sl:
                    adjust += max(float(src - sl), 0.0)
        return adjust + best_dus

    def _operand_bytes(self, cname: str, op: Op) -> int:
        table = self._shape.get(cname, {})
        return sum(_type_bytes(table.get(o, "")) for o in op.operands)

    def _io_bytes(self, cname: str, op: Op) -> float:
        return float(self._operand_bytes(cname, op) + _type_bytes(op.type_str))

    def _dot_flops(self, cname: str, op: Op) -> float:
        out_elems = _type_elems(op.type_str)
        table = self._shape.get(cname, {})
        lhs = table.get(op.operands[0], "") if op.operands else ""
        dims = _first_shape_dims(lhs)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        contract = 1
        if m and dims:
            for idx in m.group(1).split(","):
                if idx:
                    contract *= dims[int(idx)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, cname: str, op: Op) -> float:
        out_elems = _type_elems(op.type_str)
        table = self._shape.get(cname, {})
        rhs = table.get(op.operands[1], "") if len(op.operands) > 1 else ""
        kdims = _first_shape_dims(rhs)
        if not kdims:
            return 0.0
        # kernel = spatial... x in_ch x out_ch (dim order varies); flops =
        # 2 x out_elems x prod(kernel)/out_ch. Use the largest dim as out_ch
        # guess only when dim_labels absent — here we parse dim_labels.
        m = re.search(r"dim_labels=([\w?]+)_([\w?]+)->", op.attrs)
        kprod = 1
        for d in kdims:
            kprod *= d
        if m:
            rhs_labels = m.group(2)          # e.g. "io01" / "01io"
            o_idx = rhs_labels.index("o")
            kprod //= max(kdims[o_idx], 1)
        return 2.0 * out_elems * kprod


def analyze_text(hlo_text: str) -> CostSummary:
    return HloCostModel(hlo_text).cost()


def layer_attribution(hlo_text: str,
                      num_layers: int) -> tuple[List[CostSummary],
                                                CostSummary]:
    """Attribute a compiled model's cost to its ``num_layers``
    partitionable layers (CostModel v2's optional re-derivation of the
    per-layer FLOP/byte columns from real compiler output).

    Our models scan over depth (``segment_forward``'s masked
    ``lax.scan``), so the compiled module contains a while loop whose
    recorded trip count equals the layer count; one trip of its body
    (plus cond) IS one layer. Returns ``(per_layer, residual)``:
    ``per_layer[l]`` the cost of layer ``l`` (identical across a scanned
    stack — the loop body is shared) and ``residual`` everything outside
    the layer loop (embedding/head, data movement). When no matching
    loop exists (an unrolled/heterogeneous model), the total is split
    evenly with a zero residual — still loop-aware in aggregate."""
    model = HloCostModel(hlo_text)
    total = model.cost()
    best: Optional[CostSummary] = None
    for ops in model.comps.values():
        for op in ops:
            if op.opcode != "while":
                continue
            m = _TRIP_RE.search(op.attrs)
            if not m or int(m.group(1)) != num_layers:
                continue
            body = CostSummary()
            for b in model._called(op.attrs, "body"):
                body.add(model._comp_cost(b))
            for cd in model._called(op.attrs, "condition"):
                body.add(model._comp_cost(cd))
            if best is None or body.flops > best.flops:
                best = body
    if best is None:
        even = total.scaled(1.0 / max(num_layers, 1))
        return [even] * num_layers, CostSummary()
    residual = CostSummary(
        max(total.flops - num_layers * best.flops, 0.0),
        max(total.bytes - num_layers * best.bytes, 0.0),
        {k: max(v - num_layers * best.collectives.get(k, 0.0), 0.0)
         for k, v in total.collectives.items()})
    return [best] * num_layers, residual


def summarize(hlo_text: str) -> dict:
    c = analyze_text(hlo_text)
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": c.collective_bytes,
            "collectives": dict(c.collectives)}
