"""Three-term roofline from compiled dry-run artifacts (no real hardware).

  compute term    = HLO_FLOPs        / (chips x peak_FLOP/s)
  memory term     = HLO_bytes        / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

FLOPs / bytes / collective-bytes come from ``repro.roofline.hlo_cost`` —
a loop-aware walk of the compiled HLO. (The stock
``compiled.cost_analysis()`` counts every while-loop body once, which
under-reports any scanned layer stack by the trip count; see
EXPERIMENTS.md §Roofline "methodology".) The compiled module is the SPMD
per-device partition, so parsed numbers are per-device; we report global
(= per-device x chips) and divide back inside the terms.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.hlo_cost import analyze_text


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float             # global (per-device x chips)
    hlo_gbytes: float
    coll_gbytes: float            # per-device moved bytes (summed kinds)
    coll_breakdown: Dict[str, float]
    model_gflops: Optional[float] = None   # analytic 6ND / 2ND
    temp_bytes_per_device: Optional[float] = None
    arg_bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_gflops * 1e9 / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_gbytes * 1e9 / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # coll bytes are already per-device; each device pushes its share
        # through its own links
        return self.coll_gbytes * 1e9 / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_frac(self) -> Optional[float]:
        if self.model_gflops is None or self.hlo_gflops == 0:
            return None
        return self.model_gflops / self.hlo_gflops

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flop_frac=self.useful_flop_frac)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: Optional[float] = None) -> Roofline:
    cost = analyze_text(compiled.as_text())
    temp = arg = None
    try:
        ma = compiled.memory_analysis()
        temp = float(ma.temp_size_in_bytes)
        arg = float(ma.argument_size_in_bytes)
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops=cost.flops * chips / 1e9,
        hlo_gbytes=cost.bytes * chips / 1e9,
        coll_gbytes=cost.collective_bytes / 1e9,
        coll_breakdown={k: v / 1e9 for k, v in cost.collectives.items()},
        model_gflops=(model_flops / 1e9) if model_flops else None,
        temp_bytes_per_device=temp, arg_bytes_per_device=arg,
    )


# ---------------------------------------------------------------------------
# CostModel v2 bridges (DESIGN.md §9): serving profiles built from the
# mesh hardware constants, and per-layer cost columns re-derived from
# compiled HLO instead of the analytic Table II math.

def tpu_server_profile(chips: int = 1) -> "ServerProfile":
    """A ``ServerProfile`` whose compute/memory rates are the TPU v5e
    roofline denominators (``launch.mesh``): t_server = O2·gamma/f =
    2·O2/PEAK (a MAC is 2 FLOPs), mem_bw the HBM stream. Feed it to
    ``RooflineCost`` to price the deployment view of DESIGN.md §3."""
    from repro.core.cost_model import ServerProfile
    return ServerProfile(f_clock=PEAK_FLOPS_BF16 * chips / 2.0, gamma=1.0,
                         mem_bw=HBM_BW * chips)


def tpu_device_profile(flops_frac: float = 1.0,
                       bw_frac: float = 1.0) -> "DeviceProfile":
    """A single-chip accelerator ``DeviceProfile`` from the same mesh
    constants; ``flops_frac``/``bw_frac`` derate it to an edge-class
    part (an edge TPU is a fraction of a datacenter chip). ``kappa`` is
    zeroed: the paper's CPU-clock energy model (J/cycle/Hz²) is
    meaningless at accelerator f_clock values — it would charge ~0.3 J
    per MAC and drown every time term. Accelerator energy is not
    modeled; use ``ObjectiveWeights(tau=...)`` against a profile with a
    physical kappa if energy matters."""
    from repro.core.cost_model import DeviceProfile
    return DeviceProfile(f_clock=PEAK_FLOPS_BF16 * flops_frac / 2.0,
                         gamma=1.0, kappa=0.0, mem_bw=HBM_BW * bw_frac)


def layer_costs_from_hlo(compiled_or_text, num_layers: int,
                         layer_w_bytes=None,
                         spread_residual: bool = True) -> list:
    """Per-layer cost overrides for ``ModelBackend
    .set_layer_cost_overrides`` from a compiled forward: each entry
    ``{"o": MACs, "act_bytes": bytes}`` at the compiled batch (the
    backend rescales per request batch). FLOPs halve into MACs; the
    residual (embedding/head, outside the layer loop) is spread evenly
    unless ``spread_residual`` is False.

    The HLO byte count of a layer includes its WEIGHT-stream operand
    reads, which are batch-invariant and already priced separately
    (``LayerSpec.w_bytes16`` on the server tail, the deployed-bit
    footprint on the device) — pass ``layer_w_bytes`` (per-layer bf16
    weight bytes, e.g. ``[sp.w_bytes16 for sp in backend.layer_specs()]``)
    to subtract them, leaving ``act_bytes`` the genuinely batch-scaled
    activation traffic. Without it the weight stream would be double
    counted AND mis-scaled by the request batch."""
    from repro.roofline.hlo_cost import layer_attribution
    text = compiled_or_text if isinstance(compiled_or_text, str) \
        else compiled_or_text.as_text()
    per_layer, residual = layer_attribution(text, num_layers)
    rf = residual.flops / num_layers if spread_residual else 0.0
    rb = residual.bytes / num_layers if spread_residual else 0.0
    if layer_w_bytes is None:
        layer_w_bytes = [0.0] * num_layers
    return [{"o": (c.flops + rf) / 2.0,
             "act_bytes": max(c.bytes + rb - float(wb), 0.0)}
            for c, wb in zip(per_layer, layer_w_bytes)]


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training (fwd 2ND + bwd 4ND), 2*N*D
    forward-only, with N = active params (MoE top-k)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1          # decode: one token
    return 2.0 * n_active * tokens


def save_record(roofline: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(roofline.to_dict(), f, indent=2)


def load_records(record_dir: str):
    import glob
    import os
    out = []
    for p in sorted(glob.glob(os.path.join(record_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out
