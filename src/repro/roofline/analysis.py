"""Three-term roofline from compiled dry-run artifacts (no real hardware).

  compute term    = HLO_FLOPs        / (chips x peak_FLOP/s)
  memory term     = HLO_bytes        / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

FLOPs / bytes / collective-bytes come from ``repro.roofline.hlo_cost`` —
a loop-aware walk of the compiled HLO. (The stock
``compiled.cost_analysis()`` counts every while-loop body once, which
under-reports any scanned layer stack by the trip count; see
EXPERIMENTS.md §Roofline "methodology".) The compiled module is the SPMD
per-device partition, so parsed numbers are per-device; we report global
(= per-device x chips) and divide back inside the terms.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.hlo_cost import analyze_text


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float             # global (per-device x chips)
    hlo_gbytes: float
    coll_gbytes: float            # per-device moved bytes (summed kinds)
    coll_breakdown: Dict[str, float]
    model_gflops: Optional[float] = None   # analytic 6ND / 2ND
    temp_bytes_per_device: Optional[float] = None
    arg_bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_gflops * 1e9 / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_gbytes * 1e9 / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # coll bytes are already per-device; each device pushes its share
        # through its own links
        return self.coll_gbytes * 1e9 / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_frac(self) -> Optional[float]:
        if self.model_gflops is None or self.hlo_gflops == 0:
            return None
        return self.model_gflops / self.hlo_gflops

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flop_frac=self.useful_flop_frac)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: Optional[float] = None) -> Roofline:
    cost = analyze_text(compiled.as_text())
    temp = arg = None
    try:
        ma = compiled.memory_analysis()
        temp = float(ma.temp_size_in_bytes)
        arg = float(ma.argument_size_in_bytes)
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops=cost.flops * chips / 1e9,
        hlo_gbytes=cost.bytes * chips / 1e9,
        coll_gbytes=cost.collective_bytes / 1e9,
        coll_breakdown={k: v / 1e9 for k, v in cost.collectives.items()},
        model_gflops=(model_flops / 1e9) if model_flops else None,
        temp_bytes_per_device=temp, arg_bytes_per_device=arg,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training (fwd 2ND + bwd 4ND), 2*N*D
    forward-only, with N = active params (MoE top-k)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1          # decode: one token
    return 2.0 * n_active * tokens


def save_record(roofline: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(roofline.to_dict(), f, indent=2)


def load_records(record_dir: str):
    import glob
    import os
    out = []
    for p in sorted(glob.glob(os.path.join(record_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out
