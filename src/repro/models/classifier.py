"""The paper's own evaluation models: MLP / CNN classifiers (§V, Fig. 4).

These are the models QPART's simulation platform quantizes and partitions;
``layer_activations`` exposes every layer's input/output so the noise
calibration (Alg. 1 steps 7–9) can probe intermediate layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.classifier import ClassifierConfig, DenseSpec
from repro.models.common import dense_init


def init_classifier(key, cfg: ClassifierConfig):
    params = []
    keys = jax.random.split(key, cfg.num_layers)
    for k, spec in zip(keys, cfg.layers):
        if isinstance(spec, DenseSpec):
            params.append({"w": dense_init(k, (spec.in_dim, spec.out_dim)),
                           "b": jnp.zeros((spec.out_dim,), jnp.float32)})
        else:
            params.append({"w": dense_init(
                k, (spec.f1, spec.f2, spec.c_in, spec.c_out), in_axis=2),
                "b": jnp.zeros((spec.c_out,), jnp.float32)})
    return params


def _apply_layer(spec, p, x, last: bool):
    if isinstance(spec, DenseSpec):
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        x = x @ p["w"] + p["b"]
    else:
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(spec.stride, spec.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = x + p["b"]
        if spec.pool > 1:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, spec.pool, spec.pool, 1), (1, spec.pool, spec.pool, 1),
                "VALID")
    if not last:
        x = jax.nn.relu(x)
    return x


import numpy as _np


def _ensure_batched(x, cfg: ClassifierConfig):
    """Accept (B, *input_shape), (B, flattened) or a single unbatched image."""
    if x.ndim == len(cfg.input_shape) and x.size == int(_np.prod(cfg.input_shape)):
        x = x[None]
    return x


def classifier_forward(params, cfg: ClassifierConfig, x):
    """x (B, *input_shape) or (B, flat) -> logits (B, num_classes)."""
    x = _ensure_batched(x, cfg)
    if isinstance(cfg.layers[0], DenseSpec):
        x = x.reshape(x.shape[0], -1)
    for i, (spec, p) in enumerate(zip(cfg.layers, params)):
        x = _apply_layer(spec, p, x, last=i == cfg.num_layers - 1)
    return x


def layer_activations(params, cfg: ClassifierConfig, x):
    """Returns the list of activations entering each layer (x_1..x_L) plus
    the logits — what the QPART noise calibration probes."""
    x = _ensure_batched(x, cfg)
    if isinstance(cfg.layers[0], DenseSpec):
        x = x.reshape(x.shape[0], -1)
    acts = []
    for i, (spec, p) in enumerate(zip(cfg.layers, params)):
        acts.append(x)
        x = _apply_layer(spec, p, x, last=i == cfg.num_layers - 1)
    return acts, x


def forward_from_layer(params, cfg: ClassifierConfig, x, start: int):
    """Run layers start..L-1 on an intermediate activation (server-side
    segment inference after the partition point)."""
    for i in range(start, cfg.num_layers):
        x = _apply_layer(cfg.layers[i], params[i], x,
                         last=i == cfg.num_layers - 1)
    return x


# Public single-layer entry points for the serving backend
# (repro.serving.backends.classifier) — partitioned execution applies
# layers one at a time with swapped (quantized / pruned) params.
apply_layer = _apply_layer
ensure_batched = _ensure_batched
