"""Shared model primitives: norms, initializers, embeddings, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# Norms. Params are dicts so quantization / sharding rules can address them.

def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(dt)


def norm_init(kind: str, dim: int):
    return rmsnorm_init(dim) if kind == "rmsnorm" else layernorm_init(dim)


def norm_apply(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# Per-head RMSNorm over head_dim (qk-norm, Qwen3/OLMoE style).
def headnorm(scale, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)
