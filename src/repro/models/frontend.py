"""Modality frontend stubs (per assignment: the ViT / EnCodec encoders are
NOT implemented — ``input_specs`` feeds precomputed frame/patch embeddings
of the right shape, and these helpers generate matching synthetic tensors
for smoke tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stub_embeddings(key, cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    """Precomputed frontend output: (B, S, D) embeddings.

    audio  -> EnCodec frame embeddings (MusicGen consumes codebook tokens;
              the decoder sees summed codebook embeddings, same shape).
    vision -> ViT patch embeddings after the projector (Qwen2-VL).
    """
    scale = cfg.d_model ** -0.5
    return scale * jax.random.normal(key, (batch, seq, cfg.d_model), dtype)


def mrope_positions(batch: int, seq: int, image_grid=(16, 16)):
    """Qwen2-VL M-RoPE position triples (t, h, w) for a text+image stream.

    First ``h*w`` tokens are image patches laid out on a 2-D grid at t=0,
    the rest are text tokens with t advancing and h=w=t (Qwen2-VL rule).
    """
    gh, gw = image_grid
    n_img = min(gh * gw, seq)
    idx = jnp.arange(seq)
    img_h = (idx % (gh * gw)) // gw
    img_w = idx % gw
    text_t = idx - n_img + 1  # starts at 1 after the image
    is_text = idx >= n_img
    t = jnp.where(is_text, text_t, 0)
    h = jnp.where(is_text, text_t, img_h)
    w = jnp.where(is_text, text_t, img_w)
    pos = jnp.stack([t, h, w]).astype(jnp.int32)            # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
