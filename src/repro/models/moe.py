"""Top-k mixture-of-experts with capacity-based einsum dispatch.

Mesh-TF / Switch-Transformer formulation: tokens are split into groups, a
dispatch one-hot of shape (G, GS, E, C) routes each token to at most k
expert-capacity slots, and two einsums move activations to expert-major
layout (E, G, C, D) and back. Under pjit with experts sharded on the
``model`` axis and groups on ``data``, XLA lowers the dispatch/combine
einsums to all-to-alls — the canonical expert-parallel schedule.

Group size is kept small (default 128 tokens) so the dispatch tensor stays
~`T·GS·k` elements: with GS=128 that is <2 bytes/token/capacity-slot of
bf16, a few MB per device at the assigned shapes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, silu

DEFAULT_GROUP_SIZE = 128


def moe_init(key, cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    p = {"w_router": dense_init(ks[0], (d, e))}
    if cfg.mlp == "swiglu":
        p["w_gate"] = dense_init(ks[1], (e, d, f), in_axis=1)
        p["w_up"] = dense_init(ks[2], (e, d, f), in_axis=1)
        p["w_down"] = dense_init(ks[3], (e, f, d), in_axis=1)
    else:
        p["w_up"] = dense_init(ks[1], (e, d, f), in_axis=1)
        p["w_down"] = dense_init(ks[2], (e, f, d), in_axis=1)
    return p


def capacity_for(group_size: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = math.ceil(group_size * top_k / num_experts * capacity_factor)
    return max(top_k if group_size == 1 else 4, (c + 3) // 4 * 4)


def _route(logits, top_k: int, capacity: int):
    """logits (G, GS, E) -> dispatch (G,GS,E,C) bool-ish, combine (G,GS,E,C),
    aux metrics. Pure function of router logits: top-k with per-expert
    position assignment, tokens over capacity are dropped (residual path
    carries them)."""
    g, gs, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)       # (G,GS,K)

    # Normalize the k gates (Mixtral/DBRX convention).
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((g, gs, e, capacity), jnp.bfloat16)
    combine = jnp.zeros((g, gs, e, capacity), jnp.float32)
    # running token count per (group, expert) across the k rounds
    counts = jnp.zeros((g, e), jnp.int32)
    for kk in range(top_k):
        eh = jax.nn.one_hot(expert_ids[..., kk], e, dtype=jnp.int32)  # (G,GS,E)
        pos = jnp.cumsum(eh, axis=1) - 1 + counts[:, None, :]          # slot idx
        counts = counts + eh.sum(axis=1)
        pos_tok = jnp.take_along_axis(
            pos, expert_ids[..., kk:kk + 1], axis=-1)[..., 0]          # (G,GS)
        keep = pos_tok < capacity
        ph = jax.nn.one_hot(pos_tok, capacity, dtype=jnp.float32)      # (G,GS,C)
        sel = (eh.astype(jnp.float32) * keep[..., None].astype(jnp.float32))
        contrib = sel[..., None] * ph[..., None, :]                    # (G,GS,E,C)
        dispatch = dispatch + contrib.astype(jnp.bfloat16)
        combine = combine + gate_vals[..., kk, None, None] * contrib

    # aux: Switch load-balance loss + router z-loss
    density = dispatch.sum(axis=(1, 3)) / gs                            # (G,E) frac
    mean_prob = probs.mean(axis=1)                                      # (G,E)
    lb_loss = e * jnp.mean(jnp.sum(density.astype(jnp.float32) * mean_prob, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)))
    dropped = 1.0 - dispatch.astype(jnp.float32).sum() / (g * gs * top_k)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return dispatch, combine, aux


def moe_apply(params, cfg, x, group_size: int = DEFAULT_GROUP_SIZE):
    """x (B, S, D) -> (out, aux)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    gs = min(group_size, s) if s > 1 else 1
    gcount = t // gs
    xg = x.reshape(gcount, gs, d)

    logits = xg @ params["w_router"].astype(x.dtype)                    # (G,GS,E)
    cap = capacity_for(gs, m.num_experts, m.top_k, m.capacity_factor)
    dispatch, combine, aux = _route(logits, m.top_k, cap)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xg)
    if cfg.mlp == "swiglu":
        h = silu(jnp.einsum("egcd,edf->egcf", expert_in,
                            params["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("egcd,edf->egcf", expert_in,
                           params["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", expert_in,
                                   params["w_up"].astype(x.dtype)))
    expert_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"].astype(x.dtype))
    out = jnp.einsum("egcd,gsec->gsd", expert_out, combine.astype(x.dtype))
    return out.reshape(b, s, d), aux
