"""GQA attention with flash-style chunked computation, sliding windows,
qk-norm, RoPE variants and a ring-buffer KV cache for decode.

Memory discipline: prefill/train attention never materializes the (S, S)
score matrix — an outer ``lax.scan`` over query blocks and an inner scan
(full attention) or a dynamic-slice window (sliding-window attention) keep
the live score tile at (B, H, BQ, BK). This is the pure-JAX flash-attention
analogue the Pallas kernel in ``repro/kernels`` replaces on real TPUs.

TP layout (DESIGN.md §5): query-side weights are stored FLAT over a
head dim padded to the model-axis size — ``wq (D, H_pad, hd)``,
``wo (H_pad, hd, D)`` with ``H_pad = KV_pad * G_pad % tp_pad == 0``
(``ModelConfig.padded_heads``). The flat dim shards evenly under jit's
divisibility rule, and GSPMD propagates the sharding through the grouped
``(H_pad) -> (KV_pad, G_pad)`` reshape as a tiled sub-grid (verified in
the dry-run HLO: zero collectives, 1/mesh flops). Padded heads are
masked to exact zero before the output projection, so the computed
function IS the unpadded architecture — under training too (the mask is
applied every step, not just at init).

Why not a fused (D, H*hd) projection: when H % mesh != 0 GSPMD loses the
sharding at the (H*hd)->(H, hd) reshape and silently replicates the S^2
score computation on every model-axis device — a 16x compute blowup we
measured in the smollm dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import rope as rope_lib
from repro.models.common import dense_init, headnorm

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


# ---------------------------------------------------------------------------
# Params

def attn_init(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    kvp, gp = cfg.padded_heads()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, kvp * gp, hd)),
        "wk": dense_init(ks[1], (d, kvp, hd)),
        "wv": dense_init(ks[2], (d, kvp, hd)),
        "wo": dense_init(ks[3], (kvp * gp, hd, d), in_axis=1),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((kvp * gp, hd), jnp.float32)
        p["bk"] = jnp.zeros((kvp, hd), jnp.float32)
        p["bv"] = jnp.zeros((kvp, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _head_mask(cfg, dtype):
    """(KV_pad, G_pad, 1) 1.0 on real heads, 0.0 on padding (or None)."""
    kv = cfg.num_kv_heads
    g = max(cfg.num_heads // max(kv, 1), 1)
    kvp, gp = cfg.padded_heads()
    if (kvp, gp) == (kv, g):
        return None
    mask = jnp.zeros((kvp, gp, 1), dtype).at[:kv, :g, :].set(1.0)
    return mask


def _qkv_proj(x, w):
    """One QKV projection: dense einsum, or the dequantize-fused qmatmul
    kernel when the weight arrives as a quantized wire struct (the
    kernel-routed serving representation — repro/kernels/ops.qdense)."""
    from repro.kernels import ops
    if ops.is_wire_struct(w):
        return ops.qdense(x, w)                    # (B,S,*w.shape[1:])
    return jnp.einsum("bsd,d...->bs...", x, w.astype(x.dtype))


def _project_qkv(params, cfg, x):
    """x (B,S,D) -> q (B,S,KVp,Gp,hd), k/v (B,S,KVp,hd)."""
    dt = x.dtype
    kvp, gp = cfg.padded_heads()
    b, s, _ = x.shape
    q = _qkv_proj(x, params["wq"])
    k = _qkv_proj(x, params["wk"])
    v = _qkv_proj(x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = headnorm(params["q_norm"], q)
        k = headnorm(params["k_norm"], k)
    q = q.reshape(b, s, kvp, gp, q.shape[-1])
    return q, k, v


def _out_proj(params, cfg, out, dtype):
    """out (B,S,KVp,Gp,hd) -> (B,S,D) (row-parallel psum). Padded heads
    are zero-masked first so they never contribute, even after training
    has touched the padded wo rows."""
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask
    b, s, kvp, gp, hd = out.shape
    out = out.reshape(b, s, kvp * gp, hd)
    from repro.kernels import ops
    if ops.is_wire_struct(params["wo"]):
        return ops.qdense(out, params["wo"], n_contract=2, out_dtype=dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# Flash-style blocked causal attention (full context).

def _blocked_causal_attention(q, k, v, block_q, block_k,
                              skip_masked_blocks: bool = None):
    """q (B,S,KV,G,hd), k/v (B,S,KV,hd) -> (B,S,KV,G,hd). Causal.

    ``skip_masked_blocks`` (§Perf hillclimb #1): the scan-over-scan form
    computes every (q_block, k_block) pair — including the strictly-upper
    triangle that the causal mask zeroes entirely, i.e. ~2x the useful
    score work. Here the outer loop is unrolled (nq is small and static)
    and each q block scans only its <= i causal k blocks, halving both the
    score FLOPs and the materialized score bytes. Exact same math: the
    skipped blocks contributed exp(-inf) = 0 to every softmax sum.
    """
    if skip_masked_blocks is None:       # env override for A/B roofline runs
        import os
        skip_masked_blocks = os.environ.get("REPRO_CAUSAL_SKIP", "1") != "0"
    b, s, kvh, g, hd = q.shape
    scale = hd ** -0.5
    nq, nk = s // block_q, s // block_k
    # (nq, B, BQ, KV, G, hd)
    qb = q.reshape(b, nq, block_q, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, nk, block_k, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, block_k, kvh, hd).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(s).reshape(nq, block_q)
    k_pos = jnp.arange(s).reshape(nk, block_k)

    def make_q_step(n_kv):
        def q_step(_, qi):
            qblk, qp = qi                       # (B,BQ,KV,G,hd), (BQ,)

            def kv_step(carry, ki):
                acc, m, l = carry
                kblk, vblk, kp = ki
                sc = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
                mask = qp[:, None] >= kp[None, :]
                sc = jnp.where(mask[None, None, None], sc, NEG_INF)
                m_new = jnp.maximum(m, sc.max(axis=-1))
                p = jnp.exp(sc - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + p.sum(axis=-1)
                pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vblk.dtype),
                                vblk, preferred_element_type=jnp.float32)
                acc = acc * corr[..., None] + pv
                return (acc, m_new, l), None

            acc0 = jnp.zeros((b, kvh, g, block_q, hd), jnp.float32)
            m0 = jnp.full((b, kvh, g, block_q), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, kvh, g, block_q), jnp.float32)
            (acc, _, l), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0),
                (kb[:n_kv], vb[:n_kv], k_pos[:n_kv]))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return None, out.transpose(0, 3, 1, 2, 4)   # (B,BQ,KV,G,hd)

        return q_step

    if skip_masked_blocks:
        outs = []
        for i in range(nq):
            # k blocks whose start position <= last q position of block i
            n_kv = ((i + 1) * block_q - 1) // block_k + 1
            _, out_i = make_q_step(n_kv)(None, (qb[i], q_pos[i]))
            outs.append(out_i)
        out = jnp.stack(outs)
    else:
        _, out = jax.lax.scan(make_q_step(nk), None, (qb, q_pos))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kvh, g, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Sliding-window blocked attention: per query block, only a dynamic slice of
# K/V of static size (window + block_q) is touched -> O(S * window) compute.

def _windowed_attention(q, k, v, window, block_q):
    b, s, kvh, g, hd = q.shape
    scale = hd ** -0.5
    nq = s // block_q
    span = window + block_q                       # static slice size
    qb = q.reshape(b, nq, block_q, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    # pad K/V on the left so every slice is in-bounds
    pad = [(0, 0), (span - block_q, 0), (0, 0), (0, 0)]
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)

    def q_step(_, qi):
        qblk, idx = qi                            # block index
        start = idx * block_q                     # slice start in padded buf
        kblk = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        qpos = idx * block_q + jnp.arange(block_q)
        kpos = idx * block_q - (span - block_q) + jnp.arange(span)
        sc = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                        preferred_element_type=jnp.float32) * scale
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (qpos[:, None] - kpos[None, :] < window) & (kpos[None, :] >= 0)
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vblk.dtype), vblk,
                         preferred_element_type=jnp.float32)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kvh, g, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Public entry points.

def _attention_impl() -> str:
    """'blocked' (pure JAX, default) | 'flash' (Pallas kernel; on CPU it
    runs in interpret mode — correctness harness, not a perf path)."""
    import os
    impl = os.environ.get("REPRO_ATTN_IMPL", "")
    if impl:
        return impl
    return "flash" if jax.default_backend() == "tpu" else "blocked"


def attention_forward(params, cfg, x, positions, block_q=DEFAULT_BLOCK_Q,
                      block_k=DEFAULT_BLOCK_K):
    """Train/prefill attention. x (B,S,D) -> (B,S,D)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x)
    q = rope_lib.apply_rope(cfg.rope, q, positions, cfg.rope_theta)
    k = rope_lib.apply_rope(cfg.rope, k, positions, cfg.rope_theta)
    bq = min(block_q, s)
    bk = min(block_k, s)
    if cfg.sliding_window is not None and s > cfg.sliding_window:
        out = _windowed_attention(q, k, v, cfg.sliding_window, bq)
    elif _attention_impl() == "flash":
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=jax.default_backend() != "tpu")
    else:
        out = _blocked_causal_attention(q, k, v, bq, bk)
    return _out_proj(params, cfg, out, x.dtype)


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Ring-buffer KV cache for one layer. For sliding-window configs the
    buffer holds only ``window`` entries. Holds KV_pad heads (padding is
    dead weight only when KV needed padding — documented in the roofline)."""
    hd = cfg.resolved_head_dim()
    kvp, _ = cfg.padded_heads()
    buf = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, buf, kvp, hd), dtype),
        "v": jnp.zeros((batch, buf, kvp, hd), dtype),
    }


def attention_decode(params, cfg, x, cache, pos):
    """One-token decode. x (B,1,D); pos scalar int32 (same for the batch).

    Returns (out (B,1,D), updated cache). K/V are stored post-RoPE at
    absolute positions, so the ring buffer needs no re-rotation.
    """
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(params, cfg, x)
    q = rope_lib.apply_rope(cfg.rope, q, positions, cfg.rope_theta)
    k = rope_lib.apply_rope(cfg.rope, k, positions, cfg.rope_theta)

    buf = cache["k"].shape[1]
    slot = (pos % buf).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    # single-query flash attention over the ring buffer, dispatched by
    # REPRO_KERNELS (repro/kernels/ops): reference = the pure-jnp scan
    # math (kernels.ref.decode_attention_ref — bit-for-bit the pre-PR-9
    # inline path, the CPU default), kernel/interpret = the Pallas
    # decode kernel (kernels/decode_attention.py)
    from repro.kernels import ops
    out = ops.decode_attention(q[:, 0], ck, cv, pos)
    out = out[:, None].astype(x.dtype)             # (B,1,KVp,Gp,hd)
    return _out_proj(params, cfg, out, x.dtype), {"k": ck, "v": cv}
