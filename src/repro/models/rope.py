"""Rotary position embedding variants.

- ``rope``   : standard NTK-free llama RoPE over the full head dim.
- ``rope2d`` : GLM-style partial rotary — only the first half of the head
               dims rotate, the second half is passthrough.
- ``mrope``  : Qwen2-VL multimodal RoPE — the head dim is split into three
               sections (t, h, w) each rotated by its own position stream.
               For pure-text tokens all three streams carry the same
               positions, which makes mrope degenerate to rope (this is the
               property Qwen2-VL relies on and that our tests check).

All functions take ``positions`` of shape (B, S) (int32) except mrope which
accepts (3, B, S); text callers pass the broadcasted triple.
"""
from __future__ import annotations

import jax.numpy as jnp

# M-RoPE section split of (head_dim // 2) angle slots, as fractions.
MROPE_SECTIONS = (1 / 4, 3 / 8, 3 / 8)   # t, h, w


def _angles(positions, dim: int, theta: float):
    """positions (..., S) -> angles (..., S, dim//2)."""
    half = dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions[..., None].astype(jnp.float32) * freq


def _rotate(x, ang):
    """x (..., S, *head_dims, D), ang (..., S, D//2): rotate (even, odd)
    pairs, broadcasting over however many head dims sit between S and D
    (grouped GQA layout uses two: KV and G)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    while cos.ndim < x1.ndim:                 # insert head axes before D//2
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


def apply_rope(kind: str, x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (3, B, S) for mrope."""
    d = x.shape[-1]
    if kind == "none":
        return x
    if kind == "rope":
        return _rotate(x, _angles(positions, d, theta))
    if kind == "rope2d":
        half = d // 2
        rot, keep = x[..., :half], x[..., half:]
        rot = _rotate(rot, _angles(positions, half, theta))
        return jnp.concatenate([rot, keep], axis=-1)
    if kind == "mrope":
        if positions.ndim == 2:       # text-only caller: broadcast
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        half = d // 2
        sizes = [int(round(f * half)) for f in MROPE_SECTIONS]
        sizes[-1] = half - sizes[0] - sizes[1]
        # Build per-slot positions by section, then a single rotate.
        pos_t, pos_h, pos_w = positions[0], positions[1], positions[2]
        seg = jnp.concatenate([
            jnp.broadcast_to(pos_t[..., None], pos_t.shape + (sizes[0],)),
            jnp.broadcast_to(pos_h[..., None], pos_h.shape + (sizes[1],)),
            jnp.broadcast_to(pos_w[..., None], pos_w.shape + (sizes[2],)),
        ], axis=-1)                   # (B, S, half)
        freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = seg.astype(jnp.float32) * freq
        return _rotate(x, ang)
    raise ValueError(f"unknown rope kind {kind!r}")


def text_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))
