"""Mamba2 mixer implemented with the SSD (state-space duality) chunked scan
[arXiv:2405.21060].

Sequence mode runs a ``lax.scan`` over chunks of length ``Q``: within each
chunk the quadratic (dual, attention-like) form computes the intra-chunk
contribution on the MXU, while a (state -> state) recurrence carries the
inter-chunk SSM state. Live memory is O(B·H·Q·Q + B·H·N·P) per step,
independent of sequence length. Decode mode is the O(1) single-step
recurrence over the carried state + causal-conv ring buffer.

TP note (DESIGN.md §5): the input projection is SPLIT into separate
matrices (z, x, B, C, dt) rather than one packed matmul. A packed
projection cannot be head-sharded — static slices at non-shard-aligned
offsets force GSPMD to all-gather the whole (B, S, 2·d_inner+2N+H)
projection every layer. With split projections w_z/w_x/w_dt shard on the
head axis, w_B/w_C stay replicated (they are tiny and shared across
heads), and the whole SSD scan runs head-parallel with zero collectives
until the output row-matmul's psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, silu


def ssm_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    n = s.d_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, di)),
        "w_x": dense_init(ks[1], (d, di)),
        "w_B": dense_init(ks[2], (d, n)),
        "w_C": dense_init(ks[3], (d, n)),
        "w_dt": dense_init(ks[4], (d, nh)),
        # depthwise causal conv over x, B, C (split per group: a depthwise
        # conv factors exactly across channel groups)
        "conv_wx": dense_init(ks[5], (s.conv_width, di)) * 0.1,
        "conv_bx": jnp.zeros((di,), jnp.float32),
        "conv_wB": dense_init(ks[6], (s.conv_width, n)) * 0.1,
        "conv_bB": jnp.zeros((n,), jnp.float32),
        "conv_wC": dense_init(ks[7], (s.conv_width, n)) * 0.1,
        "conv_bC": jnp.zeros((n,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[3], (di, d)),
    }


def _project_in(params, x):
    """x (..., D) -> (z, xr, Br, Cr, dt_raw) pre-conv projections."""
    dt = x.dtype
    z = x @ params["w_z"].astype(dt)
    xr = x @ params["w_x"].astype(dt)
    br = x @ params["w_B"].astype(dt)
    cr = x @ params["w_C"].astype(dt)
    dt_raw = x @ params["w_dt"].astype(dt)
    return z, xr, br, cr, dt_raw


def _causal_conv(seq, w, b):
    """seq (B,S,C), w (W,C) depthwise causal conv + silu."""
    width = w.shape[0]
    pad = jnp.pad(seq, [(0, 0), (width - 1, 0), (0, 0)])
    out = sum(pad[:, i:i + seq.shape[1], :] * w[i] for i in range(width))
    return silu(out + b)


def _gated_out(params, y, z, x_dtype):
    dt = y.dtype
    g = y * silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * params["gate_norm"]).astype(dt)
    return (g @ params["w_out"].astype(dt)).astype(x_dtype)


def ssm_forward(params, cfg, x):
    """x (B, S, D) -> (B, S, D). S is right-padded to the chunk multiple."""
    out, _ = _ssm_forward_with_state(params, cfg, x)
    return out


def _ssm_forward_with_state(params, cfg, x):
    """Chunked SSD scan; returns (out (B,S,D), final carried state)."""
    s_cfg = cfg.ssm
    b, orig_len, _ = x.shape
    q = min(s_cfg.chunk, orig_len)
    if orig_len % q:                         # causal: right-pad then trim
        pad = q - orig_len % q
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0)])
    b, slen, _ = x.shape
    di = s_cfg.d_inner(cfg.d_model)
    nh = s_cfg.num_heads(cfg.d_model)
    n, p = s_cfg.d_state, s_cfg.head_dim
    nchunks = slen // q

    z, xr, br, cr, dt_raw = _project_in(params, x)
    xc = _causal_conv(xr, params["conv_wx"].astype(x.dtype),
                      params["conv_bx"].astype(x.dtype))
    bmat = _causal_conv(br, params["conv_wB"].astype(x.dtype),
                        params["conv_bB"].astype(x.dtype))
    cmat = _causal_conv(cr, params["conv_wC"].astype(x.dtype),
                        params["conv_bC"].astype(x.dtype))
    xs = xc.reshape(b, slen, nh, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])                   # (H,)
    la = dt * a                                     # per-step log decay (B,S,H)

    # chunked tensors, scanned over the chunk axis
    def chunked(t, shape):
        return t.reshape((b, nchunks, q) + shape).transpose(1, 0, 2, *range(3, 3 + len(shape)))

    xs_c = chunked(xs, (nh, p))
    b_c = chunked(bmat, (n,))
    c_c = chunked(cmat, (n,))
    dt_c = chunked(dt, (nh,))
    la_c = chunked(la, (nh,))

    def chunk_step(h, inp):
        xk, bk, ck, dtk, lak = inp                 # (B,Q,H,P) (B,Q,N) ...
        cum = jnp.cumsum(lak, axis=1)              # (B,Q,H)
        # intra-chunk (dual / quadratic) term
        scores = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32),
                            bk.astype(jnp.float32))         # (B,Q,Q)
        decay = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Qi,Qj,H)
        iidx = jnp.arange(q)
        causal = iidx[:, None] >= iidx[None, :]
        # mask BEFORE exp: non-causal entries have decay > 0, and
        # where(c, exp(big), 0) leaks NaN through the gradient (inf * 0)
        decay = jnp.where(causal[None, :, :, None], decay, -1e30)
        lmat = jnp.exp(decay)
        dtx = dtk[..., None] * xk.astype(jnp.float32)        # (B,Q,H,P)
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, lmat, dtx)
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("bin,bih,bhnp->bihp", ck.astype(jnp.float32),
                           jnp.exp(cum), h)
        # new carried state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # (B,Q,H)
        state_upd = jnp.einsum("bjn,bjh,bjhp->bhnp", bk.astype(jnp.float32),
                               decay_to_end * dtk, xk.astype(jnp.float32))
        h = jnp.exp(cum[:, -1, :])[..., None, None] * h + state_upd
        return h, y

    h0 = jnp.zeros((b, nh, n, p), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_step, h0, (xs_c, b_c, c_c, dt_c, la_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, slen, nh, p)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, slen, di).astype(x.dtype)
    out = _gated_out(params, y, z, x.dtype)
    if orig_len != slen:
        out = out[:, :orig_len]
    return out, h_final


def ssm_prefill(params, cfg, x, cache):
    """Forward + populate the decode cache (state + conv ring)."""
    s_cfg = cfg.ssm
    b, slen, _ = x.shape
    out, state = _ssm_forward_with_state(params, cfg, x)
    # conv ring: last (W-1) PRE-conv channel values of [x, B, C]
    _, xr, br, cr, _ = _project_in(params, x)
    tail = slice(slen - (s_cfg.conv_width - 1), slen)
    conv = jnp.concatenate([xr[:, tail], br[:, tail], cr[:, tail]], axis=-1)
    return out, {"state": state, "conv": conv.astype(cache["conv"].dtype)}


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    conv_ch = di + 2 * s.d_state
    return {
        "state": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def ssm_decode(params, cfg, x, cache):
    """One-token recurrence. x (B,1,D) -> (out (B,1,D), new cache)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    di = s_cfg.d_inner(cfg.d_model)
    nh = s_cfg.num_heads(cfg.d_model)
    n, p = s_cfg.d_state, s_cfg.head_dim

    z, xr, br, cr, dt_raw = _project_in(params, x[:, 0, :])
    # causal conv over ring of the last (w-1) inputs + current
    cur = jnp.concatenate([xr, br, cr], axis=-1)
    hist = jnp.concatenate([cache["conv"], cur[:, None, :].astype(cache["conv"].dtype)], axis=1)
    new_conv = hist[:, 1:, :]

    def conv1(seq, w, b_):
        out = jnp.einsum("bwc,wc->bc", seq.astype(jnp.float32),
                         w.astype(jnp.float32)) + b_
        return silu(out)

    xh = conv1(hist[..., :di], params["conv_wx"], params["conv_bx"])
    bvec = conv1(hist[..., di:di + n], params["conv_wB"], params["conv_bB"])
    cvec = conv1(hist[..., di + n:], params["conv_wC"], params["conv_bC"])
    xh = xh.reshape(b, nh, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a)                                   # (B,H)

    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, bvec, xh)
    state = decay[..., None, None] * cache["state"] + upd
    y = jnp.einsum("bn,bhnp->bhp", cvec, state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    out = _gated_out(params, y, z[:, None, :], x.dtype)
    return out, {"state": state, "conv": new_conv}
