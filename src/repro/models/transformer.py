"""Decoder-stack assembly for every assigned architecture.

The stack is organized as ``num_periods`` repetitions of a ``period`` of
blocks (period = lcm(attn interleave, MoE interleave); 1 for homogeneous
stacks, 8 for Jamba). Parameters for each period-position are stacked over
periods and the stack runs under ``jax.lax.scan``, so HLO size — and
compile time for the 80-layer/72B dry-runs — is independent of depth.

Three entry points mirror the input-shape suite:
  ``forward``      train/prefill logits over a full sequence,
  ``prefill``      forward + returns the populated decode cache,
  ``decode_step``  one token against the cache (attention ring buffer /
                   SSM state), the body ``serve_step`` lowers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models import rope as rope_lib
from repro.models.attention import (attention_decode, attention_forward,
                                    attn_init, init_kv_cache)
from repro.models.common import embed_init, norm_apply, norm_init
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import init_ssm_cache, ssm_decode, ssm_forward, ssm_init


def period_len(cfg: ModelConfig) -> int:
    a = cfg.attn_every if cfg.attn_every > 1 else 1
    m = cfg.moe.every if cfg.moe is not None else 1
    return math.lcm(a, m)


def num_periods(cfg: ModelConfig) -> int:
    p = period_len(cfg)
    assert cfg.num_layers % p == 0, (cfg.num_layers, p)
    return cfg.num_layers // p


# ---------------------------------------------------------------------------
# Init

def _block_init(key, cfg: ModelConfig, pos: int):
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_init(cfg.norm, cfg.d_model)}
    if cfg.block_kind(pos) == ATTN:
        p["attn"] = attn_init(ks[0], cfg)
    else:
        p["ssm"] = ssm_init(ks[0], cfg)
    if cfg.uses_moe(pos):
        p["norm2"] = norm_init(cfg.norm, cfg.d_model)
        p["moe"] = moe_init(ks[1], cfg)
    elif cfg.d_ff:
        p["norm2"] = norm_init(cfg.norm, cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig):
    plen, nper = period_len(cfg), num_periods(cfg)
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    vp = cfg.padded_vocab()
    params = {"embed": embed_init(k_embed, (vp, cfg.d_model)),
              "final_norm": norm_init(cfg.norm, cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, (cfg.d_model, vp))
    layer_keys = jax.random.split(k_layers, (plen, nper))
    blocks = []
    for pos in range(plen):
        stacked = jax.vmap(lambda k, pos=pos: _block_init(k, cfg, pos))(layer_keys[pos])
        blocks.append(stacked)
    params["blocks"] = blocks
    return params


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct tree without allocating (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# Caches

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-period-position stacked caches (leading axis = num_periods)."""
    plen, nper = period_len(cfg), num_periods(cfg)

    def one(pos):
        if cfg.block_kind(pos) == ATTN:
            c = init_kv_cache(cfg, batch, max_len, dtype)
        else:
            c = init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (nper,) + x.shape), c)

    return [one(pos) for pos in range(plen)]


# ---------------------------------------------------------------------------
# Block application

# matmul-weight keys with a dequantize-fused kernel route: wire structs
# at these positions pass through _dequant_block intact and execute via
# ops.qdense (Pallas qmatmul/qmatmul4) inside attention/mlp. Everything
# else (MoE expert stacks, SSM mixers) still dequantizes at block entry.
KERNEL_ROUTED = {"attn": ("wq", "wk", "wv", "wo"),
                 "mlp": ("w_gate", "w_up", "w_down")}


def _dequant_block(bp, cfg):
    """Serving path: block weights may arrive as int8/int4 wire structs
    {codes|codes_packed, scale, mu} (core.quantizer) — the QPART
    quantization keeping weights compact in HBM. Structs under
    ``KERNEL_ROUTED`` positions are left packed: the qmatmul kernels
    dequantize per (block_k, block_n) tile inside the matmul
    (kernels/qmatmul.py), so the full-precision weight never
    materializes in HBM. Remaining structs dequantize here, once per
    block application."""
    def dequant(node):
        if "codes" in node:
            w = node["codes"].astype(jnp.float32) * node["scale"] \
                + node["mu"]
            return w.astype(getattr(jnp, cfg.dtype))
        p = node["codes_packed"]              # int4: two codes per byte
        lo = (p & 0xF).astype(jnp.float32)
        hi = ((p >> 4) & 0xF).astype(jnp.float32)
        w = jnp.stack([lo, hi], axis=-1).reshape(
            p.shape[:-1] + (p.shape[-1] * 2,))
        w = w * node["scale"] + node["mu"]
        return w.astype(getattr(jnp, cfg.dtype))

    def is_struct(node):
        return isinstance(node, dict) and \
            ("codes" in node or "codes_packed" in node) and "scale" in node

    def walk(node, parent=None):
        if isinstance(node, dict):
            if is_struct(node):
                return node if parent == "routed" else dequant(node)
            out = {}
            for k, v in node.items():
                if parent in KERNEL_ROUTED and k in KERNEL_ROUTED[parent]:
                    out[k] = walk(v, "routed")
                else:
                    out[k] = walk(v, k)
            return out
        return node

    return walk(bp)


def _block_apply(bp, cfg, pos, x, positions, *, cache=None, decode_pos=None):
    """One block. Returns (x, aux, new_cache)."""
    bp = _dequant_block(bp, cfg)
    aux = None
    h = norm_apply(cfg.norm, bp["norm1"], x)
    if cfg.block_kind(pos) == ATTN:
        if cache is not None:
            mixed, cache = attention_decode(bp["attn"], cfg, h, cache, decode_pos)
        else:
            mixed = attention_forward(bp["attn"], cfg, h, positions)
    else:
        if cache is not None:
            mixed, cache = ssm_decode(bp["ssm"], cfg, h, cache)
        else:
            mixed = ssm_forward(bp["ssm"], cfg, h)
    x = x + mixed
    if "moe" in bp:
        h2 = norm_apply(cfg.norm, bp["norm2"], x)
        out, aux = moe_apply(bp["moe"], cfg, h2)
        x = x + out
    elif "mlp" in bp:
        h2 = norm_apply(cfg.norm, bp["norm2"], x)
        x = x + mlp_apply(bp["mlp"], cfg, h2)
    return x, aux, cache


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "dropped_frac": jnp.zeros((), jnp.float32)}


def _acc_aux(acc, aux):
    if aux is None:
        return acc
    return jax.tree.map(jnp.add, acc, aux)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)

def _embed(params, cfg, tokens=None, embeds=None):
    if embeds is not None:
        return embeds.astype(getattr(jnp, cfg.dtype))
    return params["embed"][tokens].astype(getattr(jnp, cfg.dtype))


def _unembed(params, cfg, x):
    x = norm_apply(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    vp = cfg.padded_vocab()
    if vp != cfg.vocab_size:                  # mask padded vocab columns
        col = jnp.arange(vp)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            positions=None, remat: bool = False):
    """-> (logits (B,S,V), aux dict of summed router losses)."""
    x = _embed(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = rope_lib.text_positions(b, s)
    plen = period_len(cfg)

    def period_fn(x, period_params):
        aux_acc = _zero_aux()
        for pos in range(plen):
            x, aux, _ = _block_apply(period_params[pos], cfg, pos, x, positions)
            aux_acc = _acc_aux(aux_acc, aux)
        return x, aux_acc

    if remat:
        period_fn = jax.checkpoint(period_fn)

    def scan_fn(x, period_params):
        return period_fn(x, period_params)

    x, auxs = jax.lax.scan(scan_fn, x, tuple(params["blocks"]))
    aux = jax.tree.map(lambda a: a.sum(0), auxs)
    return _unembed(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            positions=None, max_len: int, cache_dtype=jnp.bfloat16):
    """Forward + build the decode cache by replaying K/V (attention) and
    final states (SSM). Implemented as forward with per-block cache fill."""
    x = _embed(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = rope_lib.text_positions(b, s)
    plen = period_len(cfg)
    cache0 = init_cache(cfg, b, max_len, cache_dtype)

    def scan_fn(x, inp):
        period_params, caches = inp
        new_caches = []
        aux_acc = _zero_aux()
        for pos in range(plen):
            bp = _dequant_block(period_params[pos], cfg)
            h = norm_apply(cfg.norm, bp["norm1"], x)
            if cfg.block_kind(pos) == ATTN:
                mixed, c = _attn_prefill_with_cache(bp["attn"], cfg, h,
                                                    positions, caches[pos])
            else:
                mixed, c = _ssm_prefill_with_cache(bp["ssm"], cfg, h, caches[pos])
            x = x + mixed
            if "moe" in bp:
                h2 = norm_apply(cfg.norm, bp["norm2"], x)
                out, aux = moe_apply(bp["moe"], cfg, h2)
                x = x + out
                aux_acc = _acc_aux(aux_acc, aux)
            elif "mlp" in bp:
                h2 = norm_apply(cfg.norm, bp["norm2"], x)
                x = x + mlp_apply(bp["mlp"], cfg, h2)
            new_caches.append(c)
        return x, (tuple(new_caches), aux_acc)

    x, (caches, auxs) = jax.lax.scan(scan_fn, x, (tuple(params["blocks"]),
                                                  tuple(cache0)))
    aux = jax.tree.map(lambda a: a.sum(0), auxs)
    return _unembed(params, cfg, x), list(caches), aux


def _attn_prefill_with_cache(ap, cfg, h, positions, cache):
    from repro.models.attention import (_blocked_causal_attention,
                                        _out_proj, _project_qkv,
                                        _windowed_attention, DEFAULT_BLOCK_Q,
                                        DEFAULT_BLOCK_K)
    b, s, _ = h.shape
    q, k, v = _project_qkv(ap, cfg, h)
    qr = rope_lib.apply_rope(cfg.rope, q, positions, cfg.rope_theta)
    kr = rope_lib.apply_rope(cfg.rope, k, positions, cfg.rope_theta)
    bq, bk = min(DEFAULT_BLOCK_Q, s), min(DEFAULT_BLOCK_K, s)
    if cfg.sliding_window is not None and s > cfg.sliding_window:
        out = _windowed_attention(qr, kr, v, cfg.sliding_window, bq)
    else:
        out = _blocked_causal_attention(qr, kr, v, bq, bk)
    out = _out_proj(ap, cfg, out, h.dtype)
    buf = cache["k"].shape[1]
    # write the last min(s, buf) keys/values into the ring
    take = min(s, buf)
    kw = kr[:, s - take:, :, :].astype(cache["k"].dtype)
    vw = v[:, s - take:, :, :].astype(cache["v"].dtype)
    if take == buf:
        # ring layout: slot = pos % buf
        pos0 = s - take
        # jnp.roll: out[j] = in[(j - shift) % buf]; we need out[(pos0+i)%buf]
        # = in[i], i.e. shift = pos0.
        ck = jnp.roll(kw, pos0 % buf, axis=1)
        cv = jnp.roll(vw, pos0 % buf, axis=1)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kw, s - take, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vw, s - take, axis=1)
    return out, {"k": ck, "v": cv}


def _ssm_prefill_with_cache(sp, cfg, h, cache):
    from repro.models.ssm import ssm_prefill
    return ssm_prefill(sp, cfg, h, cache)


# ---------------------------------------------------------------------------
# Decode

def decode_step(params, cfg: ModelConfig, token, caches, pos):
    """token (B,1) int32 or embeds (B,1,D); pos scalar int32 absolute
    position. Returns (logits (B,1,V), new caches)."""
    if token.ndim == 2:
        x = _embed(params, cfg, token)
    else:
        x = token.astype(getattr(jnp, cfg.dtype))
    plen = period_len(cfg)

    def scan_fn(x, inp):
        period_params, caches_in = inp
        new_caches = []
        for p in range(plen):
            x, _, c = _block_apply(period_params[p], cfg, p, x, None,
                                   cache=caches_in[p], decode_pos=pos)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, caches = jax.lax.scan(scan_fn, x, (tuple(params["blocks"]),
                                          tuple(caches)))
    return _unembed(params, cfg, x), list(caches)


# ---------------------------------------------------------------------------
# Depth-independent segment forward (compile-once partitioned execution)

def segment_forward(params, cfg: ModelConfig, h, start, stop, *,
                    positions=None, collect: bool = False):
    """Apply blocks ``[start, stop)`` of the stack to hidden state ``h``
    (B, S, D) under ONE masked ``lax.scan`` over the stacked period
    representation. ``start``/``stop`` are DYNAMIC operands — every
    device/server segment split of the same input shape shares a single
    compiled program, instead of one XLA compilation per resume point.

    Every block of the stack is computed and blocks outside the segment
    are masked to identity (``jnp.where``): O(L) FLOPs regardless of
    segment length, O(1) compilations regardless of L — the QPART serving
    paths (calibration probes at every layer, arbitrary partition points)
    are compile-bound, not FLOP-bound, at the depths they sweep.

    ``collect=True`` additionally stacks the activation ENTERING each
    block — shape (L, B, S, D), the Alg. 1 calibration's ``acts`` — at
    the cost of the extra output buffer. Returns ``h_out`` or
    ``(h_out, acts)``. Router aux losses are dropped (serving paths only
    consume logits)."""
    b, s, _ = h.shape
    if positions is None:
        positions = rope_lib.text_positions(b, s)
    plen, nper = period_len(cfg), num_periods(cfg)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)

    def scan_fn(x, inp):
        per_idx, period_params = inp
        entries = []
        for pos in range(plen):
            layer = per_idx * plen + pos
            if collect:
                entries.append(x)
            x_new, _, _ = _block_apply(period_params[pos], cfg, pos, x,
                                       positions)
            active = (layer >= start) & (layer < stop)
            x = jnp.where(active, x_new, x)
        return x, (jnp.stack(entries) if collect else None)

    xs = (jnp.arange(nper), tuple(params["blocks"]))
    h, acts = jax.lax.scan(scan_fn, h, xs)
    if collect:
        # (nper, plen, B, S, D) -> (L, B, S, D); layer = per * plen + pos
        return h, acts.reshape((nper * plen,) + acts.shape[2:])
    return h


def segment_logits(params, cfg: ModelConfig, h, start, stop, *,
                   positions=None):
    """``segment_forward`` + unembed at the LAST position — the
    (B, V) "logits" view the serving backends and Alg. 1 probes use."""
    h = segment_forward(params, cfg, h, start, stop, positions=positions)
    return _unembed(params, cfg, h)[:, -1, :]


# ---------------------------------------------------------------------------
# Depth-independent segment prefill/decode (cut-point-partitioned KV cache)

def segment_prefill(params, cfg: ModelConfig, h, cache0, start, stop, *,
                    positions=None):
    """``prefill`` restricted to blocks ``[start, stop)`` under the same
    masked ``lax.scan`` as ``segment_forward`` — ``start``/``stop`` are
    DYNAMIC operands, so the device segment ``[0, p)`` and the server
    tail ``[p, L)`` of EVERY cut point share one compiled program per
    input shape. ``cache0`` is an ``init_cache`` tree (its dtype/max_len
    are part of the jit shape key); blocks outside the segment leave
    both the hidden state and their cache slots untouched
    (``jnp.where`` on every leaf). Returns ``(h_out, caches)``. Router
    aux losses are dropped (serving paths only consume logits)."""
    b, s, _ = h.shape
    if positions is None:
        positions = rope_lib.text_positions(b, s)
    plen, nper = period_len(cfg), num_periods(cfg)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)

    def scan_fn(x, inp):
        per_idx, period_params, caches = inp
        new_caches = []
        for pos in range(plen):
            layer = per_idx * plen + pos
            bp = _dequant_block(period_params[pos], cfg)
            hh = norm_apply(cfg.norm, bp["norm1"], x)
            if cfg.block_kind(pos) == ATTN:
                mixed, c = _attn_prefill_with_cache(bp["attn"], cfg, hh,
                                                    positions, caches[pos])
            else:
                mixed, c = _ssm_prefill_with_cache(bp["ssm"], cfg, hh,
                                                   caches[pos])
            x_new = x + mixed
            if "moe" in bp:
                h2 = norm_apply(cfg.norm, bp["norm2"], x_new)
                out, _ = moe_apply(bp["moe"], cfg, h2)
                x_new = x_new + out
            elif "mlp" in bp:
                h2 = norm_apply(cfg.norm, bp["norm2"], x_new)
                x_new = x_new + mlp_apply(bp["mlp"], cfg, h2)
            active = (layer >= start) & (layer < stop)
            x = jnp.where(active, x_new, x)
            new_caches.append(jax.tree.map(
                lambda new, old: jnp.where(active, new, old),
                c, caches[pos]))
        return x, tuple(new_caches)

    xs = (jnp.arange(nper), tuple(params["blocks"]), tuple(cache0))
    h, caches = jax.lax.scan(scan_fn, h, xs)
    return h, list(caches)


def segment_decode_step(params, cfg: ModelConfig, x, caches, pos, start,
                        stop):
    """One decode step over blocks ``[start, stop)``: ``x`` (B, 1, D)
    hidden state entering block ``start``, ``pos`` the scalar absolute
    position of the token. Masked twin of ``decode_step``'s scan with
    DYNAMIC ``(start, stop)``; inactive blocks pass hidden state and
    cache through unchanged. Returns ``(x_out, caches)``."""
    plen, nper = period_len(cfg), num_periods(cfg)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)

    def scan_fn(x, inp):
        per_idx, period_params, caches_in = inp
        new_caches = []
        for p in range(plen):
            layer = per_idx * plen + p
            x_new, _, c = _block_apply(period_params[p], cfg, p, x, None,
                                       cache=caches_in[p], decode_pos=pos)
            active = (layer >= start) & (layer < stop)
            x = jnp.where(active, x_new, x)
            new_caches.append(jax.tree.map(
                lambda new, old: jnp.where(active, new, old),
                c, caches_in[p]))
        return x, tuple(new_caches)

    xs = (jnp.arange(nper), tuple(params["blocks"]), tuple(caches))
    x, caches = jax.lax.scan(scan_fn, x, xs)
    return x, list(caches)


def _attn_extend_with_cache(ap, cfg, h, positions, cache):
    """Multi-token attention against a PARTIALLY POPULATED ring cache:
    project/RoPE the ``s`` incoming rows at absolute ``positions``, write
    their K/V into the cache, then attend every row against the full
    ring under a per-row validity mask (ring index <= row position).
    Masked lanes contribute EXACT zeros (``exp(NEG_INF - m) == 0.0`` and
    ``0.0 * v == 0.0``), so the reduction over the padded ring is
    bitwise the reduction over just the valid prefix — the body below is
    the single-block ``_blocked_causal_attention`` accumulator math with
    its initial carries written out (m0 = NEG_INF, l0 = 0, acc0 = 0:
    ``corr`` underflows to exact 0.0, so l = p.sum and acc = pv).

    Chunked prefill is therefore bitwise the monolithic
    ``segment_prefill`` for lossless cache storage and chunks of >= 2
    rows within one causal block (s <= DEFAULT_BLOCK_K): XLA lowers a
    1-row chunk's dense contractions to matvecs whose reduction order
    differs from the matmul the monolithic path ran, so chunk planners
    must never emit a size-1 chunk (``DecodeSession`` folds a remainder
    of 1 into the final chunk).

    No-wraparound contract: callers guarantee ``positions < buf`` (the
    decode sessions gate out sliding-window configs and bound positions
    by ``max_len``), so slot == position and the write is one
    ``dynamic_update_slice``.
    """
    from repro.models.attention import NEG_INF, _out_proj, _project_qkv
    b, s, _ = h.shape
    buf = cache["k"].shape[1]
    q, k, v = _project_qkv(ap, cfg, h)
    qr = rope_lib.apply_rope(cfg.rope, q, positions, cfg.rope_theta)
    kr = rope_lib.apply_rope(cfg.rope, k, positions, cfg.rope_theta)
    pos0 = positions[0, 0]
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], kr.astype(cache["k"].dtype), pos0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos0, axis=1)
    hd = qr.shape[-1]
    kb = ck.astype(qr.dtype)
    vb = cv.astype(qr.dtype)
    qp = positions[0]                        # (s,) absolute row positions
    kp = jnp.arange(buf, dtype=positions.dtype)   # ring index == position
    mask = qp[:, None] >= kp[None, :]        # (s, buf) causal validity
    scale = hd ** -0.5
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qr, kb,
                    preferred_element_type=jnp.float32) * scale
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    m0 = jnp.full((b, kb.shape[2], qr.shape[3], s), NEG_INF, jnp.float32)
    m_new = jnp.maximum(m0, sc.max(axis=-1))
    p = jnp.exp(sc - m_new[..., None])
    corr = jnp.exp(m0 - m_new)
    l = jnp.zeros_like(m0) * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                    preferred_element_type=jnp.float32)
    acc = jnp.zeros_like(pv) * corr[..., None] + pv
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).astype(qr.dtype)
    out = _out_proj(ap, cfg, out, h.dtype)
    return out, {"k": ck, "v": cv}


def segment_extend(params, cfg: ModelConfig, h, caches, pos0, start, stop):
    """Apply blocks ``[start, stop)`` to ``s`` NEW rows ``h`` (B, S, D)
    entering at absolute position ``pos0``, extending the per-block ring
    caches in place of re-running the whole prefix. The masked-scan twin
    of ``segment_prefill`` with a POSITION OFFSET: ``pos0``/``start``/
    ``stop`` are dynamic operands, so the program is shape-keyed on the
    CHUNK length, never the prompt length — every chunk of every prompt
    reuses one compiled program per (batch, s) shape, and chunked
    prefill rebuilds a bit-identical cache vs the monolithic
    ``segment_prefill`` (see :func:`_attn_extend_with_cache` for the
    exact conditions). Attention blocks only — SSM state is a running
    reduction, not position-addressable, so a chunk cannot resume it
    mid-stream."""
    plen, nper = period_len(cfg), num_periods(cfg)
    for pos in range(plen):
        if cfg.block_kind(pos) != ATTN:
            raise NotImplementedError(
                "segment_extend supports attention blocks only: "
                f"block kind at period position {pos} is not ATTN")
    b, s, _ = h.shape
    positions = rope_lib.text_positions(b, s) + jnp.asarray(pos0, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)

    def scan_fn(x, inp):
        per_idx, period_params, caches_in = inp
        new_caches = []
        for pos in range(plen):
            layer = per_idx * plen + pos
            bp = _dequant_block(period_params[pos], cfg)
            hh = norm_apply(cfg.norm, bp["norm1"], x)
            mixed, c = _attn_extend_with_cache(bp["attn"], cfg, hh,
                                               positions, caches_in[pos])
            x_new = x + mixed
            if "moe" in bp:
                h2 = norm_apply(cfg.norm, bp["norm2"], x_new)
                out, _ = moe_apply(bp["moe"], cfg, h2)
                x_new = x_new + out
            elif "mlp" in bp:
                h2 = norm_apply(cfg.norm, bp["norm2"], x_new)
                x_new = x_new + mlp_apply(bp["mlp"], cfg, h2)
            active = (layer >= start) & (layer < stop)
            x = jnp.where(active, x_new, x)
            new_caches.append(jax.tree.map(
                lambda new, old: jnp.where(active, new, old),
                c, caches_in[pos]))
        return x, tuple(new_caches)

    xs = (jnp.arange(nper), tuple(params["blocks"]), tuple(caches))
    h, caches = jax.lax.scan(scan_fn, h, xs)
    return h, list(caches)


def segment_verify(params, cfg: ModelConfig, xs, caches, pos0, start, stop):
    """Speculative-decode verification: run the ``s`` hidden rows ``xs``
    (B, S, D) — the cut-point activations of a drafted token batch at
    positions ``pos0 .. pos0 + s - 1`` — through blocks ``[start, stop)``
    and unembed EVERY row. Returns ``(logits (B, S, V), caches)``.

    The rows execute as a ``lax.scan`` of the EXACT
    ``segment_decode_step`` + unembed per-token math, inside ONE jitted
    program: bit-identical logits to ``s`` sequential decode steps BY
    CONSTRUCTION (same ops, same shapes, same kernel routing — a
    guarantee a batched multi-row forward cannot make, since XLA's
    reduction order in the dense contractions differs between 1-row and
    s-row operands). What the batching buys is the SERVING shape: one
    device->server round trip verifies k drafts instead of k round
    trips, which is the term that bounds tokens/s on a slow channel.

    Attention blocks only: an SSM running state cannot be rolled back
    to the acceptance point when a draft is rejected, while a ring
    cache needs no rollback at all (every stale slot is re-written
    before any later query can attend it)."""
    plen = period_len(cfg)
    for pos in range(plen):
        if cfg.block_kind(pos) != ATTN:
            raise NotImplementedError(
                "segment_verify supports attention blocks only: "
                f"block kind at period position {pos} is not ATTN")
    b, s, _ = xs.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)

    def row_step(carry, inp):
        xj, off = inp                       # (B, D), scalar row offset
        x_out, new_caches = segment_decode_step(
            params, cfg, xj[:, None, :], list(carry), pos0 + off, start,
            stop)
        logits = _unembed(params, cfg, x_out)[:, -1, :]
        return tuple(new_caches), logits

    carry, logits = jax.lax.scan(
        row_step, tuple(caches),
        (xs.transpose(1, 0, 2), jnp.arange(s, dtype=jnp.int32)))
    return logits.transpose(1, 0, 2), list(carry)


# ---------------------------------------------------------------------------
# Public single-block entry points (repro.serving.backends.transformer):
# embed/unembed and one block application — the non-scan view of the same
# math `forward` runs under lax.scan, for paths that need per-block access
# (QPART noise calibration, partitioned device-segment execution).

embed_tokens = _embed
unembed = _unembed
apply_block = _block_apply


def block_at(params, cfg: ModelConfig, layer: int):
    """(block param pytree, period position) of global block index
    ``layer``: the scan iterates periods on the stacked leading axis and
    positions within a period, so layer = period * period_len + pos."""
    per, pos = divmod(layer, period_len(cfg))
    return jax.tree.map(lambda t: t[per], params["blocks"][pos]), pos
