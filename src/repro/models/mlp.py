"""Dense feed-forward blocks: SwiGLU (llama-style) and GeLU (vanilla)."""
from __future__ import annotations

import jax

from repro.models.common import dense_init, silu


def mlp_init(key, cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f)),
            "w_up": dense_init(ks[1], (d, f)),
            "w_down": dense_init(ks[2], (f, d)),
        }
    return {
        "w_up": dense_init(ks[0], (d, f)),
        "w_down": dense_init(ks[1], (f, d)),
    }


def mlp_apply(params, cfg, x):
    if cfg.mlp == "swiglu":
        h = silu(x @ params["w_gate"].astype(x.dtype)) * (x @ params["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)
