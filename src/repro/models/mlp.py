"""Dense feed-forward blocks: SwiGLU (llama-style) and GeLU (vanilla)."""
from __future__ import annotations

import jax

from repro.models.common import dense_init, silu


def mlp_init(key, cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f)),
            "w_up": dense_init(ks[1], (d, f)),
            "w_down": dense_init(ks[2], (f, d)),
        }
    return {
        "w_up": dense_init(ks[0], (d, f)),
        "w_down": dense_init(ks[1], (f, d)),
    }


def _ff(x, w):
    """x @ w: dense, or the dequantize-fused qmatmul kernel when the
    weight arrives as a quantized wire struct (repro/kernels/ops)."""
    from repro.kernels import ops
    if ops.is_wire_struct(w):
        return ops.qdense(x, w)
    return x @ w.astype(x.dtype)


def mlp_apply(params, cfg, x):
    if cfg.mlp == "swiglu":
        h = silu(_ff(x, params["w_gate"])) * _ff(x, params["w_up"])
    else:
        h = jax.nn.gelu(_ff(x, params["w_up"]))
    return _ff(h, params["w_down"])
