"""Baseline offloading schemes the paper compares against (§V, Fig. 7–10,
Table III):

  * no-optimization — the model segment ships at full f32 precision and
    the cut activation uploads at f32 (the paper's "No Optimization").
  * autoencoder     — DeepCOD-style [35]: a linear encoder/decoder pair is
    inserted at the cut; the device uploads the compressed code. Extra
    encode/decode compute is charged to the device/server respectively,
    and the reconstruction perturbs accuracy (really executed).
  * pruning         — two-step-pruning-style [44][45]: neurons of the
    device segment are magnitude-pruned to a retention ratio chosen to
    keep measured accuracy degradation comparable to QPART's budget, which
    shrinks both the shipped weights and the cut activation.

Every baseline takes a ``ModelBackend`` and returns the same
``ServingResult`` as QPART (priced by the same simulator), so the
comparison is apples-to-apples. All model execution goes through the
backend's forward family / ``run_prefix`` — no private model reach-ins.
The pruning baseline additionally assumes the classifier param layout
(a list of per-layer ``{"w", "b"}`` dicts) since magnitude pruning is
defined on those weight matrices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile, cost_breakdown)
from repro.core.solver import PartitionPlan
from repro.serving.backends.base import ModelBackend
from repro.serving.simulator import ServingResult


def _plan_stub(p: int, payload_bits: float) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(max(p, 0), 32.0),
                         bits_x=32.0, objective=0.0, psi_total=0.0,
                         payload_bits=payload_bits, breakdown={})


def _result(plan, specs, device, server, channel, weights,
            extra_dev_macs: float = 0.0,
            extra_srv_macs: float = 0.0) -> ServingResult:
    o = np.array([sp.o for sp in specs], dtype=np.float64)
    o1 = float(o[:plan.p].sum()) + extra_dev_macs
    o2 = float(o[plan.p:].sum()) + extra_srv_macs
    costs = cost_breakdown(o1, o2, plan.payload_bits, device, server, channel)
    res = ServingResult(plan=plan, costs=costs,
                        objective=costs.objective(weights),
                        payload_bits=plan.payload_bits)
    # baselines are priced at zero load; make that explicit so they mix
    # with scheduled/engine results in aggregations (scheduler
    # .total_latency, fleet metrics) without a missing-key special case
    res.extra["queue_delay"] = 0.0
    return res


def _measure(res: ServingResult, logits, test_y,
             base_accuracy: Optional[float]) -> None:
    res.accuracy = float(jnp.mean(jnp.argmax(logits, -1) == test_y))
    if base_accuracy is not None:
        res.accuracy_degradation = base_accuracy - res.accuracy


# ---------------------------------------------------------------------------
# 1. No optimization.

def no_opt_offload(backend: ModelBackend, p: int,
                   device: DeviceProfile, server: ServerProfile,
                   channel: Channel, weights: ObjectiveWeights,
                   test_x=None, test_y=None,
                   base_accuracy: Optional[float] = None) -> ServingResult:
    """Ship segment + activation at f32; accuracy == base model."""
    specs = backend.layer_specs()
    wire = sum(specs[i].z_w for i in range(p)) * 32.0
    wire += (specs[p - 1].z_x if p else backend.input_elements()) * 32.0
    res = _result(_plan_stub(p, wire), specs, device, server, channel, weights)
    if test_x is not None:
        _measure(res, backend.forward(test_x), test_y, base_accuracy)
    return res


# ---------------------------------------------------------------------------
# 2. Autoencoder compression at the cut (DeepCOD-style [35]).

@dataclasses.dataclass
class AutoencoderBaseline:
    """Linear AE at the partition point, trained by ridge-regression on the
    calibration activations (closed form — no SGD needed for a linear AE)."""
    code_ratio: float = 0.25      # code dim = ratio * activation dim

    def offload(self, backend: ModelBackend, p: int, calib_x,
                device, server, channel, weights,
                test_x=None, test_y=None,
                base_accuracy: Optional[float] = None) -> ServingResult:
        assert p >= 1, "autoencoder needs an on-device segment"
        specs = backend.layer_specs()
        L = backend.num_layers
        acts, logits_c = backend.layer_activations(calib_x)
        # the cut activation = OUTPUT of layer p (input of p+1); at p == L
        # that's the logits themselves
        a = acts[p] if p < L else logits_c
        a = a.reshape(a.shape[0], -1)
        d = a.shape[-1]
        code = max(int(d * self.code_ratio), 1)
        # PCA-style closed-form linear AE: top-`code` principal directions
        mu = a.mean(0)
        ac = a - mu
        cov = (ac.T @ ac) / a.shape[0]
        _, vecs = jnp.linalg.eigh(cov.astype(jnp.float64))
        enc = vecs[:, -code:].astype(jnp.float32)      # (d, code)
        # wire: segment at f32 + encoder weights + compressed activation
        # (decoder lives server-side, off the radio link)
        wire = sum(specs[i].z_w for i in range(p)) * 32.0
        wire += d * code * 32.0                          # encoder shipped
        wire += specs[p - 1].z_x * (code / d) * 32.0     # compressed cut
        extra_dev = float(d * code)                    # encode MACs
        extra_srv = float(code * d)                    # decode MACs
        res = _result(_plan_stub(p, wire), specs, device, server, channel,
                      weights, extra_dev, extra_srv)
        if test_x is not None:
            acts_t, logits_t = backend.layer_activations(test_x)
            at = acts_t[p] if p < L else logits_t
            shape_t = at.shape
            at = at.reshape(at.shape[0], -1)
            recon = ((at - mu) @ enc @ enc.T + mu).reshape(shape_t)
            logits = backend.forward_from_layer(recon, p) if p < L else recon
            _measure(res, logits, test_y, base_accuracy)
        res.extra["code_dim"] = code
        return res


# ---------------------------------------------------------------------------
# 3. Magnitude pruning of the device segment ([44][45]).

def _pruned_params(params, p: int, retain: float):
    pruned = [dict(lp) for lp in params]
    kept_elems = []
    for i in range(p):
        w = pruned[i]["w"]
        thresh = jnp.quantile(jnp.abs(w), 1.0 - retain)
        mask = jnp.abs(w) >= thresh
        pruned[i]["w"] = w * mask
        kept_elems.append(float(mask.sum()))
    return pruned, kept_elems


@dataclasses.dataclass
class PruningBaseline:
    retain: float = 0.5           # fraction of weights kept per layer

    def offload(self, backend: ModelBackend, p: int,
                device, server, channel, weights,
                test_x=None, test_y=None,
                base_accuracy: Optional[float] = None) -> ServingResult:
        specs = backend.layer_specs()
        pruned, kept_elems = _pruned_params(backend.params, p, self.retain)
        # wire: sparse encoding ~ (32-bit value + 32-bit index) per kept
        # weight — the honest cost of unstructured sparsity
        wire = sum(k * 64.0 for k in kept_elems)
        wire += (specs[p - 1].z_x if p else backend.input_elements()) * 32.0
        # device MACs shrink with the retained fraction
        o_dev = sum(specs[i].o * self.retain for i in range(p))
        o_full_dev = sum(specs[i].o for i in range(p))
        res = _result(_plan_stub(p, wire), specs, device, server, channel,
                      weights, extra_dev_macs=o_dev - o_full_dev)
        if test_x is not None:
            if p >= 1:
                h = backend.run_prefix(test_x, p, params=pruned)
                logits = backend.forward_from_layer(h, p)
            else:
                logits = backend.forward(test_x)
            _measure(res, logits, test_y, base_accuracy)
        res.extra["retain"] = self.retain
        return res

    def calibrated(self, backend: ModelBackend, p: int, calib_x, calib_y,
                   budget: float, base_accuracy: float):
        """Pick the lowest retention whose measured degradation stays within
        ``budget`` (the paper matches pruning degradation to QPART's)."""
        for retain in (0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.0):
            pruned, _ = _pruned_params(backend.params, p, retain)
            h = backend.run_prefix(calib_x, p, params=pruned)
            logits = backend.forward_from_layer(h, p)
            acc = float(jnp.mean(jnp.argmax(logits, -1) == calib_y))
            if base_accuracy - acc <= budget:
                return dataclasses.replace(self, retain=retain)
        return dataclasses.replace(self, retain=1.0)
