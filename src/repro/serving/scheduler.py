"""Dynamic workload balancing across concurrent requests (the paper's
title's second half; §VI names global scheduling as the planned extension
— this is the natural instantiation consistent with the paper's own cost
model).

Mechanism: the server is a finite resource (MAC/s). Each admitted plan's
server segment occupies it for ``T_server`` seconds, so later requests in
the scheduling window see a QUEUE DELAY on their server term. The balancer
re-prices every candidate (b, p) pattern per request with the CURRENT
congestion — as the queue grows, Alg. 2's objective naturally shifts work
toward capable devices (larger p), which is exactly the workload balancing
the title promises: no new math, the paper's Eq. 17 objective re-evaluated
under load.

Two policies:
  * fcfs      — requests priced in arrival order, each seeing the queue
                left by its predecessors.
  * balanced  — same, but requests are admitted shortest-server-demand
                first (SJF-flavoured), which provably reduces the mean
                queueing term for the same total work.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import (ObjectiveWeights, ServerProfile,
                                   cost_breakdown, delta_coeff, eps_coeff,
                                   xi_coeff)
from repro.serving.simulator import InferenceRequest, ServingResult


@dataclasses.dataclass
class ScheduledResult:
    request: InferenceRequest
    result: ServingResult
    queue_delay: float              # server wait this request experienced
    start_order: int


@dataclasses.dataclass
class WorkloadBalancer:
    """Prices a window of requests against one shared server."""
    server: ServerProfile
    policy: str = "balanced"        # fcfs | balanced

    def schedule(self, qpart_server, requests: Sequence[InferenceRequest],
                 ) -> List[ScheduledResult]:
        order = list(range(len(requests)))
        if self.policy == "balanced":
            # shortest-server-demand first, estimated at zero load
            demands = [self._server_seconds(qpart_server, r, 0.0)
                       for r in requests]
            order = list(np.argsort(demands))
        busy_until = 0.0
        out = []
        for rank, idx in enumerate(order):
            req = requests[idx]
            res = self._serve_under_load(qpart_server, req, busy_until)
            t_srv = res.costs.t_server
            out.append(ScheduledResult(req, res, busy_until, rank))
            busy_until += t_srv
        out.sort(key=lambda sr: requests.index(sr.request))
        return out

    # ------------------------------------------------------------------
    def _server_seconds(self, srv, req, queue: float) -> float:
        res = self._serve_under_load(srv, req, queue)
        return res.costs.t_server

    def _serve_under_load(self, srv, req: InferenceRequest,
                          queue: float) -> ServingResult:
        """Alg. 2 with the queue delay added to the server time term."""
        m = srv.models[req.model]
        from repro.core.cost_model import classifier_layer_specs
        specs = classifier_layer_specs(m.cfg, batch=req.batch)
        o = np.array([sp.o for sp in specs])
        o_cum = np.cumsum(o)
        xi = xi_coeff(req.weights, req.device)
        dl = delta_coeff(req.weights, self.server)
        ep = eps_coeff(req.weights, req.device, req.channel)

        def objective(plan):
            o1 = o_cum[plan.p - 1] if plan.p else 0.0
            o2 = float(o_cum[-1] - o1)
            wire = plan.payload_x_bits if req.segment_cached \
                else plan.payload_bits
            base = xi * o1 + dl * o2 + ep * wire
            # queueing: the server term waits for the backlog — but only
            # if this plan uses the server at all
            wait = req.weights.omega * queue if o2 > 0 else 0.0
            return base + wait

        plan = m.store.lookup(req.accuracy_budget, objective)
        wire = plan.payload_x_bits if req.segment_cached else plan.payload_bits
        o1 = float(o_cum[plan.p - 1]) if plan.p else 0.0
        o2 = float(o_cum[-1] - o1)
        costs = cost_breakdown(o1, o2, wire, req.device, self.server,
                               req.channel)
        res = ServingResult(plan=plan, costs=costs,
                            objective=costs.objective(req.weights)
                            + req.weights.omega * (queue if o2 > 0 else 0.0),
                            payload_bits=wire)
        res.extra["queue_delay"] = queue if o2 > 0 else 0.0
        return res


def total_latency(results: List[ScheduledResult]) -> float:
    return sum(sr.result.costs.t_total + sr.result.extra["queue_delay"]
               for sr in results)
