"""Dynamic workload balancing across concurrent requests (the paper's
title's second half; §VI names global scheduling as the planned extension
— this is the natural instantiation consistent with the paper's own cost
model).

Mechanism: the server is a finite resource (MAC/s). Each admitted plan's
server segment occupies it for ``T_server`` seconds, so later requests in
the scheduling window see a QUEUE DELAY on their server term. The balancer
re-prices every candidate (b, p) pattern per request with the CURRENT
congestion — as the queue grows, Alg. 2's objective naturally shifts work
toward capable devices (larger p), which is exactly the workload balancing
the title promises: no new math, the paper's Eq. 17 objective re-evaluated
under load.

Execution: the zero-load objective of every (request, partition) pair is
precomputed as ONE (R, P+1) matrix (DESIGN.md §5); the sequential
admission loop then only adds the scalar queue term to a row and takes an
argmin — no per-request store scans or Python objective closures. Each
admission yields a ``Deployment`` (plan + priced costs + callable
quantized segment), same as ``serve``/``serve_batch``.

Two policies:
  * fcfs      — requests priced in arrival order, each seeing the queue
                left by its predecessors.
  * balanced  — same, but requests are admitted shortest-server-demand
                first (SJF-flavoured), which provably reduces the mean
                queueing term for the same total work.

Since the event-driven engine landed (serving.engine, DESIGN.md §8) this
module is the COMPATIBILITY SURFACE over it: ``schedule()`` runs the
``FleetEngine`` in its degenerate configuration — one server, arrivals
as given (all t=0 for plain requests) — which reproduces the historical
one-shot behavior plan-for-plan and objective-for-objective. fcfs and
balanced are two of the engine's pluggable ``AdmissionPolicy``
implementations (see engine/policies.py for EDF and least-loaded). The
scalar per-request re-pricing (``_serve_under_load``) stays here as the
executable reference both paths are regression-locked against.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import (ServerProfile, cost_breakdown,
                                   delta_coeff, eps_coeff, xi_coeff)
from repro.serving.deployment import Deployment, ReferenceContext
from repro.serving.engine import FleetEngine
from repro.serving.simulator import InferenceRequest, ServingResult


@dataclasses.dataclass
class ScheduledResult:
    request: InferenceRequest
    deployment: Deployment
    queue_delay: float              # server wait this request experienced
    start_order: int

    @property
    def result(self) -> ServingResult:
        """Priced result of the deployment (view)."""
        return self.deployment.result


@dataclasses.dataclass
class WorkloadBalancer:
    """Prices a window of requests against one shared server.
    ``provider`` overrides the cost provider (default: the
    qpart_server's — AnalyticCost unless configured otherwise)."""
    server: ServerProfile
    policy: str = "balanced"        # fcfs | balanced
    provider: Optional[object] = None   # CostProvider

    def schedule(self, qpart_server, requests: Sequence[InferenceRequest],
                 context: Optional[ReferenceContext] = None,
                 ) -> List[ScheduledResult]:
        """The event engine's degenerate configuration: one server, the
        requests' own arrival times (0 by default, i.e. one simultaneous
        window). Records come back in trace order, same as before."""
        if not len(requests):
            return []
        engine = FleetEngine(qpart_server, servers=[self.server],
                             policy=self.policy, provider=self.provider)
        records = engine.run(requests, context=context).records
        return [ScheduledResult(rec.request, rec.deployment,
                                rec.backlog_at_admission, rec.start_order)
                for rec in records]

    # ------------------------------------------------------------------
    # Scalar reference path (kept for the benchmark's before/after and as
    # executable documentation of the per-request Alg. 2 re-pricing).
    def _server_seconds(self, srv, req, queue: float) -> float:
        res = self._serve_under_load(srv, req, queue)
        return res.costs.t_server

    def _serve_under_load(self, srv, req: InferenceRequest, queue: float,
                          context: Optional[ReferenceContext] = None,
                          ) -> ServingResult:
        """Alg. 2 with the queue delay added to the server time term.
        ``context`` must match what ``schedule`` was given for the
        before/after comparison to price against the same plan table."""
        m = srv.models[req.model]
        specs = m.backend.layer_specs(batch=req.batch)
        o = np.array([sp.o for sp in specs])
        o_cum = np.cumsum(o)
        xi = xi_coeff(req.weights, req.device)
        dl = delta_coeff(req.weights, self.server)
        ep = eps_coeff(req.weights, req.device, req.channel)

        def objective(plan):
            o1 = o_cum[plan.p - 1] if plan.p else 0.0
            o2 = float(o_cum[-1] - o1)
            wire = plan.payload_x_bits if req.segment_cached \
                else plan.payload_bits
            base = xi * o1 + dl * o2 + ep * wire
            wait = req.weights.omega * queue if o2 > 0 else 0.0
            return base + wait

        plan = m.store(context).lookup(
            req.accuracy_budget, objective,
            feasible_fn=lambda pl:
                pl.device_memory_bytes <= req.device.memory_bytes)
        wire = plan.payload_x_bits if req.segment_cached else plan.payload_bits
        o1 = float(o_cum[plan.p - 1]) if plan.p else 0.0
        o2 = float(o_cum[-1] - o1)
        costs = cost_breakdown(o1, o2, wire, req.device, self.server,
                               req.channel)
        res = ServingResult(plan=plan, costs=costs,
                            objective=costs.objective(req.weights)
                            + req.weights.omega * (queue if o2 > 0 else 0.0),
                            payload_bits=wire)
        res.extra["queue_delay"] = queue if o2 > 0 else 0.0
        return res


def total_latency(results) -> float:
    """Sum of per-request latency incl. queue delay. Accepts anything
    with a ``.result`` view (``ScheduledResult`` or ``Deployment``) —
    results from ``serve``/``serve_batch`` never saw a queue, so a
    missing ``queue_delay`` reads as 0 instead of raising ``KeyError``."""
    return sum(sr.result.costs.t_total
               + sr.result.extra.get("queue_delay", 0.0) for sr in results)
