"""Dynamic workload balancing across concurrent requests (the paper's
title's second half; §VI names global scheduling as the planned extension
— this is the natural instantiation consistent with the paper's own cost
model).

Mechanism: the server is a finite resource (MAC/s). Each admitted plan's
server segment occupies it for ``T_server`` seconds, so later requests in
the scheduling window see a QUEUE DELAY on their server term. The balancer
re-prices every candidate (b, p) pattern per request with the CURRENT
congestion — as the queue grows, Alg. 2's objective naturally shifts work
toward capable devices (larger p), which is exactly the workload balancing
the title promises: no new math, the paper's Eq. 17 objective re-evaluated
under load.

Execution: the zero-load objective of every (request, partition) pair is
precomputed as ONE (R, P+1) matrix (DESIGN.md §5); the sequential
admission loop then only adds the scalar queue term to a row and takes an
argmin — no per-request store scans or Python objective closures. Each
admission yields a ``Deployment`` (plan + priced costs + callable
quantized segment), same as ``serve``/``serve_batch``.

Two policies:
  * fcfs      — requests priced in arrival order, each seeing the queue
                left by its predecessors.
  * balanced  — same, but requests are admitted shortest-server-demand
                first (SJF-flavoured), which provably reduces the mean
                queueing term for the same total work.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import (ServerProfile, cost_breakdown,
                                   delta_coeff, eps_coeff, xi_coeff)
from repro.serving.deployment import Deployment, ReferenceContext
from repro.serving.pricing import WindowTable, price_window
from repro.serving.simulator import InferenceRequest, ServingResult


@dataclasses.dataclass
class ScheduledResult:
    request: InferenceRequest
    deployment: Deployment
    queue_delay: float              # server wait this request experienced
    start_order: int

    @property
    def result(self) -> ServingResult:
        """Priced result of the deployment (view)."""
        return self.deployment.result


@dataclasses.dataclass
class WorkloadBalancer:
    """Prices a window of requests against one shared server."""
    server: ServerProfile
    policy: str = "balanced"        # fcfs | balanced

    def schedule(self, qpart_server, requests: Sequence[InferenceRequest],
                 context: Optional[ReferenceContext] = None,
                 ) -> List[ScheduledResult]:
        if not len(requests):
            return []
        tab = price_window(qpart_server.models, self.server, requests,
                           context=context)
        # per-candidate server seconds and server-use masks from the
        # shared table's MAC columns
        t_server = [(row[-1] - row) * self.server.gamma / self.server.f_clock
                    for row in tab.o1]
        uses_server = [row[-1] - row > 0 for row in tab.o1]
        R = len(requests)
        order = list(range(R))
        if self.policy == "balanced":
            # shortest-server-demand first, estimated at zero load
            zero_choice = tab.argmin_choices()
            demands = np.array([t_server[i][zero_choice[i]]
                                for i in range(R)])
            order = list(np.argsort(demands))
        busy_until = 0.0
        out = []
        for rank, idx in enumerate(order):
            req = requests[idx]
            # queueing: the server term waits for the backlog — but only
            # if the candidate uses the server at all
            row = tab.obj[idx] \
                + req.weights.omega * busy_until * uses_server[idx]
            c = int(np.argmin(row))
            dep = self._deployment_at(qpart_server, tab, idx, c, req,
                                      busy_until)
            out.append((idx, ScheduledResult(req, dep, busy_until, rank)))
            busy_until += t_server[idx][c]
        # restore arrival order by the carried original index (a
        # requests.index() scan is O(n^2) and wrong for duplicates)
        out.sort(key=lambda t: t[0])
        return [sr for _, sr in out]

    # ------------------------------------------------------------------
    def _deployment_at(self, qpart_server, tab: WindowTable, idx: int,
                       c: int, req: InferenceRequest,
                       queue: float) -> Deployment:
        plan, o1, o2, wire = tab.select(idx, c)
        costs = cost_breakdown(o1, o2, wire, req.device, self.server,
                               req.channel)
        res = ServingResult(plan=plan, costs=costs,
                            objective=costs.objective(req.weights)
                            + req.weights.omega * (queue if o2 > 0 else 0.0),
                            payload_bits=wire)
        res.extra["queue_delay"] = queue if o2 > 0 else 0.0
        backend = qpart_server.models[req.model].backend
        return Deployment(req.model, backend, req, plan, res)

    # ------------------------------------------------------------------
    # Scalar reference path (kept for the benchmark's before/after and as
    # executable documentation of the per-request Alg. 2 re-pricing).
    def _server_seconds(self, srv, req, queue: float) -> float:
        res = self._serve_under_load(srv, req, queue)
        return res.costs.t_server

    def _serve_under_load(self, srv, req: InferenceRequest, queue: float,
                          context: Optional[ReferenceContext] = None,
                          ) -> ServingResult:
        """Alg. 2 with the queue delay added to the server time term.
        ``context`` must match what ``schedule`` was given for the
        before/after comparison to price against the same plan table."""
        m = srv.models[req.model]
        specs = m.backend.layer_specs(batch=req.batch)
        o = np.array([sp.o for sp in specs])
        o_cum = np.cumsum(o)
        xi = xi_coeff(req.weights, req.device)
        dl = delta_coeff(req.weights, self.server)
        ep = eps_coeff(req.weights, req.device, req.channel)

        def objective(plan):
            o1 = o_cum[plan.p - 1] if plan.p else 0.0
            o2 = float(o_cum[-1] - o1)
            wire = plan.payload_x_bits if req.segment_cached \
                else plan.payload_bits
            base = xi * o1 + dl * o2 + ep * wire
            wait = req.weights.omega * queue if o2 > 0 else 0.0
            return base + wait

        plan = m.store(context).lookup(
            req.accuracy_budget, objective,
            feasible_fn=lambda pl:
                pl.device_memory_bytes <= req.device.memory_bytes)
        wire = plan.payload_x_bits if req.segment_cached else plan.payload_bits
        o1 = float(o_cum[plan.p - 1]) if plan.p else 0.0
        o2 = float(o_cum[-1] - o1)
        costs = cost_breakdown(o1, o2, wire, req.device, self.server,
                               req.channel)
        res = ServingResult(plan=plan, costs=costs,
                            objective=costs.objective(req.weights)
                            + req.weights.omega * (queue if o2 > 0 else 0.0),
                            payload_bits=wire)
        res.extra["queue_delay"] = queue if o2 > 0 else 0.0
        return res


def total_latency(results: List[ScheduledResult]) -> float:
    return sum(sr.result.costs.t_total + sr.result.extra["queue_delay"]
               for sr in results)
