"""Simulation platform (paper §V): executing module + communication module
+ performance module.

The executing module evaluates the two model segments with the device /
server processing profiles (Table II); the communication module prices the
wireless transfer of the quantized segment and the cut activation with the
Shannon-capacity channel (Eq. 13–16); the performance module aggregates
CostBreakdowns. All timing is analytic (the paper's simulator is too) —
the *accuracy* numbers, by contrast, come from really executing the
quantized models in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.cost_model import (Channel, CostBreakdown, DeviceProfile,
                                   ObjectiveWeights, ServerProfile,
                                   cost_breakdown)
from repro.core.solver import PartitionPlan


@dataclasses.dataclass
class InferenceRequest:
    """r = (theta, a) + device/channel context (paper §III-A)."""
    model: str
    accuracy_budget: float              # max acceptable degradation `a`
    device: DeviceProfile
    channel: Channel
    weights: ObjectiveWeights = dataclasses.field(default_factory=ObjectiveWeights)
    batch: int = 1
    # Repeat requester whose device already holds the quantized segment:
    # the weight share of the wire (Eq. 14 Z_w) amortizes to zero and only
    # the cut activation Z_x is priced. This is where partitioning beats
    # p=0 full-offload (the Neurosurgeon regime) — a fresh request always
    # pays for the model shipment and usually prefers p=0.
    #
    # When the request carries a ``device_id`` the fleet engine OWNS this
    # flag: the per-device segment cache decides which candidates ship
    # weights, and the caller's value is ignored (engine/fleet.py).
    segment_cached: bool = False
    # -- continuous-time fields (serving.engine). The one-shot paths
    # (serve / serve_batch / WorkloadBalancer.schedule) ignore them, which
    # is exactly the all-arrivals-at-t=0 degenerate case of the engine.
    arrival_time: float = 0.0           # seconds on the fleet clock
    deadline: Optional[float] = None    # SLO: max end-to-end seconds from
    # arrival; None = best-effort
    device_id: Optional[str] = None     # stable requester identity — keys
    # the engine's segment cache AND fault injection (engine/faults.py)
    attempt_budget: Optional[int] = None  # per-request cap on admission
    # attempts under fault recovery; None = the RetryPolicy default
    max_new_tokens: int = 0             # autoregressive decode stream
    # length (DESIGN.md §11): 0 = one-shot (every pre-decode path —
    # bit-for-bit unchanged); N >= 1 streams N tokens, the first being
    # the prefill's (TTFT), through the serving server's continuous-
    # batching decode lane. Needs a decode-capable backend.


@dataclasses.dataclass
class ServingResult:
    plan: PartitionPlan
    costs: CostBreakdown
    objective: float
    payload_bits: float
    accuracy: Optional[float] = None    # measured, when a test set is given
    accuracy_degradation: Optional[float] = None
    attempt: int = 1                    # which admission attempt produced
    # this result (> 1 after fault-driven re-admissions, engine/retry.py)
    extra: dict = dataclasses.field(default_factory=dict)


def simulate_plan(plan: PartitionPlan, layer_specs, device: DeviceProfile,
                  server: ServerProfile, channel: Channel,
                  weights: ObjectiveWeights,
                  payload_bits: Optional[float] = None) -> ServingResult:
    """Price an arbitrary (p, payload) pattern — shared by QPART and every
    baseline so the comparison is apples-to-apples."""
    o = np.array([sp.o for sp in layer_specs], dtype=np.float64)
    o1 = float(o[:plan.p].sum())
    o2 = float(o[plan.p:].sum())
    pb = plan.payload_bits if payload_bits is None else payload_bits
    costs = cost_breakdown(o1, o2, pb, device, server, channel)
    return ServingResult(plan=plan, costs=costs,
                         objective=costs.objective(weights),
                         payload_bits=pb)
