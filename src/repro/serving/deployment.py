"""Plan → deploy → execute: the objects the online pipeline hands out.

``QPARTServer`` keys its offline stores by a ``ReferenceContext`` (the
device/channel/weights Alg. 1 optimized for) and its online entry points
(``serve`` / ``serve_batch`` / ``WorkloadBalancer.schedule``) return a
``Deployment``: the chosen plan, its priced costs, and a callable
quantized device segment — with measurement (really running the
partitioned, quantized model on a test set) an explicit separate step,
``Deployment.execute``, instead of an optional side effect of serving.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights)
from repro.core.solver import PartitionPlan
from repro.serving.backends.base import DeviceExecutor, ModelBackend
from repro.serving.simulator import InferenceRequest, ServingResult


@dataclasses.dataclass(frozen=True)
class ReferenceContext:
    """The (device, channel, weights) a pattern store was built against
    (Alg. 1's reference request). Hashable — all three profiles are frozen
    dataclasses — so one model holds stores for many contexts side by
    side instead of each ``build_store`` overwriting the last."""
    device: DeviceProfile
    channel: Channel
    weights: ObjectiveWeights


@dataclasses.dataclass
class Deployment:
    """One served request: the plan Alg. 2 picked, its priced costs, and
    the means to really run it. Cheap to create — the quantized segment
    materializes lazily on first ``device_segment()``/``execute`` so the
    batched pricing paths never pay for quantization."""
    model: str
    backend: ModelBackend
    request: InferenceRequest
    plan: PartitionPlan
    result: ServingResult
    _segment: Optional[DeviceExecutor] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- convenience views over the priced result -----------------------
    @property
    def costs(self):
        return self.result.costs

    @property
    def objective(self) -> float:
        return self.result.objective

    @property
    def payload_bits(self) -> float:
        return self.result.payload_bits

    @property
    def extra(self) -> dict:
        return self.result.extra

    @property
    def queue_delay(self) -> float:
        """Server queue delay priced into this deployment's objective —
        0.0 on the queue-less paths (``serve``/``serve_batch``)."""
        return self.result.extra.get("queue_delay", 0.0)

    @property
    def accuracy(self):
        return self.result.accuracy

    @property
    def accuracy_degradation(self):
        return self.result.accuracy_degradation

    # -- deploy ---------------------------------------------------------
    def device_segment(self) -> DeviceExecutor:
        """The callable quantized device segment (lazily materialized):
        maps a raw input batch to the quantized cut activation the device
        would uplink. Cached — repeated execute calls quantize once."""
        if self._segment is None:
            self._segment = self.backend.device_executor(self.plan)
        return self._segment

    # -- execute --------------------------------------------------------
    def execute(self, test_x, test_y) -> ServingResult:
        """Really run the partitioned, quantized model on (test_x,
        test_y): quantized device segment, quantized cut activation,
        full-precision server tail. Fills ``result.accuracy`` and
        ``result.accuracy_degradation`` (vs the full-precision model on
        the SAME test set) and returns the result.

        The two compute stages are wall-clock fenced
        (``jax.block_until_ready`` between them) and recorded into
        ``result.extra['measured']`` alongside the predicted breakdown
        (``result.costs``), so predicted-vs-measured fidelity is
        inspectable on every executed deployment — and feedable into
        ``QPARTServer.record_execution`` / the calibration ledger
        (DESIGN.md §9). First execution of a (p, shape) pays XLA
        compilation; re-execute (the compile caches persist) before
        trusting the timings."""
        t0 = time.perf_counter()
        if self.plan.p:
            h = jax.block_until_ready(self.device_segment()(test_x))
            t1 = time.perf_counter()
            logits = jax.block_until_ready(
                self.backend.forward_from_layer(h, self.plan.p))
        else:
            t1 = t0
            logits = jax.block_until_ready(self.backend.forward(test_x))
        t2 = time.perf_counter()
        self.result.extra["measured"] = {
            "batch": int(test_x.shape[0]),
            "t_device_s": t1 - t0,
            "t_server_s": t2 - t1,
            "t_total_s": t2 - t0,
            # the prediction the same stages were priced at (provider
            # breakdown; radio time excluded — nothing is transmitted)
            "t_device_pred_s": self.result.costs.t_local,
            "t_server_pred_s": self.result.costs.t_server,
        }
        acc = float(jnp.mean(jnp.argmax(logits, -1) == test_y))
        # memoized per test-set identity on the backend: a window of
        # deployments executing against one test set pays for the
        # full-precision baseline forward once
        base = self.backend.evaluate(test_x, test_y)
        self.result.accuracy = acc
        self.result.accuracy_degradation = base - acc
        return self.result

    # -- generate (autoregressive decode, DESIGN.md §11) ----------------
    def decode_session(self, max_len: Optional[int] = None,
                       prefill_chunk_tokens: Optional[int] = None,
                       draft_tokens: int = 0):
        """A fresh ``DecodeSession`` on this deployment's plan, reusing
        the lazily-materialized quantized device segment. The serving-
        shape knobs (DESIGN.md §14) pass through: ``prefill_chunk_tokens``
        admits the prompt in chunks, ``draft_tokens`` turns decode rounds
        speculative — both bit-identical to the plain pipeline."""
        from repro.serving.decode import DecodeSession
        seg = self.device_segment().segment if self.plan.p else None
        if max_len is None:
            max_len = getattr(self.backend, "decode_max_len", None) \
                or 2 * getattr(self.backend, "seq_len", 1)
        return DecodeSession(self.backend, self.plan, max_len=max_len,
                             segment=seg,
                             prefill_chunk_tokens=prefill_chunk_tokens,
                             draft_tokens=draft_tokens)

    def generate(self, prompt, max_new_tokens: int, *,
                 max_len: Optional[int] = None, stream_cb=None,
                 prefill_chunk_tokens: Optional[int] = None,
                 draft_tokens: int = 0):
        """Stream ``max_new_tokens`` greedy tokens through the
        partitioned prefill→decode pipeline (quantized device segment
        ``[0, p)`` with its cache at the deployed bit-width's dtype,
        full-precision server tail ``[p, L)``). Wall-clock stage seconds
        land in ``result.extra['measured_decode']`` — the sample
        ``CalibrationLedger.record_decode`` regresses per-token rates
        from. ``stream_cb(i, token)`` observes tokens as they decode.
        Returns a ``decode.GenerationResult``."""
        sess = self.decode_session(max_len=max_len,
                                   prefill_chunk_tokens=prefill_chunk_tokens,
                                   draft_tokens=draft_tokens)
        out = sess.generate(prompt, max_new_tokens, stream_cb=stream_cb)
        self.result.extra["measured_decode"] = {
            "batch": int(out.tokens.shape[0]),
            "new_tokens": out.new_tokens,
            "ttft_s": out.ttft_s,
            "t_device_s": out.t_device_s,
            "t_server_s": out.t_server_s,
            "t_total_s": out.t_total_s,
            "tokens_per_s": out.tokens_per_s,
            "device_cache_bytes": out.device_cache_bytes,
            "device_cache_dtype": out.device_cache_dtype,
            # serving-shape measurements (DESIGN.md §14): rounds counts
            # decode rounds; accept_rate is the measured draft
            # acceptance the CalibrationLedger feeds back into the
            # expected-tokens-per-round pricing term (None = no drafts)
            "rounds": out.rounds,
            "draft_tokens": out.draft_tokens,
            "drafts_proposed": out.drafts_proposed,
            "drafts_accepted": out.drafts_accepted,
            "accept_rate": out.accept_rate,
            "prefill_chunks": out.prefill_chunks,
        }
        return out
