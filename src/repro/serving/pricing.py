"""Shared window pricing: Alg. 2's objective for every (request,
partition point) pair of a request window, as one matrix op per model
group (DESIGN.md §5, generalized by the provider layer of §9):

    obj[r, p] = sum_k  c_k[r] · T_k[p]

with ``c_k`` the provider's per-request coefficients and ``T_k`` the
per-candidate term vectors (``CandidateRows``). The analytic default is
the paper's K=3 instance — xi·O1 + delta·O2 + eps·wire — accumulated in
the same association order as the pre-provider code, so its objective
matrices are bit-identical (locked in tests/test_cost_model.py).

This is the single implementation both batched online paths build on:
``QPARTServer.serve_batch`` (argmin per row → Deployment) and
``WorkloadBalancer``/``FleetEngine`` (adds queue/server terms per
admission step). Partition candidates whose deployed quantized segment
exceeds the request device's ``memory_bytes`` are masked to +inf before
any argmin — the matrix form of the scalar path's ``OfflineStore.lookup``
feasibility filter.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional, Sequence

import numpy as np

from repro.core.cost_model import (ANALYTIC, CandidateRows, CostProvider,
                                   ServerProfile, act_bytes_row,
                                   candidate_byte_rows)
from repro.serving.simulator import InferenceRequest

if TYPE_CHECKING:                        # pricing stays JAX-import-free
    from repro.serving.deployment import ReferenceContext


@dataclasses.dataclass
class WindowTable:
    """Zero-load pricing of a request window against the plan table.
    Entry i is a per-request view into its model group's stacked
    matrices, so one window may mix models with different layer counts."""
    obj: List[np.ndarray]           # per request: (P+1,) Eq. 17, no queue
    o1: List[np.ndarray]            # per request: (P+1,) device-side MACs
    wire: List[np.ndarray]          # per request: (P+1,) wire bits
    plans: List[list]               # per request: candidate plan list
    groups: list                    # [(request indices, (G, P+1) obj)]
    # both payload rows per request — the fleet engine re-prices single
    # candidates between them when its device cache holds a segment
    # (wire[i] is the row the request's segment_cached flag selected)
    pb: List[np.ndarray] = dataclasses.field(default_factory=list)
    px: List[np.ndarray] = dataclasses.field(default_factory=list)
    # per-request CandidateRows — the provider term vectors the fleet
    # engine's server corrections / stage estimates / breakdowns consume
    rows: List[CandidateRows] = dataclasses.field(default_factory=list)

    def argmin_choices(self) -> np.ndarray:
        """Best partition point per request — one matrix argmin per
        model group rather than a per-request scan."""
        choices = np.empty(len(self.obj), dtype=int)
        for idxs, obj in self.groups:
            choices[idxs] = np.argmin(obj, axis=1)
        return choices

    def select(self, i: int, c: int):
        """(plan, o1, o2, wire) of candidate c for request i — the one
        place the result-assembly terms derive from the table."""
        plan = self.plans[i][c]
        o1 = float(self.o1[i][c])
        o2 = float(self.o1[i][-1] - o1)
        return plan, o1, o2, float(self.wire[i][c])


def _assemble_rows(specs, store, a_star: float, cached: bool,
                   need_bytes: bool, o1: np.ndarray,
                   ab_cum) -> CandidateRows:
    """THE CandidateRows assembly (single implementation): ``o1`` and
    ``ab_cum`` come precomputed so ``price_window`` can share them
    across keys of one batch size."""
    pb, px = store.level_payload_rows(a_star)
    dev_b = srv_b = None
    if need_bytes:
        dev_b, srv_b = candidate_byte_rows(
            specs, store.level_memory_rows(a_star), ab_cum)
    return CandidateRows(o1=o1, o2=o1[-1] - o1, wire=px if cached else pb,
                         dev_bytes=dev_b, srv_bytes=srv_b)


def candidate_rows_for(backend, store, a_star: float, batch: int,
                       cached: bool, need_bytes: bool) -> CandidateRows:
    """The per-candidate term vectors of one (model, level, batch,
    cached) pricing profile — the scalar ``serve`` path's entry into
    the same ``_assemble_rows`` the window path uses."""
    specs = backend.layer_specs(batch=batch)
    o1 = np.concatenate([[0.0], np.cumsum([sp.o for sp in specs])])
    ab_cum = act_bytes_row(specs) if need_bytes else None
    return _assemble_rows(specs, store, a_star, cached, need_bytes, o1,
                          ab_cum)


def decode_rows_for(backend, store, a_star: float, batch: int,
                    need_bytes: bool) -> CandidateRows:
    """Per-TOKEN candidate term vectors of one decode step (DESIGN.md
    §11): the same assembly as ``candidate_rows_for`` but over the
    backend's decode-mode layer specs, so ``o1``/``o2`` are MACs per
    generated token and the byte rows carry the per-step KV read/write
    traffic. The ``wire`` row is the payload table's shipment row and is
    NOT the per-token wire — callers price the per-step hidden-state hop
    themselves (one activation vector, not a sequence)."""
    specs = backend.decode_layer_specs(batch=batch)
    o1 = np.concatenate([[0.0], np.cumsum([sp.o for sp in specs])])
    ab_cum = act_bytes_row(specs) if need_bytes else None
    return _assemble_rows(specs, store, a_star, False, need_bytes, o1,
                          ab_cum)


def prefill_chunk_rows_for(backend, store, a_star: float, batch: int,
                           chunk_tokens: int,
                           need_bytes: bool) -> CandidateRows:
    """Per-CHUNK candidate term vectors of a chunked prefill (DESIGN.md
    §14): the same assembly as ``candidate_rows_for`` but over layer
    specs at the CHUNK length, so ``o1``/``o2`` are MACs per admitted
    chunk — what one PREFILL_CHUNK round of the fleet's decode lane
    costs. A prompt of n chunks prices as n of these rows instead of
    one monolithic prompt-length row; the dense terms agree exactly
    (linear in sequence length) while the attention term is chunk-local
    — a lower bound that misses cross-chunk attention, which is why the
    fleet's chunk lane splits the calibrated monolithic ``t_server``
    evenly across chunks (sums exactly) and uses these rows only for
    relative per-cut comparisons. ``wire`` stays the shipment row, as
    in ``decode_rows_for``."""
    if int(chunk_tokens) < 2:
        raise ValueError("chunk_tokens must be >= 2 (pipeline contract)")
    specs = backend.layer_specs(batch=batch, seq_len=int(chunk_tokens))
    o1 = np.concatenate([[0.0], np.cumsum([sp.o for sp in specs])])
    ab_cum = act_bytes_row(specs) if need_bytes else None
    return _assemble_rows(specs, store, a_star, False, need_bytes, o1,
                          ab_cum)


def price_window(models, server: ServerProfile,
                 requests: Sequence[InferenceRequest],
                 context: Optional["ReferenceContext"] = None,
                 provider: Optional[CostProvider] = None,
                 cache: Optional[dict] = None) -> WindowTable:
    """``models``: name -> ModelState (raises ``UnknownModelError`` /
    ``NotCalibratedError`` through ``ModelState.store`` when a request
    names an unregistered or un-calibrated model).

    ``cache``: optional caller-owned dict persisting the per-(level,
    batch, cached) row tuples and per-batch layer specs ACROSS calls —
    the fleet engine prices thousands of epochs against the same stores,
    and rebuilding identical ``CandidateRows`` per epoch dominates at
    scale. The caller owns invalidation: drop the dict whenever the
    models, stores, context or provider it was filled under change.
    Rows coming out of a shared cache are the SAME objects every call
    (stable identity), which downstream per-``id(rows)`` caches rely on.
    """
    from repro.serving.errors import UnknownModelError

    provider = ANALYTIC if provider is None else provider
    need_bytes = provider.uses_bytes
    R = len(requests)
    tab = WindowTable(obj=[None] * R, o1=[None] * R, wire=[None] * R,
                      plans=[None] * R, groups=[],
                      pb=[None] * R, px=[None] * R, rows=[None] * R)
    by_model = {}
    for i, r in enumerate(requests):
        by_model.setdefault(r.model, []).append(i)
    for name, idxs in by_model.items():
        if name not in models:
            raise UnknownModelError(name, models)
        m = models[name]
        store = m.store(context)
        group = [requests[i] for i in idxs]
        # per-request coefficient vectors — ONE cached lookup per
        # distinct (weights, device, channel) profile instead of three
        # list-comprehension recomputes per window
        coeff = np.stack([provider.coeffs_cached(r.weights, r.device,
                                                 r.channel, server)
                          for r in group])                   # (G, K)
        # rows cached per (accuracy level, batch, cached) — large windows
        # with few distinct budgets reuse one (terms, plans, payloads,
        # memory) tuple instead of rebuilding identical rows per request
        if cache is not None:
            rows_cache = cache.setdefault((name, "rows"), {})
            by_batch = cache.setdefault((name, "batch"), {})
        else:
            rows_cache = {}
            by_batch = {}      # batch -> (specs, o1 row, ab_cum row)
        plans, mem_rows = [], []
        row_objs, pb_rows, px_rows = [], [], []
        for r in group:
            key = (store.level_for(r.accuracy_budget), r.batch,
                   bool(r.segment_cached))
            if key not in rows_cache:
                a_star, batch, cached = key
                if batch not in by_batch:
                    specs = m.backend.layer_specs(batch=batch)
                    o1_r = np.concatenate(
                        [[0.0], np.cumsum([sp.o for sp in specs])])
                    by_batch[batch] = (specs, o1_r,
                                       act_bytes_row(specs)
                                       if need_bytes else None)
                specs, o1_r, ab_cum = by_batch[batch]
                crow = _assemble_rows(specs, store, a_star, cached,
                                      need_bytes, o1_r, ab_cum)
                pb, px = store.level_payload_rows(a_star)
                rows_cache[key] = (crow, store.level_plans(a_star),
                                   store.level_memory_rows(a_star), pb, px)
            crow, plans_r, mem_r, pb_r, px_r = rows_cache[key]
            row_objs.append(crow)
            plans.append(plans_r)
            mem_rows.append(mem_r)
            pb_rows.append(pb_r)
            px_rows.append(px_r)
        # obj = sum_k c_k[:, None] · T_k — accumulated in term order, so
        # the analytic provider reproduces the historical
        # xi·O1 + delta·O2 + eps·wire float-for-float
        term_stacks = [np.stack(ts) for ts in zip(
            *(provider.terms(cr) for cr in row_objs))]       # K × (G, P+1)
        obj = coeff[:, 0, None] * term_stacks[0]
        for k in range(1, len(term_stacks)):
            obj = obj + coeff[:, k, None] * term_stacks[k]
        # device-memory admission (plan-time): infeasible candidates can
        # never win the argmin. p=0 holds no device weights, so a finite
        # column always remains.
        mem = np.stack(mem_rows)
        # decode-planned backends (decode_max_len set) additionally hold
        # the device segment's KV cache for the stream's lifetime —
        # candidate c's resident footprint is weights + cache (None for
        # classifiers / prefill-only backends: mask unchanged; getattr
        # tolerates spec-only backend stubs in tests). With
        # ``kv_page_tokens`` set the stream is priced at its
        # page-rounded ACTUAL context (prompt + its own new tokens)
        # instead of the max_len worst case — strictly <= the dense
        # reservation, so the mask only ever widens.
        kv_fn = getattr(m.backend, "kv_bytes_row", None)
        paged = kv_fn is not None and \
            getattr(m.backend, "kv_page_tokens", None) is not None
        if paged:
            seq = int(m.backend.seq_len)
            kv_rows = [kv_fn(r.batch,
                             tokens=seq + max(int(r.max_new_tokens), 1))
                       for r in group]
        else:
            kv_rows = [kv_fn(r.batch) if kv_fn else None for r in group]
        if any(k is not None for k in kv_rows):
            zero = np.zeros_like(mem[0])
            mem = mem + np.stack([zero if k is None else k
                                  for k in kv_rows])
        dev_mem = np.array([r.device.memory_bytes for r in group])
        obj = np.where(mem > dev_mem[:, None], np.inf, obj)
        tab.groups.append((idxs, obj))
        for j, i in enumerate(idxs):
            tab.obj[i], tab.o1[i] = obj[j], row_objs[j].o1
            tab.wire[i], tab.plans[i] = row_objs[j].wire, plans[j]
            tab.pb[i], tab.px[i] = pb_rows[j], px_rows[j]
            tab.rows[i] = row_objs[j]
    return tab
