"""Shared window pricing: Alg. 2's objective for every (request,
partition point) pair of a request window, as one matrix op per model
group (DESIGN.md §5).

    obj[r, p] = xi_r · O1[p] + delta_r · (O_total − O1[p]) + eps_r · wire[r, p]

This is the single implementation both batched online paths build on:
``QPARTServer.serve_batch`` (argmin per row → Deployment) and
``WorkloadBalancer`` (adds the queue term per admission step). Partition
candidates whose deployed quantized segment exceeds the request device's
``memory_bytes`` are masked to +inf before any argmin — the matrix form
of the scalar path's ``OfflineStore.lookup`` feasibility filter.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import (ServerProfile, delta_coeff, eps_coeff,
                                   xi_coeff)
from repro.serving.deployment import ReferenceContext
from repro.serving.simulator import InferenceRequest


@dataclasses.dataclass
class WindowTable:
    """Zero-load pricing of a request window against the plan table.
    Entry i is a per-request view into its model group's stacked
    matrices, so one window may mix models with different layer counts."""
    obj: List[np.ndarray]           # per request: (P+1,) Eq. 17, no queue
    o1: List[np.ndarray]            # per request: (P+1,) device-side MACs
    wire: List[np.ndarray]          # per request: (P+1,) wire bits
    plans: List[list]               # per request: candidate plan list
    groups: list                    # [(request indices, (G, P+1) obj)]
    # both payload rows per request — the fleet engine re-prices single
    # candidates between them when its device cache holds a segment
    # (wire[i] is the row the request's segment_cached flag selected)
    pb: List[np.ndarray] = dataclasses.field(default_factory=list)
    px: List[np.ndarray] = dataclasses.field(default_factory=list)

    def argmin_choices(self) -> np.ndarray:
        """Best partition point per request — one matrix argmin per
        model group rather than a per-request scan."""
        choices = np.empty(len(self.obj), dtype=int)
        for idxs, obj in self.groups:
            choices[idxs] = np.argmin(obj, axis=1)
        return choices

    def select(self, i: int, c: int):
        """(plan, o1, o2, wire) of candidate c for request i — the one
        place the result-assembly terms derive from the table."""
        plan = self.plans[i][c]
        o1 = float(self.o1[i][c])
        o2 = float(self.o1[i][-1] - o1)
        return plan, o1, o2, float(self.wire[i][c])


def price_window(models, server: ServerProfile,
                 requests: Sequence[InferenceRequest],
                 context: Optional[ReferenceContext] = None) -> WindowTable:
    """``models``: name -> ModelState (raises ``UnknownModelError`` /
    ``NotCalibratedError`` through ``ModelState.store`` when a request
    names an unregistered or un-calibrated model)."""
    from repro.serving.errors import UnknownModelError

    R = len(requests)
    tab = WindowTable(obj=[None] * R, o1=[None] * R, wire=[None] * R,
                      plans=[None] * R, groups=[],
                      pb=[None] * R, px=[None] * R)
    by_model = {}
    for i, r in enumerate(requests):
        by_model.setdefault(r.model, []).append(i)
    for name, idxs in by_model.items():
        if name not in models:
            raise UnknownModelError(name, models)
        m = models[name]
        store = m.store(context)
        group = [requests[i] for i in idxs]
        # per-request reduced coefficients (Eq. 24–26)
        xi = np.array([xi_coeff(r.weights, r.device) for r in group])
        dl = np.array([delta_coeff(r.weights, server) for r in group])
        ep = np.array([eps_coeff(r.weights, r.device, r.channel)
                       for r in group])
        # rows cached per (accuracy level, batch, cached) — large windows
        # with few distinct budgets reuse one (o1, plans, payloads,
        # memory) tuple instead of rebuilding identical rows per request
        rows_cache = {}
        plans, o1_rows, wire_rows, mem_rows = [], [], [], []
        pb_rows, px_rows = [], []
        o1_by_batch = {}
        for r in group:
            key = (store.level_for(r.accuracy_budget), r.batch,
                   bool(r.segment_cached))
            if key not in rows_cache:
                a_star, batch, cached = key
                if batch not in o1_by_batch:
                    specs = m.backend.layer_specs(batch=batch)
                    o1_by_batch[batch] = np.concatenate(
                        [[0.0], np.cumsum([sp.o for sp in specs])])
                pb, px = store.level_payload_rows(a_star)
                rows_cache[key] = (o1_by_batch[batch],
                                   store.level_plans(a_star),
                                   px if cached else pb,
                                   store.level_memory_rows(a_star), pb, px)
            o1_r, plans_r, wire_r, mem_r, pb_r, px_r = rows_cache[key]
            o1_rows.append(o1_r)
            plans.append(plans_r)
            wire_rows.append(wire_r)
            mem_rows.append(mem_r)
            pb_rows.append(pb_r)
            px_rows.append(px_r)
        o1 = np.stack(o1_rows)                          # (G, P+1)
        wire = np.stack(wire_rows)
        obj = xi[:, None] * o1 + dl[:, None] * (o1[:, -1:] - o1) \
            + ep[:, None] * wire
        # device-memory admission (plan-time): infeasible candidates can
        # never win the argmin. p=0 holds no device weights, so a finite
        # column always remains.
        mem = np.stack(mem_rows)
        dev_mem = np.array([r.device.memory_bytes for r in group])
        obj = np.where(mem > dev_mem[:, None], np.inf, obj)
        tab.groups.append((idxs, obj))
        for j, i in enumerate(idxs):
            tab.obj[i], tab.o1[i] = obj[j], o1[j]
            tab.wire[i], tab.plans[i] = wire[j], plans[j]
            tab.pb[i], tab.px[i] = pb_rows[j], px_rows[j]
    return tab
