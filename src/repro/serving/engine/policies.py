"""Pluggable admission policies for the fleet engine.

A policy decides, at each decision epoch, (a) in which ORDER the pending
requests are admitted — each admission sees the queue state its
predecessors left, so order is the whole game — and (b) how a server is
picked for each admission (``server_rule``):

  objective     — joint argmin over (server, partition candidate) of the
                  queue-adjusted Eq. 17 row: the QPART-native rule.
  least_loaded  — restrict to the server with the smallest work backlog
                  first, then argmin over candidates: pure load
                  balancing, ignores server-speed differences.

The historical ``WorkloadBalancer`` policies are the first two entries:
``fcfs`` and ``balanced`` (shortest-server-demand-first) admit exactly
as the one-shot scheduler did, which is what regression-locks the
degenerate one-server / simultaneous-arrivals case plan-for-plan.

Policies are stateless; all fleet state lives in the engine. Custom
policies subclass ``AdmissionPolicy`` and go straight into
``FleetEngine(policy=MyPolicy())``.
"""
from __future__ import annotations

import numpy as np


class AdmissionPolicy:
    """Base: admit in arrival order, objective-driven server choice."""

    name = "fcfs"
    server_rule = "objective"          # objective | least_loaded

    def order(self, pending, tab, t_server_rows):
        """Admission order as indices into ``pending``.

        ``pending`` — list of engine ``_Pending`` entries (``.request``,
        ``.arrival``, ``.index``); ``tab`` — the epoch's ``WindowTable``
        (row i prices pending[i]); ``t_server_rows`` — per-pending
        (P+1,) zero-load server seconds on the reference server.
        """
        return sorted(range(len(pending)),
                      key=lambda i: (pending[i].arrival, pending[i].index))


class FCFSPolicy(AdmissionPolicy):
    """First-come-first-served (the historical ``fcfs``)."""


class BalancedPolicy(AdmissionPolicy):
    """Shortest-server-demand first (SJF-flavoured; the historical
    ``balanced``): provably reduces the mean queueing term for the same
    total work. Demand is estimated at zero load from the window table —
    the same ``np.argsort`` the one-shot scheduler ran."""

    name = "balanced"

    def order(self, pending, tab, t_server_rows):
        zero_choice = tab.argmin_choices()
        demands = np.array([t_server_rows[i][zero_choice[i]]
                            for i in range(len(pending))])
        return list(np.argsort(demands))


class EDFPolicy(AdmissionPolicy):
    """Earliest-deadline-first: admit by absolute deadline (arrival +
    SLO). Jackson's rule — for a single queue this minimizes the maximum
    lateness, so any trace FCFS can meet end-to-end, EDF meets too.
    Deadline-less requests go last, among themselves in arrival order."""

    name = "edf"

    def order(self, pending, tab, t_server_rows):
        def key(i):
            r = pending[i].request
            if r.deadline is None:
                return (1, 0.0, pending[i].arrival, pending[i].index)
            return (0, pending[i].arrival + r.deadline,
                    pending[i].arrival, pending[i].index)
        return sorted(range(len(pending)), key=key)


class LeastLoadedPolicy(AdmissionPolicy):
    """Arrival order, but each admission goes to the server with the
    smallest work backlog regardless of the objective — the classic
    join-the-shortest-queue dispatcher, here as the contrast case to the
    objective-driven rule."""

    name = "least_loaded"
    server_rule = "least_loaded"


POLICIES = {cls.name: cls for cls in
            (FCFSPolicy, BalancedPolicy, EDFPolicy, LeastLoadedPolicy)}


def get_policy(policy) -> AdmissionPolicy:
    """'fcfs' | 'balanced' | 'edf' | 'least_loaded', or an
    ``AdmissionPolicy`` instance (returned as-is)."""
    if isinstance(policy, AdmissionPolicy):
        return policy
    if policy not in POLICIES:
        raise ValueError(f"unknown admission policy {policy!r}; "
                         f"known: {sorted(POLICIES)}")
    return POLICIES[policy]()
