"""Columnar record core of the fleet engine (DESIGN.md §12).

At 10⁶ requests, one ``FleetRecord`` dataclass per request is the
engine's dominant allocation cost and ``FleetMetrics``'s dominant
aggregation cost. ``RecordStore`` holds the SAME per-request facts as
preallocated NumPy columns: the engine's handlers write scalar slots
(cheap), ``FleetMetrics`` reduces whole columns (one vector op per
aggregate), and ``FleetRecord`` views are materialized lazily — only
for the records a caller actually touches — so the dataclass API stays
intact without 10⁶ up-front allocations.

Two record modes (``FleetEngine(records=...)``):

  "full"   — default. Also keeps the per-request ``Deployment`` object
             (plan + costs + lazily-built quantized device segment) in
             an object column: every ``FleetRecord`` field round-trips.
  "light"  — skips ``Deployment``/``ServingResult`` assembly entirely;
             stage boundaries are computed from the provider's
             ``device_seconds``/``server_seconds`` (identical floats to
             ``breakdown`` — locked in tests/test_fleet_scale.py), and
             materialized views carry ``deployment=None``. The mode for
             scale sweeps where nobody executes the plans.

The timeline lives as an (N, 6) float column block; NaN in the admit
slot means "no committed attempt" (never admitted, SLO-rejected, or the
last attempt was fault-cancelled) — exactly the states where the
dataclass engine kept ``timeline=None``.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.serving.engine.retry import DROP_REASONS
from repro.serving.simulator import InferenceRequest

# timeline column indices (StageTimeline field order)
TL_ADMIT, TL_SHIP, TL_DEVICE, TL_TRANSFER, TL_START, TL_FINISH = range(6)

# drop reasons as small ints (0 = not dropped); names in retry.py
DROP_CODES = {reason: k + 1 for k, reason in enumerate(DROP_REASONS)}
CODE_REASONS = {v: k for k, v in DROP_CODES.items()}


class RecordStore:
    """Preallocated per-request columns for one ``FleetEngine.run``."""

    def __init__(self, requests: Sequence[InferenceRequest],
                 full: bool = True):
        n = len(requests)
        self.n = n
        self.full = bool(full)
        self.requests = requests if isinstance(requests, list) \
            else list(requests)
        self.arrival = np.fromiter(
            (r.arrival_time for r in self.requests), np.float64, count=n)
        self.deadline = np.fromiter(
            (np.nan if r.deadline is None else r.deadline
             for r in self.requests), np.float64, count=n)
        self.server = np.full(n, -1, dtype=np.int64)
        self.start_order = np.full(n, -1, dtype=np.int64)
        self.backlog = np.zeros(n, dtype=np.float64)
        self.queue_delay = np.zeros(n, dtype=np.float64)
        self.degraded_to = np.full(n, np.nan, dtype=np.float64)
        self.rejected = np.zeros(n, dtype=bool)
        self.drop_code = np.zeros(n, dtype=np.int8)
        self.attempts = np.zeros(n, dtype=np.int32)
        self.faults = np.zeros(n, dtype=np.int32)
        self.parked = np.zeros(n, dtype=np.int32)
        self.decode_tokens = np.zeros(n, dtype=np.int64)
        self.tokens_emitted = np.zeros(n, dtype=np.int64)
        self.decode_done = np.full(n, np.nan, dtype=np.float64)
        self.payload_bits = np.full(n, np.nan, dtype=np.float64)
        self.tl = np.full((n, 6), np.nan, dtype=np.float64)
        self.deployments = np.full(n, None, dtype=object) if full else None

    # -- engine-side mutations -----------------------------------------
    def reset_attempt(self, i: int) -> None:
        """Void a fault-cancelled attempt's per-attempt fields (the
        dataclass engine nulled the same set); ``attempts``/``faults``/
        ``parked`` are per-request counters and survive."""
        if self.full:
            self.deployments[i] = None
        self.tl[i] = np.nan
        self.server[i] = -1
        self.start_order[i] = -1
        self.backlog[i] = 0.0
        self.queue_delay[i] = 0.0
        self.degraded_to[i] = np.nan
        self.decode_tokens[i] = 0
        self.tokens_emitted[i] = 0
        self.decode_done[i] = np.nan
        self.payload_bits[i] = np.nan

    # -- view materialization ------------------------------------------
    def materialize(self, i: int):
        """The classic ``FleetRecord`` dataclass view of row ``i``."""
        from repro.serving.engine.events import StageTimeline
        from repro.serving.engine.metrics import FleetRecord
        tl_row = self.tl[i]
        timeline = None if np.isnan(tl_row[TL_ADMIT]) \
            else StageTimeline(*(float(x) for x in tl_row))
        degraded = self.degraded_to[i]
        decode_done = self.decode_done[i]
        code = int(self.drop_code[i])
        return FleetRecord(
            index=i, request=self.requests[i],
            deployment=self.deployments[i] if self.full else None,
            timeline=timeline,
            server=int(self.server[i]),
            start_order=int(self.start_order[i]),
            backlog_at_admission=float(self.backlog[i]),
            queue_delay=float(self.queue_delay[i]),
            degraded_to=None if np.isnan(degraded) else float(degraded),
            rejected=bool(self.rejected[i]),
            drop_reason=CODE_REASONS.get(code),
            attempts=int(self.attempts[i]),
            faults=int(self.faults[i]),
            parked=int(self.parked[i]),
            decode_tokens=int(self.decode_tokens[i]),
            tokens_emitted=int(self.tokens_emitted[i]),
            decode_done=None if np.isnan(decode_done)
            else float(decode_done))


class LazyRecords:
    """Sequence facade over a ``RecordStore``: ``metrics.records[i]``
    materializes (and memoizes) dataclass views on demand, so touching a
    handful of records out of 10⁶ costs a handful of allocations."""

    __slots__ = ("_store", "_cache")

    def __init__(self, store: RecordStore):
        self._store = store
        self._cache = np.full(store.n, None, dtype=object)

    def __len__(self) -> int:
        return self._store.n

    def _one(self, i: int):
        if i < 0:
            i += self._store.n
        if not 0 <= i < self._store.n:
            raise IndexError(i)
        rec = self._cache[i]
        if rec is None:
            rec = self._store.materialize(i)
            self._cache[i] = rec
        return rec

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._one(k) for k in range(*i.indices(self._store.n))]
        return self._one(int(i))

    def __iter__(self):
        return (self._one(i) for i in range(self._store.n))
