"""Fleet-level serving metrics.

The engine accounts one record per request (admitted, rejected, or
dead-lettered); ``FleetMetrics`` owns the records plus the engine's
queue-depth samples, per-server busy totals, dead-letter queue and event
journal, and aggregates the numbers a serving system is judged by:
p50/p99 end-to-end latency, deadline-miss rate, server utilization,
time-weighted queue depth, payload on the radio link — and, under fault
injection, goodput, retry rate, and per-reason drop counts. Terminal
accounting is an invariant, not a hope: ``assert_terminal()`` checks
every request either completed or carries a structured drop reason.

Since the columnar rework (DESIGN.md §12) the engine keeps per-request
facts in a ``RecordStore`` (engine/records.py) and hands it to
``FleetMetrics`` as ``store``; every aggregate then reduces whole
columns. ``records`` stays a sequence of ``FleetRecord`` dataclass
views, materialized lazily. When ``store`` is None (hand-built metrics,
and the reference path the equivalence tests in
tests/test_fleet_scale.py compare against) each aggregate falls back to
the historical per-record loop — both paths produce bit-identical
numbers.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.engine.events import StageTimeline
from repro.serving.engine.records import (CODE_REASONS, TL_DEVICE,
                                          TL_FINISH, TL_SHIP, TL_START,
                                          TL_TRANSFER)
from repro.serving.engine.retry import DeadLetter
from repro.serving.simulator import InferenceRequest


@dataclasses.dataclass
class FleetRecord:
    """Everything the engine decided and observed for one request."""
    index: int                          # arrival-order position in the trace
    request: InferenceRequest
    deployment: object = None           # serving.Deployment; None = dropped
    timeline: Optional[StageTimeline] = None
    server: int = -1                    # fleet index of the serving server
    start_order: int = -1               # global admission rank
    # pricing-side queue view (what entered the objective; the paper's
    # Eq. 17 queue term = reference-server work backlog at admission)
    backlog_at_admission: float = 0.0
    queue_delay: float = 0.0            # backlog, zeroed when p = L (no
    # server segment) — mirrors result.extra["queue_delay"]
    degraded_to: Optional[float] = None  # accuracy level after SLO degrade
    # or retry-with-degraded-budget (engine/retry.py)
    rejected: bool = False              # True for EVERY non-completed
    # terminal state; drop_reason says WHY (retry.DROP_REASONS)
    drop_reason: Optional[str] = None
    attempts: int = 0                   # admission attempts consumed
    # (0 = never admitted; > 1 = fault-driven re-admissions)
    faults: int = 0                     # in-flight cancellations suffered
    parked: int = 0                     # times held for a down device
    # -- decode streams (DESIGN.md §11) --------------------------------
    decode_tokens: int = 0              # tokens the request streams
    # (0 = one-shot request; == request.max_new_tokens when admitted)
    tokens_emitted: int = 0             # tokens the decode lane delivered
    decode_done: Optional[float] = None  # last-token time (streams only)

    @property
    def arrival(self) -> float:
        return self.request.arrival_time

    @property
    def completed(self) -> bool:
        return not self.rejected

    @property
    def dead_lettered(self) -> bool:
        """Terminally failed under fault recovery (as opposed to an SLO
        admission reject)."""
        from repro.serving.engine.retry import REASON_SLO
        return self.rejected and self.drop_reason is not None \
            and self.drop_reason != REASON_SLO

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (arrival → prefill finish). For one-shot
        requests this IS the end-to-end latency."""
        if self.timeline is None:
            return None
        return self.timeline.latency_from(self.arrival)

    @property
    def latency(self) -> Optional[float]:
        """End-to-end: last decode token for streams (``decode_done``),
        the prefill/one-shot finish otherwise."""
        if self.decode_tokens > 1 and self.decode_done is not None:
            return self.decode_done - self.arrival
        if self.timeline is None:
            return None
        return self.timeline.latency_from(self.arrival)

    @property
    def deadline_missed(self) -> Optional[bool]:
        """None when the request has no deadline; a dropped request with
        a deadline counts as missed. For decode streams the SLO is on
        TTFT (the interactive metric) — the stream's tail pace is priced,
        not promised."""
        if self.request.deadline is None:
            return None
        if self.rejected:
            return True
        lat = self.ttft if self.decode_tokens > 1 else self.latency
        return lat > self.request.deadline + 1e-12


@dataclasses.dataclass
class FleetMetrics:
    records: Sequence[FleetRecord]
    server_busy: List[float]            # per-server reserved work seconds
    queue_samples: Sequence            # (time, total in-flight) pairs —
    # an (M, 2) float column block from the engine, a list of tuples
    # when hand-built
    horizon: float                      # last completion time
    dead_letters: List[DeadLetter] = dataclasses.field(default_factory=list)
    journal: object = None              # EventJournal | LightJournal | None
    store: object = None                # engine RecordStore (columnar path)

    # -- columnar helpers ----------------------------------------------
    def _lat_cols(self):
        """(latency, ttft) columns over ALL rows — NaN where the row has
        no committed timeline (exactly the rows whose dataclass view has
        ``latency``/``ttft`` None)."""
        st = self.store
        ttft = st.tl[:, TL_FINISH] - st.arrival
        lat = np.where((st.decode_tokens > 1) & ~np.isnan(st.decode_done),
                       st.decode_done - st.arrival, ttft)
        return lat, ttft

    def _miss_cols(self):
        """(has-deadline mask, missed flags over ALL rows): rejected
        rows count as missed; streams are judged on TTFT."""
        st = self.store
        lat, ttft = self._lat_cols()
        eff = np.where(st.decode_tokens > 1, ttft, lat)
        miss = np.where(st.rejected, True, eff > st.deadline + 1e-12)
        return ~np.isnan(st.deadline), miss

    # ------------------------------------------------------------------
    def completed(self) -> List[FleetRecord]:
        if self.store is not None:
            recs = self.records
            return [recs[int(i)]
                    for i in np.flatnonzero(~self.store.rejected)]
        return [r for r in self.records if not r.rejected]

    def latencies(self) -> np.ndarray:
        if self.store is not None:
            lat, _ = self._lat_cols()
            return lat[~self.store.rejected]
        return np.array([r.latency for r in self.completed()], np.float64)

    def deadline_miss_rate(self) -> Optional[float]:
        """Missed / carrying-a-deadline (drops count as misses); None
        when the trace has no deadlines at all."""
        if self.store is not None:
            has, miss = self._miss_cols()
            if not has.any():
                return None
            return float(np.mean(miss[has]))
        flags = [r.deadline_missed for r in self.records
                 if r.deadline_missed is not None]
        if not flags:
            return None
        return float(np.mean(flags))

    def utilization(self) -> List[float]:
        if self.horizon <= 0:
            return [0.0] * len(self.server_busy)
        return [min(b / self.horizon, 1.0) for b in self.server_busy]

    def mean_queue_depth(self) -> float:
        """Time-weighted mean of in-flight requests over the horizon."""
        if len(self.queue_samples) < 2:
            return 0.0
        if isinstance(self.queue_samples, np.ndarray):
            t = self.queue_samples[:, 0]
            d = self.queue_samples[:, 1]
        else:
            t = np.array([s[0] for s in self.queue_samples])
            d = np.array([s[1] for s in self.queue_samples], np.float64)
        dt = np.diff(t)
        span = t[-1] - t[0]
        if span <= 0:
            return float(d.mean())
        return float(np.sum(d[:-1] * dt) / span)

    def _stage_cols(self, done_mask=None):
        """Per-stage duration columns over completed rows, in trace
        order — same key order and the same subtractions as
        ``StageTimeline.stage_seconds``."""
        tl = self.store.tl[~self.store.rejected if done_mask is None
                           else done_mask]
        return {"ship": tl[:, TL_SHIP] - tl[:, 0],
                "device": tl[:, TL_DEVICE] - tl[:, TL_SHIP],
                "transfer": tl[:, TL_TRANSFER] - tl[:, TL_DEVICE],
                "server_wait": tl[:, TL_START] - tl[:, TL_TRANSFER],
                "server": tl[:, TL_FINISH] - tl[:, TL_START]}

    def mean_stage_seconds(self) -> dict:
        """Mean per-stage seconds over completed requests (the priced
        ``StageTimeline`` view) — where fleet time actually goes."""
        if self.store is not None:
            cols = self._stage_cols()
            n = cols["ship"].shape[0]
            if not n:
                return {}
            # sequential Python sum, exactly the historical per-record
            # accumulation order (np.sum's pairwise reduction would
            # drift in the last ulps)
            return {k: sum(col.tolist(), 0.0) / n for k, col in cols.items()}
        done = self.completed()
        if not done:
            return {}
        acc: dict = {}
        for r in done:
            for k, v in r.timeline.stage_seconds.items():
                acc[k] = acc.get(k, 0.0) + v
        return {k: v / len(done) for k, v in acc.items()}

    # -- resilience aggregates (DESIGN.md §10) -------------------------
    def drop_reasons(self) -> dict:
        """Structured drop-reason counts — SLO rejects, retry
        exhaustion and disconnect abandonment are distinguishable."""
        counts: dict = {}
        if self.store is not None:
            codes = self.store.drop_code[self.store.rejected]
            for code in codes.tolist():     # record order -> key order
                key = CODE_REASONS.get(code, "unknown")
                counts[key] = counts.get(key, 0) + 1
            return counts
        for r in self.records:
            if r.rejected:
                key = r.drop_reason or "unknown"
                counts[key] = counts.get(key, 0) + 1
        return counts

    def retried(self) -> int:
        """Requests that needed more than one admission attempt."""
        if self.store is not None:
            return int((self.store.attempts > 1).sum())
        return sum(1 for r in self.records if r.attempts > 1)

    def disrupted(self) -> int:
        """Requests a fault touched at all: cancelled in flight or
        parked behind a disconnected device."""
        if self.store is not None:
            return int(((self.store.faults > 0)
                        | (self.store.parked > 0)).sum())
        return sum(1 for r in self.records if r.faults or r.parked)

    def retry_rate(self) -> float:
        if not len(self.records):
            return 0.0
        return self.retried() / len(self.records)

    # -- decode aggregates (DESIGN.md §11) -----------------------------
    def ttfts(self) -> np.ndarray:
        if self.store is not None:
            _, ttft = self._lat_cols()
            return ttft[~self.store.rejected]
        return np.array([r.ttft for r in self.completed()
                         if r.ttft is not None], np.float64)

    def tokens_per_s(self) -> float:
        """Decode-lane throughput: tokens delivered per second of
        horizon (0.0 for one-shot-only traces)."""
        if self.horizon <= 0:
            return 0.0
        if self.store is not None:
            return int(self.store.tokens_emitted.sum()) / self.horizon
        return sum(r.tokens_emitted for r in self.records) / self.horizon

    def goodput_rps(self) -> float:
        """USEFUL completions per second of horizon: completed AND (when
        a deadline was attached) inside it — the number fault tolerance
        is supposed to protect."""
        if self.horizon <= 0:
            return 0.0
        if self.store is not None:
            has, miss = self._miss_cols()
            good = ~self.store.rejected & (~has | ~miss)
            return int(good.sum()) / self.horizon
        good = sum(1 for r in self.completed()
                   if r.deadline_missed is not True)
        return good / self.horizon

    def assert_terminal(self) -> None:
        """Every request is terminally accounted for: completed with a
        timeline, or dropped with a structured reason (no lost
        requests). The chaos acceptance invariant."""
        if self.store is not None:
            st = self.store
            rej = st.rejected
            bad = np.flatnonzero(rej & (st.drop_code == 0))
            assert not bad.size, \
                f"request {bad[0]} dropped without a reason"
            done = ~rej
            bad = np.flatnonzero(done & np.isnan(st.tl[:, 0]))
            assert not bad.size, \
                f"request {bad[0]} neither completed nor dropped"
            if st.full:
                dep_ok = np.fromiter((d is not None for d in st.deployments),
                                     bool, count=st.n)
                bad = np.flatnonzero(done & ~dep_ok)
                assert not bad.size, \
                    f"request {bad[0]} neither completed nor dropped"
                bad = np.flatnonzero(rej & dep_ok)
                assert not bad.size, \
                    f"request {bad[0]} dropped but kept a deployment"
            streams = done & (st.decode_tokens > 0)
            bad = np.flatnonzero(
                streams & (st.tokens_emitted != st.decode_tokens))
            assert not bad.size, \
                (f"request {bad[0] if bad.size else -1} completed with "
                 f"missing decode tokens")
            bad = np.flatnonzero(done & (st.decode_tokens > 1)
                                 & np.isnan(st.decode_done))
            assert not bad.size, \
                f"request {bad[0] if bad.size else -1} stream never finished"
            n_dead = int((rej & (st.drop_code > 1)).sum())
        else:
            for r in self.records:
                if r.rejected:
                    assert r.deployment is None and r.drop_reason, \
                        f"request {r.index} dropped without a reason"
                else:
                    assert r.deployment is not None \
                        and r.timeline is not None, \
                        f"request {r.index} neither completed nor dropped"
                    if r.decode_tokens:
                        # a completed stream delivered EVERY token: no
                        # request may finish with its stream dangling
                        assert r.tokens_emitted == r.decode_tokens, \
                            (f"request {r.index} completed with "
                             f"{r.tokens_emitted}/{r.decode_tokens} tokens")
                        assert r.decode_tokens == 1 \
                            or r.decode_done is not None, \
                            f"request {r.index} stream never finished"
            n_dead = sum(1 for r in self.records if r.dead_lettered)
        assert n_dead == len(self.dead_letters), \
            f"{n_dead} dead-lettered records vs {len(self.dead_letters)} DLQ"

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        lat = self.latencies()
        tt = self.ttfts()
        st = self.store
        if st is not None:
            done_mask = ~st.rejected
            n_done = int(done_mask.sum())
            n = st.n
            n_rejected = int(st.rejected.sum())
            n_degraded = int((~np.isnan(st.degraded_to)).sum())
            queue_delays = self._stage_cols(done_mask)["server_wait"]
            total_payload = float(sum(
                st.payload_bits[done_mask].tolist()))
            max_depth = int(self.queue_samples[:, 1].max()) \
                if len(self.queue_samples) else 0
        else:
            done = self.completed()
            n_done = len(done)
            n = len(self.records)
            n_rejected = sum(r.rejected for r in self.records)
            n_degraded = sum(r.degraded_to is not None
                             for r in self.records)
            queue_delays = [r.timeline.server_wait for r in done]
            total_payload = float(sum(
                r.deployment.payload_bits for r in done))
            max_depth = max((s[1] for s in self.queue_samples), default=0)
        out = {
            "requests": n,
            "completed": n_done,
            "rejected": n_rejected,
            "degraded": n_degraded,
            "dead_lettered": len(self.dead_letters),
            "retried": self.retried(),
            "disrupted": self.disrupted(),
            "drop_reasons": self.drop_reasons(),
            "horizon_s": round(self.horizon, 6),
            "throughput_rps": round(n_done / self.horizon, 3)
            if self.horizon > 0 else 0.0,
            "goodput_rps": round(self.goodput_rps(), 3),
            "p50_latency_s": round(float(np.percentile(lat, 50)), 6)
            if len(lat) else None,
            "p99_latency_s": round(float(np.percentile(lat, 99)), 6)
            if len(lat) else None,
            "mean_latency_s": round(float(lat.mean()), 6)
            if len(lat) else None,
            "deadline_miss_rate": self.deadline_miss_rate(),
            "mean_queue_delay_s": round(float(np.mean(queue_delays)), 6)
            if len(queue_delays) else None,
            "tokens_per_s": round(self.tokens_per_s(), 3),
            "ttft_p50": round(float(np.percentile(tt, 50)), 6)
            if len(tt) else None,
            "ttft_p99": round(float(np.percentile(tt, 99)), 6)
            if len(tt) else None,
            "mean_queue_depth": round(self.mean_queue_depth(), 3),
            "max_queue_depth": max_depth,
            "server_utilization": [round(u, 4) for u in self.utilization()],
            "total_payload_bits": total_payload,
            "mean_stage_s": {k: round(v, 6)
                             for k, v in self.mean_stage_seconds().items()},
        }
        miss = out["deadline_miss_rate"]
        if miss is not None:
            out["deadline_miss_rate"] = round(miss, 4)
        return out
