"""Fleet-level serving metrics.

The engine emits one ``FleetRecord`` per request (admitted, rejected, or
dead-lettered); ``FleetMetrics`` owns the records plus the engine's
queue-depth samples, per-server busy totals, dead-letter queue and event
journal, and aggregates the numbers a serving system is judged by:
p50/p99 end-to-end latency, deadline-miss rate, server utilization,
time-weighted queue depth, payload on the radio link — and, under fault
injection, goodput, retry rate, and per-reason drop counts. Terminal
accounting is an invariant, not a hope: ``assert_terminal()`` checks
every request either completed or carries a structured drop reason.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serving.engine.events import StageTimeline
from repro.serving.engine.retry import DeadLetter
from repro.serving.simulator import InferenceRequest


@dataclasses.dataclass
class FleetRecord:
    """Everything the engine decided and observed for one request."""
    index: int                          # arrival-order position in the trace
    request: InferenceRequest
    deployment: object = None           # serving.Deployment; None = dropped
    timeline: Optional[StageTimeline] = None
    server: int = -1                    # fleet index of the serving server
    start_order: int = -1               # global admission rank
    # pricing-side queue view (what entered the objective; the paper's
    # Eq. 17 queue term = reference-server work backlog at admission)
    backlog_at_admission: float = 0.0
    queue_delay: float = 0.0            # backlog, zeroed when p = L (no
    # server segment) — mirrors result.extra["queue_delay"]
    degraded_to: Optional[float] = None  # accuracy level after SLO degrade
    # or retry-with-degraded-budget (engine/retry.py)
    rejected: bool = False              # True for EVERY non-completed
    # terminal state; drop_reason says WHY (retry.DROP_REASONS)
    drop_reason: Optional[str] = None
    attempts: int = 0                   # admission attempts consumed
    # (0 = never admitted; > 1 = fault-driven re-admissions)
    faults: int = 0                     # in-flight cancellations suffered
    parked: int = 0                     # times held for a down device
    # -- decode streams (DESIGN.md §11) --------------------------------
    decode_tokens: int = 0              # tokens the request streams
    # (0 = one-shot request; == request.max_new_tokens when admitted)
    tokens_emitted: int = 0             # tokens the decode lane delivered
    decode_done: Optional[float] = None  # last-token time (streams only)

    @property
    def arrival(self) -> float:
        return self.request.arrival_time

    @property
    def completed(self) -> bool:
        return not self.rejected

    @property
    def dead_lettered(self) -> bool:
        """Terminally failed under fault recovery (as opposed to an SLO
        admission reject)."""
        from repro.serving.engine.retry import REASON_SLO
        return self.rejected and self.drop_reason is not None \
            and self.drop_reason != REASON_SLO

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (arrival → prefill finish). For one-shot
        requests this IS the end-to-end latency."""
        if self.timeline is None:
            return None
        return self.timeline.latency_from(self.arrival)

    @property
    def latency(self) -> Optional[float]:
        """End-to-end: last decode token for streams (``decode_done``),
        the prefill/one-shot finish otherwise."""
        if self.decode_tokens > 1 and self.decode_done is not None:
            return self.decode_done - self.arrival
        if self.timeline is None:
            return None
        return self.timeline.latency_from(self.arrival)

    @property
    def deadline_missed(self) -> Optional[bool]:
        """None when the request has no deadline; a dropped request with
        a deadline counts as missed. For decode streams the SLO is on
        TTFT (the interactive metric) — the stream's tail pace is priced,
        not promised."""
        if self.request.deadline is None:
            return None
        if self.rejected:
            return True
        lat = self.ttft if self.decode_tokens > 1 else self.latency
        return lat > self.request.deadline + 1e-12


@dataclasses.dataclass
class FleetMetrics:
    records: List[FleetRecord]
    server_busy: List[float]            # per-server reserved work seconds
    queue_samples: List[tuple]          # (time, total in-flight requests)
    horizon: float                      # last completion time
    dead_letters: List[DeadLetter] = dataclasses.field(default_factory=list)
    journal: object = None              # engine.EventJournal of the run

    # ------------------------------------------------------------------
    def completed(self) -> List[FleetRecord]:
        return [r for r in self.records if not r.rejected]

    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.completed()], np.float64)

    def deadline_miss_rate(self) -> Optional[float]:
        """Missed / carrying-a-deadline (drops count as misses); None
        when the trace has no deadlines at all."""
        flags = [r.deadline_missed for r in self.records
                 if r.deadline_missed is not None]
        if not flags:
            return None
        return float(np.mean(flags))

    def utilization(self) -> List[float]:
        if self.horizon <= 0:
            return [0.0] * len(self.server_busy)
        return [min(b / self.horizon, 1.0) for b in self.server_busy]

    def mean_queue_depth(self) -> float:
        """Time-weighted mean of in-flight requests over the horizon."""
        if len(self.queue_samples) < 2:
            return 0.0
        t = np.array([s[0] for s in self.queue_samples])
        d = np.array([s[1] for s in self.queue_samples], np.float64)
        dt = np.diff(t)
        span = t[-1] - t[0]
        if span <= 0:
            return float(d.mean())
        return float(np.sum(d[:-1] * dt) / span)

    def mean_stage_seconds(self) -> dict:
        """Mean per-stage seconds over completed requests (the priced
        ``StageTimeline`` view) — where fleet time actually goes."""
        done = self.completed()
        if not done:
            return {}
        acc: dict = {}
        for r in done:
            for k, v in r.timeline.stage_seconds.items():
                acc[k] = acc.get(k, 0.0) + v
        return {k: v / len(done) for k, v in acc.items()}

    # -- resilience aggregates (DESIGN.md §10) -------------------------
    def drop_reasons(self) -> dict:
        """Structured drop-reason counts — SLO rejects, retry
        exhaustion and disconnect abandonment are distinguishable."""
        counts: dict = {}
        for r in self.records:
            if r.rejected:
                key = r.drop_reason or "unknown"
                counts[key] = counts.get(key, 0) + 1
        return counts

    def retried(self) -> int:
        """Requests that needed more than one admission attempt."""
        return sum(1 for r in self.records if r.attempts > 1)

    def disrupted(self) -> int:
        """Requests a fault touched at all: cancelled in flight or
        parked behind a disconnected device."""
        return sum(1 for r in self.records if r.faults or r.parked)

    def retry_rate(self) -> float:
        if not self.records:
            return 0.0
        return self.retried() / len(self.records)

    # -- decode aggregates (DESIGN.md §11) -----------------------------
    def ttfts(self) -> np.ndarray:
        return np.array([r.ttft for r in self.completed()
                         if r.ttft is not None], np.float64)

    def tokens_per_s(self) -> float:
        """Decode-lane throughput: tokens delivered per second of
        horizon (0.0 for one-shot-only traces)."""
        if self.horizon <= 0:
            return 0.0
        return sum(r.tokens_emitted for r in self.records) / self.horizon

    def goodput_rps(self) -> float:
        """USEFUL completions per second of horizon: completed AND (when
        a deadline was attached) inside it — the number fault tolerance
        is supposed to protect."""
        if self.horizon <= 0:
            return 0.0
        good = sum(1 for r in self.completed()
                   if r.deadline_missed is not True)
        return good / self.horizon

    def assert_terminal(self) -> None:
        """Every request is terminally accounted for: completed with a
        timeline, or dropped with a structured reason (no lost
        requests). The chaos acceptance invariant."""
        for r in self.records:
            if r.rejected:
                assert r.deployment is None and r.drop_reason, \
                    f"request {r.index} dropped without a reason"
            else:
                assert r.deployment is not None and r.timeline is not None, \
                    f"request {r.index} neither completed nor dropped"
                if r.decode_tokens:
                    # a completed stream delivered EVERY token: no
                    # request may finish with its decode stream dangling
                    assert r.tokens_emitted == r.decode_tokens, \
                        (f"request {r.index} completed with "
                         f"{r.tokens_emitted}/{r.decode_tokens} tokens")
                    assert r.decode_tokens == 1 \
                        or r.decode_done is not None, \
                        f"request {r.index} stream never finished"
        n_dead = sum(1 for r in self.records if r.dead_lettered)
        assert n_dead == len(self.dead_letters), \
            f"{n_dead} dead-lettered records vs {len(self.dead_letters)} DLQ"

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        lat = self.latencies()
        tt = self.ttfts()
        done = self.completed()
        n = len(self.records)
        queue_delays = [r.timeline.server_wait for r in done]
        out = {
            "requests": n,
            "completed": len(done),
            "rejected": sum(r.rejected for r in self.records),
            "degraded": sum(r.degraded_to is not None for r in self.records),
            "dead_lettered": len(self.dead_letters),
            "retried": self.retried(),
            "disrupted": self.disrupted(),
            "drop_reasons": self.drop_reasons(),
            "horizon_s": round(self.horizon, 6),
            "throughput_rps": round(len(done) / self.horizon, 3)
            if self.horizon > 0 else 0.0,
            "goodput_rps": round(self.goodput_rps(), 3),
            "p50_latency_s": round(float(np.percentile(lat, 50)), 6)
            if len(lat) else None,
            "p99_latency_s": round(float(np.percentile(lat, 99)), 6)
            if len(lat) else None,
            "mean_latency_s": round(float(lat.mean()), 6)
            if len(lat) else None,
            "deadline_miss_rate": self.deadline_miss_rate(),
            "mean_queue_delay_s": round(float(np.mean(queue_delays)), 6)
            if queue_delays else None,
            "tokens_per_s": round(self.tokens_per_s(), 3),
            "ttft_p50": round(float(np.percentile(tt, 50)), 6)
            if len(tt) else None,
            "ttft_p99": round(float(np.percentile(tt, 99)), 6)
            if len(tt) else None,
            "mean_queue_depth": round(self.mean_queue_depth(), 3),
            "max_queue_depth": max((s[1] for s in self.queue_samples),
                                   default=0),
            "server_utilization": [round(u, 4) for u in self.utilization()],
            "total_payload_bits": float(sum(
                r.deployment.payload_bits for r in done)),
            "mean_stage_s": {k: round(v, 6)
                             for k, v in self.mean_stage_seconds().items()},
        }
        miss = out["deadline_miss_rate"]
        if miss is not None:
            out["deadline_miss_rate"] = round(miss, 4)
        return out
