"""Fault injection for the fleet engine (DESIGN.md §10).

A ``FaultInjector`` carries a seeded, time-sorted schedule of
``FaultEvent``s that the engine merges into its event queue at ``run()``
— faults are ordinary DES events (kind FAULT, first at equal times), so
a faulted run is exactly as deterministic and replayable as a sunny-day
one. Three fault kinds:

  DISCONNECT — the device drops off the radio. Every in-flight attempt
               of that device still in its ship/device/transfer stage is
               CANCELLED: the server reservation is released, a pending
               CACHE_INSTALL is invalidated, and the request goes to the
               engine's ``RetryPolicy``. Attempts already past
               ``transfer_done`` (cut activation reached the server)
               complete normally. New arrivals from a disconnected
               device are PARKED (no attempt burned) until reconnect.
  RECONNECT  — the device is back; parked requests rejoin the pending
               set at the next decision epoch.
  DEGRADE    — the device's effective channel capacity is multiplied by
               ``factor`` (< 1 degrades, 1.0 restores) for every LATER
               admission. In-flight timelines are reservations and never
               re-priced mid-stage — the drift shows up at the next
               (re-)admission, which is also where replanning would see
               it.

Trace generators (``churn_trace``, ``degrade_trace``) build seeded
renewal-process schedules over a device pool; both compose by
concatenation (``FaultInjector(a.events + b.events)`` or ``a + b``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.serving.errors import FaultConfigError

DISCONNECT = "disconnect"
RECONNECT = "reconnect"
DEGRADE = "degrade"
FAULT_KINDS = (DISCONNECT, RECONNECT, DEGRADE)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` applied to ``device_id`` at
    ``time``. ``factor`` is the channel-capacity multiplier (DEGRADE
    only; 1.0 restores the nominal channel)."""
    time: float
    kind: str                      # disconnect | reconnect | degrade
    device_id: str
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise FaultConfigError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")
        if not self.time >= 0:
            raise FaultConfigError(
                f"fault time must be >= 0, got {self.time}")
        if self.kind == DEGRADE and not self.factor > 0:
            raise FaultConfigError(
                f"degrade factor must be > 0, got {self.factor}")

    def to_dict(self) -> dict:
        return {"time": self.time, "kind": self.kind,
                "device": self.device_id, "factor": self.factor}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(float(d["time"]), d["kind"], d["device"],
                   float(d.get("factor", 1.0)))


class FaultInjector:
    """A time-sorted fault schedule the engine drains each ``run()``.
    Stateless between runs (the engine owns all fault *state*); two
    injectors compose with ``+``."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.time, e.kind, e.device_id))

    def __len__(self) -> int:
        return len(self.events)

    def __add__(self, other: "FaultInjector") -> "FaultInjector":
        return FaultInjector(self.events + other.events)


def churn_trace(device_ids: Sequence[str], horizon: float,
                mean_uptime: float, mean_downtime: float,
                seed: int = 0,
                first_down: Optional[float] = None) -> FaultInjector:
    """Seeded device churn: each device alternates up/down with
    exponential dwell times (a renewal process — disconnects and
    reconnects always pair up, and a final disconnect without a
    reconnect inside ``horizon`` models a device that never comes
    back)."""
    if mean_uptime <= 0 or mean_downtime <= 0:
        raise FaultConfigError("churn dwell times must be > 0")
    rng = np.random.default_rng(seed)
    events = []
    for dev in device_ids:
        t = float(rng.exponential(mean_uptime)) if first_down is None \
            else first_down
        while t < horizon:
            events.append(FaultEvent(t, DISCONNECT, dev))
            t += float(rng.exponential(mean_downtime))
            if t >= horizon:
                break               # never reconnects inside the horizon
            events.append(FaultEvent(t, RECONNECT, dev))
            t += float(rng.exponential(mean_uptime))
    return FaultInjector(events)


def degrade_trace(device_ids: Sequence[str], horizon: float,
                  mean_interval: float, mean_duration: float,
                  factor_range=(0.1, 0.5), seed: int = 0) -> FaultInjector:
    """Seeded channel-quality drift: per device, capacity-degradation
    episodes (capacity × U[factor_range]) arrive as a Poisson process
    and restore (factor 1.0) after an exponential duration."""
    if mean_interval <= 0 or mean_duration <= 0:
        raise FaultConfigError("degrade interval/duration must be > 0")
    lo, hi = factor_range
    if not (0 < lo <= hi):
        raise FaultConfigError(f"bad factor_range {factor_range}")
    rng = np.random.default_rng(seed)
    events = []
    for dev in device_ids:
        t = float(rng.exponential(mean_interval))
        while t < horizon:
            events.append(FaultEvent(t, DEGRADE, dev,
                                     float(rng.uniform(lo, hi))))
            t += float(rng.exponential(mean_duration))
            if t >= horizon:
                break
            events.append(FaultEvent(t, DEGRADE, dev, 1.0))
            t += float(rng.exponential(mean_interval))
    return FaultInjector(events)
