"""Event-driven fleet serving engine (DESIGN.md §8/§10): continuous-time
arrivals, multi-server queues, device segment-cache state, pluggable
admission policies, fleet metrics — plus the operational-resilience
layer: fault injection (device churn, channel degradation), retry with
dead-letter queue, replayable event journal, MMPP/diurnal traces — and
the scale core (DESIGN.md §12): bulk-loaded arrivals, columnar records,
vectorized admission and selectable journaling modes."""
from repro.serving.engine.events import (DECODE_STEP, ArrivalStream,  # noqa: F401
                                         Event, EventQueue, StageTimeline)
from repro.serving.engine.faults import (DEGRADE,  # noqa: F401
                                         DISCONNECT, RECONNECT, FaultEvent,
                                         FaultInjector, churn_trace,
                                         degrade_trace)
from repro.serving.engine.fleet import (FleetEngine,  # noqa: F401
                                        ServerState)
from repro.serving.engine.journal import (JOURNAL_MODES,  # noqa: F401
                                          EventJournal, JournalEntry,
                                          LightJournal)
from repro.serving.engine.metrics import (FleetMetrics,  # noqa: F401
                                          FleetRecord)
from repro.serving.engine.records import (LazyRecords,  # noqa: F401
                                          RecordStore)
from repro.serving.engine.policies import (POLICIES,  # noqa: F401
                                           AdmissionPolicy, BalancedPolicy,
                                           EDFPolicy, FCFSPolicy,
                                           LeastLoadedPolicy, get_policy)
from repro.serving.engine.retry import (DROP_REASONS,  # noqa: F401
                                        REASON_ABANDONED, REASON_EXHAUSTED,
                                        REASON_SLO, DeadLetter, RetryPolicy)
from repro.serving.engine.traces import (diurnal_arrivals,  # noqa: F401
                                         materialize, mmpp_arrivals)
