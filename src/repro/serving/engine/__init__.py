"""Event-driven fleet serving engine (DESIGN.md §8): continuous-time
arrivals, multi-server queues, device segment-cache state, pluggable
admission policies, fleet metrics."""
from repro.serving.engine.events import (Event, EventQueue,  # noqa: F401
                                         StageTimeline)
from repro.serving.engine.fleet import (FleetEngine,  # noqa: F401
                                        ServerState)
from repro.serving.engine.metrics import (FleetMetrics,  # noqa: F401
                                          FleetRecord)
from repro.serving.engine.policies import (POLICIES,  # noqa: F401
                                           AdmissionPolicy, BalancedPolicy,
                                           EDFPolicy, FCFSPolicy,
                                           LeastLoadedPolicy, get_policy)
