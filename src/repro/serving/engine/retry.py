"""Retry policy + dead-letter queue for fault-cancelled requests
(DESIGN.md §10).

When a fault cancels an in-flight attempt (engine/faults.py), the
engine hands the request to its ``RetryPolicy``: re-admission after a
capped exponential backoff, at most ``max_attempts`` total admissions
per request (``InferenceRequest.attempt_budget`` overrides per
request), optionally coarsening the accuracy budget one store level per
retry (``degrade_on_retry`` — the same degrade ladder SLO admission
walks). A request that exhausts its attempts — or is still parked on a
disconnected device when the trace drains — lands in the dead-letter
queue with a structured reason, so every request is terminally
accounted for: completed, rejected, or dead-lettered. Backoffs are
deterministic (no jitter): a faulted run replays bit-for-bit from its
journal.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.errors import FaultConfigError

# structured terminal drop reasons (FleetRecord.drop_reason)
REASON_SLO = "slo_reject"                    # SLO admission rejected
REASON_EXHAUSTED = "retries_exhausted"       # fault-cancelled, budget spent
REASON_ABANDONED = "disconnect_abandoned"    # device never reconnected
DROP_REASONS = (REASON_SLO, REASON_EXHAUSTED, REASON_ABANDONED)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with a per-request attempt budget.

    ``max_attempts`` counts ADMISSIONS (first try included): 3 means
    one admission plus up to two retries. ``degrade_on_retry`` coarsens
    the accuracy budget one offline-store level per retry — the
    retry-with-degraded-budget ladder: a flaky device trades accuracy
    for a cheaper (smaller-payload, faster) plan instead of burning its
    remaining attempts on the same doomed shipment."""
    max_attempts: int = 3
    base_backoff_s: float = 0.05
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0
    degrade_on_retry: bool = False

    def __post_init__(self):
        if self.max_attempts < 1:
            raise FaultConfigError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise FaultConfigError("backoffs must be >= 0")
        if self.backoff_factor < 1.0:
            raise FaultConfigError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}")

    def backoff(self, attempt: int) -> float:
        """Seconds to wait before admission attempt ``attempt`` (>= 2):
        base · factor^(attempt − 2), capped."""
        return min(self.base_backoff_s
                   * self.backoff_factor ** max(attempt - 2, 0),
                   self.max_backoff_s)

    def budget_for(self, request) -> int:
        """The request's attempt budget (its own override, else the
        policy default)."""
        budget = getattr(request, "attempt_budget", None)
        return self.max_attempts if budget is None else int(budget)


@dataclasses.dataclass(frozen=True)
class DeadLetter:
    """One terminally failed request: why, when, and after how many
    admission attempts (`reason` is a ``DROP_REASONS`` constant)."""
    index: int                     # trace position of the request
    reason: str
    time: float                    # when the request became terminal
    attempts: int                  # admissions consumed (0 = never admitted)
    device_id: Optional[str] = None

    def to_dict(self) -> dict:
        return {"index": self.index, "reason": self.reason,
                "time": self.time, "attempts": self.attempts,
                "device": self.device_id}
