"""Event-driven fleet serving engine (DESIGN.md §8).

Runs a discrete-event loop over timestamped ``InferenceRequest`` arrivals
against a MULTI-SERVER fleet: plan → uplink (model shipment) → device
segment → cut-activation transfer → server segment → complete. The
engine generalizes the one-shot ``WorkloadBalancer.schedule`` window
along three axes while keeping its vectorized hot path (every decision
epoch prices all pending requests as ONE ``price_window`` matrix):

  * time      — arrivals carry ``arrival_time``; requests admitted at a
                later epoch see whatever backlog earlier admissions left.
  * fleet     — N servers, each with its own ``ServerProfile``, work
                backlog and wall-clock reservation horizon. The pricing
                row of server s is the reference row plus a per-server
                delta-coefficient correction and its own queue term, so
                heterogeneous fleets cost one vector op per server.
  * state     — per-device segment caches. When a request carries a
                ``device_id`` the ENGINE decides which candidates ship
                weights: a candidate whose quantized segment the device
                already holds is priced at the activation-only payload
                (``segment_cached`` set automatically, not trusted from
                the caller). Shipments install into the cache when their
                downlink completes, not at admission.

Queue semantics: the objective's queue term is the PRICING view — the
chosen server's reserved work backlog at admission (``max(0,
work_until − now)``), exactly the paper's Eq. 17-under-load term the
one-shot scheduler charged. The executed ``StageTimeline`` is the
wall-clock truth: the server segment starts at ``max(server free, cut
activation arrival)`` and servers serve reservations in admission order
(FIFO, non-preemptive). With one server and all arrivals at t = 0 the
two views coincide and the engine reproduces ``WorkloadBalancer
.schedule`` plan-for-plan and objective-for-objective (regression-locked
in tests/test_scheduler.py + tests/test_fleet.py).

Deadline/SLO admission (``slo=``):
  * "observe" — deadlines only tracked in metrics (default).
  * "reject"  — a request whose estimated finish misses ``arrival +
                deadline`` on every (server, candidate) is rejected.
  * "degrade" — same check, but before rejecting, the accuracy budget is
                relaxed level-by-level (cheaper payloads) until some
                candidate meets the deadline; only then reject.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import CostProvider, ServerProfile
from repro.serving.deployment import Deployment, ReferenceContext
from repro.serving.engine.events import (ARRIVAL, CACHE_INSTALL, COMPLETE,
                                         EPOCH, Event, EventQueue,
                                         StageTimeline)
from repro.serving.engine.metrics import FleetMetrics, FleetRecord
from repro.serving.engine.policies import AdmissionPolicy, get_policy
from repro.serving.pricing import price_window
from repro.serving.simulator import InferenceRequest, ServingResult

SLO_MODES = ("observe", "reject", "degrade")


@dataclasses.dataclass
class ServerState:
    """One fleet member: profile + the two queue views."""
    profile: ServerProfile
    work_until: float = 0.0     # pricing backlog: committed server seconds
    free: float = 0.0           # wall clock: last reservation's finish
    busy: float = 0.0           # total reserved work (utilization)


@dataclasses.dataclass
class _Pending:
    index: int                  # position in the submitted trace
    request: InferenceRequest
    arrival: float


class FleetEngine:
    """Discrete-event serving over a fleet of QPART servers.

    ``qpart_server`` supplies the registered models and offline stores;
    ``servers`` the fleet profiles (default: the qpart_server's own
    profile, a fleet of one); ``policy`` an ``AdmissionPolicy`` or its
    name; ``epoch_interval`` batches arrivals into decision epochs (0 =
    admit at each arrival instant; simultaneous arrivals always share
    one epoch/window).
    """

    def __init__(self, qpart_server, servers: Optional[Sequence[ServerProfile]] = None,
                 policy="fcfs", slo: str = "observe",
                 epoch_interval: float = 0.0,
                 provider: Optional[CostProvider] = None):
        if slo not in SLO_MODES:
            raise ValueError(f"slo must be one of {SLO_MODES}, got {slo!r}")
        self.qs = qpart_server
        profiles = list(servers) if servers is not None \
            else [qpart_server.server]
        if not profiles:
            raise ValueError("fleet needs at least one server")
        self._profiles = profiles
        self.servers = [ServerState(p) for p in profiles]
        self.policy: AdmissionPolicy = get_policy(policy)
        self.slo = slo
        self.epoch_interval = float(epoch_interval)
        self.context: Optional[ReferenceContext] = None
        # CostModel v2: pricing, SLO finish estimates, reservations and
        # breakdowns all run through the provider (default: the
        # qpart_server's — AnalyticCost unless overridden, e.g. with a
        # CalibratedCost to re-price reservations from measured rates)
        if provider is None:
            provider = getattr(qpart_server, "provider", None)
        if provider is None:
            from repro.core.cost_model import ANALYTIC
            provider = ANALYTIC
        self.provider: CostProvider = provider
        # device_id -> set of (model, accuracy level, p) the device holds
        self.caches: dict = {}

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[InferenceRequest],
            context: Optional[ReferenceContext] = None) -> FleetMetrics:
        """Run the trace to completion and return the fleet metrics
        (``.records`` is in trace order, one entry per request). Each
        run is an independent simulation: server queues and device
        caches start empty (the engine is re-runnable, not resumable)."""
        self.context = context
        self.servers = [ServerState(p) for p in self._profiles]
        self.caches = {}
        records = [FleetRecord(i, r) for i, r in enumerate(requests)]
        self._records = records
        self._queue = EventQueue()
        self._pending: List[_Pending] = []
        self._epochs = set()
        self._admit_rank = 0
        self._in_flight = 0
        self._samples: List[tuple] = []
        self._horizon = 0.0
        for i, r in enumerate(requests):
            self._queue.push(Event(float(r.arrival_time), ARRIVAL, i))
        while self._queue:
            ev = self._queue.pop()
            if ev.kind == ARRIVAL:
                self._on_arrival(ev)
            elif ev.kind == CACHE_INSTALL:
                dev_id, key = ev.payload
                self.caches.setdefault(dev_id, set()).add(key)
            elif ev.kind == EPOCH:
                self._on_epoch(ev.time)
            elif ev.kind == COMPLETE:
                self._in_flight -= 1
                self._samples.append((ev.time, self._in_flight))
        return FleetMetrics(records=records,
                            server_busy=[s.busy for s in self.servers],
                            queue_samples=self._samples,
                            horizon=self._horizon)

    # ------------------------------------------------------------------
    def _on_arrival(self, ev: Event) -> None:
        i = ev.payload
        self._pending.append(_Pending(i, self._records[i].request, ev.time))
        t = ev.time
        if self.epoch_interval > 0:
            k = math.ceil(round(t / self.epoch_interval, 9))
            t = k * self.epoch_interval
        if t not in self._epochs:
            self._epochs.add(t)
            self._queue.push(Event(t, EPOCH))

    def _on_epoch(self, t: float) -> None:
        self._epochs.discard(t)
        pending, self._pending = self._pending, []
        if not pending:
            return
        pricing = [self._pricing_request(p.request) for p in pending]
        tab = price_window(self.qs.models, self.servers[0].profile, pricing,
                           context=self.context, provider=self.provider)
        ref = self.servers[0].profile
        t_server_rows = [self.provider.server_seconds(ref, rows.o2,
                                                      rows.srv_bytes)
                         for rows in tab.rows]
        for j in self.policy.order(pending, tab, t_server_rows):
            self._admit(t, pending[j], tab, j)

    def _pricing_request(self, req: InferenceRequest) -> InferenceRequest:
        """Engine-owned cache state: a request with a ``device_id`` is
        priced from the full-payload row and the cached candidates are
        re-priced individually; the caller's flag only survives for
        anonymous requests (the one-shot degenerate case)."""
        if req.device_id is not None and req.segment_cached:
            return dataclasses.replace(req, segment_cached=False)
        return req

    # ------------------------------------------------------------------
    def _cached_candidates(self, req: InferenceRequest,
                           a_star: float) -> np.ndarray:
        if req.device_id is None:
            return np.zeros(0, dtype=int)
        held = self.caches.get(req.device_id, ())
        return np.array(sorted(p for (m, lv, p) in held
                               if m == req.model and lv == a_star),
                        dtype=int)

    def _candidate_rows(self, req: InferenceRequest, tab, j, a_star: float):
        """(base objective row, wire vector) with the device segment
        cache applied: a cached candidate drops the weight-shipment share
        of its wire term (Eq. 14 Z_w amortized to zero)."""
        row = tab.obj[j]
        wire = tab.wire[j]
        cached = self._cached_candidates(req, a_star)
        cached = cached[cached < len(wire)]
        if len(cached):
            ep = self.provider.wire_coeff(req.weights, req.device,
                                          req.channel)
            pb, px = tab.pb[j], tab.px[j]
            adj = np.zeros_like(row)
            adj[cached] = ep * (pb[cached] - px[cached])
            row = row - adj
            wire = wire.copy()
            wire[cached] = px[cached]
        return row, wire

    def _finish_vec(self, req: InferenceRequest, t: float, rows, wire_vec,
                    px_row, srv: ServerState) -> np.ndarray:
        """Estimated wall-clock completion per candidate on ``srv`` under
        the reservation semantics (exact: reservations never move). Stage
        durations come from the provider, so a calibrated/roofline
        provider's SLO admission sees its own clock."""
        r_cap = req.channel.capacity()
        ship = np.maximum(wire_vec - px_row, 0.0)
        o2 = rows.o2
        ready = (t + ship / r_cap
                 + self.provider.device_seconds(req.device, rows.o1,
                                                rows.dev_bytes)
                 + px_row / r_cap)
        start = np.where(o2 > 0, np.maximum(ready, srv.free), ready)
        return start + self.provider.server_seconds(srv.profile, o2,
                                                    rows.srv_bytes)

    # ------------------------------------------------------------------
    def _choose(self, t: float, req: InferenceRequest, arrival: float,
                tab, j: int, a_star: float, enforce_slo: bool):
        """Best (server, candidate) under the policy's server rule; None
        when ``enforce_slo`` and no pair meets the deadline."""
        row0, wire_vec = self._candidate_rows(req, tab, j, a_star)
        rows = tab.rows[j]
        o2_vec = rows.o2
        uses_server = o2_vec > 0
        ref = self.servers[0].profile
        least_loaded = self.policy.server_rule == "least_loaded"
        if least_loaded:
            # load order; under an SLO the later servers are the
            # fallback, so a request is only rejected when EVERY
            # (server, candidate) pair misses the deadline
            order = sorted(range(len(self.servers)),
                           key=lambda s: (self.servers[s].work_until, s))
            if not enforce_slo:
                order = order[:1]
        else:
            order = range(len(self.servers))
        best = None
        for s in order:
            srv = self.servers[s]
            row = row0
            if srv.profile is not ref:
                row = row + self.provider.server_correction(
                    req.weights, ref, srv.profile, rows)
            queue = max(0.0, srv.work_until - t)
            row = row + req.weights.omega * queue * uses_server
            if enforce_slo:
                finish = self._finish_vec(req, t, rows, wire_vec,
                                          tab.px[j], srv)
                row = np.where(finish <= arrival + req.deadline + 1e-12,
                               row, np.inf)
                if not np.isfinite(row).any():
                    continue
            c = int(np.argmin(row))
            if least_loaded:
                # first feasible server in load order wins outright
                return (row[c], s, c, queue, wire_vec)
            if best is None or row[c] < best[0]:
                best = (row[c], s, c, queue, wire_vec)
        return best

    # ------------------------------------------------------------------
    def _admit(self, t: float, pnd: _Pending, tab, j: int) -> None:
        req = pnd.request
        store = self.qs.models[req.model].store(self.context)
        a_star = store.level_for(req.accuracy_budget)
        enforce = req.deadline is not None and self.slo != "observe"
        choice = self._choose(t, req, pnd.arrival, tab, j, a_star, enforce)
        degraded = None
        if choice is None and self.slo == "degrade":
            for lv in sorted(store.levels):
                if lv <= a_star:
                    continue
                relaxed = dataclasses.replace(self._pricing_request(req),
                                              accuracy_budget=lv)
                tab_lv = price_window(self.qs.models,
                                      self.servers[0].profile, [relaxed],
                                      context=self.context,
                                      provider=self.provider)
                choice = self._choose(t, req, pnd.arrival, tab_lv, 0, lv,
                                      True)
                if choice is not None:
                    degraded, tab, j, a_star = lv, tab_lv, 0, lv
                    break
        rec = self._records[pnd.index]
        if choice is None:
            rec.rejected = True
            return
        _, s, c, queue, wire_vec = choice
        self._commit(t, pnd, tab, j, s, c, queue, float(wire_vec[c]),
                     a_star, degraded)

    def _commit(self, t: float, pnd: _Pending, tab, j: int, s: int, c: int,
                queue: float, wire: float, a_star: float,
                degraded: Optional[float]) -> None:
        req = pnd.request
        srv = self.servers[s]
        plan, o1, o2, _ = tab.select(j, c)
        dev_b, srv_b = tab.rows[j].bytes_at(c)
        costs = self.provider.breakdown(o1, o2, wire, req.device,
                                        srv.profile, req.channel,
                                        dev_bytes=dev_b, srv_bytes=srv_b)
        res = ServingResult(plan=plan, costs=costs,
                            objective=costs.objective(req.weights)
                            + req.weights.omega * (queue if o2 > 0 else 0.0),
                            payload_bits=wire)
        res.extra["queue_delay"] = queue if o2 > 0 else 0.0
        res.extra["server"] = s
        if degraded is not None:
            res.extra["degraded_to"] = degraded
        backend = self.qs.models[req.model].backend
        dep = Deployment(req.model, backend, req, plan, res)

        # stage timeline (events.py): ship → device segment → transfer →
        # server segment, reserved FIFO on the chosen server
        r_cap = req.channel.capacity()
        ship = max(wire - plan.payload_x_bits, 0.0)
        x_share = wire - ship
        ship_done = t + ship / r_cap
        # the executed device stage is the provider's t_local — identical
        # to o1·gamma/f under the analytic default, memory-/measurement-
        # aware under the roofline/calibrated providers
        device_done = ship_done + costs.t_local
        transfer_done = device_done + x_share / r_cap
        if o2 > 0:
            server_start = max(srv.free, transfer_done)
            finish = server_start + costs.t_server
            srv.free = finish
        else:
            server_start = transfer_done
            finish = server_start
        srv.work_until = max(srv.work_until, t) + costs.t_server
        srv.busy += costs.t_server
        tl = StageTimeline(t, ship_done, device_done, transfer_done,
                           server_start, finish)

        rec = self._records[pnd.index]
        rec.deployment = dep
        rec.timeline = tl
        rec.server = s
        rec.start_order = self._admit_rank
        rec.backlog_at_admission = queue
        rec.queue_delay = res.extra["queue_delay"]
        rec.degraded_to = degraded
        self._admit_rank += 1

        if (req.device_id is not None and plan.p and ship > 0):
            self._queue.push(Event(ship_done, CACHE_INSTALL,
                                   (req.device_id,
                                    (req.model, a_star, plan.p))))
        self._in_flight += 1
        self._samples.append((t, self._in_flight))
        self._queue.push(Event(finish, COMPLETE, pnd.index))
        self._horizon = max(self._horizon, finish)
