"""Event-driven fleet serving engine (DESIGN.md §8, resilience §10,
scale §12).

Runs a discrete-event loop over timestamped ``InferenceRequest`` arrivals
against a MULTI-SERVER fleet: plan → uplink (model shipment) → device
segment → cut-activation transfer → server segment → complete. The
engine generalizes the one-shot ``WorkloadBalancer.schedule`` window
along three axes while keeping its vectorized hot path (every decision
epoch prices all pending requests as ONE ``price_window`` matrix):

  * time      — arrivals carry ``arrival_time``; requests admitted at a
                later epoch see whatever backlog earlier admissions left.
  * fleet     — N servers, each with its own ``ServerProfile``, work
                backlog and wall-clock reservation horizon. The pricing
                row of server s is the reference row plus a per-server
                delta-coefficient correction and its own queue term, so
                heterogeneous fleets cost one vector op per server.
  * state     — per-device segment caches. When a request carries a
                ``device_id`` the ENGINE decides which candidates ship
                weights: a candidate whose quantized segment the device
                already holds is priced at the activation-only payload
                (``segment_cached`` set automatically, not trusted from
                the caller). Shipments install into the cache when their
                downlink completes, not at admission.

Queue semantics: the objective's queue term is the PRICING view — the
chosen server's reserved work backlog at admission (``max(0,
work_until − now)``), exactly the paper's Eq. 17-under-load term the
one-shot scheduler charged. The executed ``StageTimeline`` is the
wall-clock truth: the server segment starts at ``max(server free, cut
activation arrival)`` and servers serve reservations in admission order
(FIFO, non-preemptive). With one server and all arrivals at t = 0 the
two views coincide and the engine reproduces ``WorkloadBalancer
.schedule`` plan-for-plan and objective-for-objective (regression-locked
in tests/test_scheduler.py + tests/test_fleet.py).

Deadline/SLO admission (``slo=``):
  * "observe" — deadlines only tracked in metrics (default).
  * "reject"  — a request whose estimated finish misses ``arrival +
                deadline`` on every (server, candidate) is rejected.
  * "degrade" — same check, but before rejecting, the accuracy budget is
                relaxed level-by-level (cheaper payloads) until some
                candidate meets the deadline; only then reject.

Fault tolerance (DESIGN.md §10): a ``FaultInjector`` merges seeded
DISCONNECT / RECONNECT / DEGRADE events into the queue. A disconnect
CANCELS every in-flight attempt of that device still in its
ship/device/transfer stage — the server reservation is released (the
backlog refund future admissions price against; committed later
timelines never move), a pending CACHE_INSTALL is invalidated, and the
request goes to the ``RetryPolicy`` (capped exponential backoff,
per-request attempt budget, optional accuracy degradation per retry,
terminal dead-letter queue). Arrivals on a down device PARK — no
attempt burned — until reconnect, and park forever becomes the
``disconnect_abandoned`` dead letter when the trace drains. Every event
processed lands in a replayable ``EventJournal``; with no faults
injected the engine is bit-for-bit the sunny-day engine of §8.

Scale (DESIGN.md §12): the hot loop is built for 10⁶-request traces —
arrivals bulk-load through one stable argsort (``ArrivalStream``)
instead of a heappush per request, per-request facts live in a columnar
``RecordStore``, the admission argmin runs as one (servers × candidates)
masked matrix op (``admission="vectorized"``; the historical scalar loop
survives as ``admission="reference"`` and is asserted decision-for-
decision identical), the degrade/retry ladders re-price against cached
one-row tables, and ``journal="light"|"off"`` drop journaling overhead.
Every knob defaults to the bit-for-bit path (vectorized admission IS
bit-for-bit; it's locked, not trusted).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cost_model import Channel, CostProvider, ServerProfile
from repro.serving.decode.batching import DecodeBatcher, DecodeStream
from repro.serving.decode.cache import PageLedger, paged_kv_ctx
from repro.serving.decode.pipeline import DecodeSession

_chunk_bounds = DecodeSession.chunk_bounds
from repro.serving.deployment import Deployment, ReferenceContext
from repro.serving.engine.events import (ARRIVAL, CACHE_INSTALL, COMPLETE,
                                         DECODE_STEP, EPOCH, FAULT,
                                         PREFILL_CHUNK, RETRY,
                                         ArrivalStream, EventQueue,
                                         StageTimeline)
from repro.serving.engine.faults import (DEGRADE, DISCONNECT, RECONNECT,
                                         FaultInjector)
from repro.serving.engine.journal import (JOURNAL_MODES, EventJournal,
                                          LightJournal)
from repro.serving.engine.metrics import FleetMetrics
from repro.serving.engine.policies import AdmissionPolicy, get_policy
from repro.serving.engine.records import (DROP_CODES, LazyRecords,
                                          RecordStore)
from repro.serving.engine.retry import (REASON_ABANDONED, REASON_EXHAUSTED,
                                        REASON_SLO, DeadLetter, RetryPolicy)
from repro.serving.errors import ServingError
from repro.serving.pricing import decode_rows_for, price_window
from repro.serving.simulator import InferenceRequest, ServingResult

SLO_MODES = ("observe", "reject", "degrade")
RECORD_MODES = ("full", "light")
ADMISSION_MODES = ("vectorized", "reference")


@dataclasses.dataclass
class ServerState:
    """One fleet member: profile + the two queue views + the active
    reservation ledger (token -> committed finish time) that fault
    cancellation rolls back."""
    profile: ServerProfile
    work_until: float = 0.0     # pricing backlog: committed server seconds
    free: float = 0.0           # wall clock: last reservation's finish
    busy: float = 0.0           # total reserved work (utilization)
    reservations: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Pending:
    index: int                  # position in the submitted trace
    request: InferenceRequest
    arrival: float


@dataclasses.dataclass
class _Flight:
    """One in-flight admission attempt (between commit and COMPLETE)."""
    token: tuple                # (request index, attempt) — unique
    device_id: Optional[str]
    server: int
    t_server: float             # reserved server seconds (the refund)
    timeline: StageTimeline


class FleetEngine:
    """Discrete-event serving over a fleet of QPART servers.

    ``qpart_server`` supplies the registered models and offline stores;
    ``servers`` the fleet profiles (default: the qpart_server's own
    profile, a fleet of one); ``policy`` an ``AdmissionPolicy`` or its
    name; ``epoch_interval`` batches arrivals into decision epochs (0 =
    admit at each arrival instant; simultaneous arrivals always share
    one epoch/window); ``retry`` the fault-recovery ``RetryPolicy``
    (default ``RetryPolicy()`` — inert without faults); ``faults`` a
    ``FaultInjector`` or plain ``FaultEvent`` sequence.

    Scale knobs (DESIGN.md §12) — every default is the full-fidelity
    path, and every non-default is decision-for-decision identical
    (only cheaper bookkeeping):

    ``journal``   — "full" (replayable ``EventJournal``), "light"
                    (columnar time/kind tape), "off" (no journal object;
                    ``metrics.journal`` is None).
    ``records``   — "full" keeps per-request ``Deployment`` objects;
                    "light" skips result assembly (views carry
                    ``deployment=None``; stage math identical).
    ``admission`` — "vectorized" (one masked (servers × candidates)
                    argmin per admission), "reference" (the historical
                    per-server scalar loop, kept as the equivalence
                    oracle).
    ``reprice_cache`` — memoize the degrade/retry ladders' one-row
                    ``price_window`` tables per (model, level, batch,
                    device, effective channel, weights, cached) for the
                    run; False re-prices fresh per rung (the oracle).
    """

    def __init__(self, qpart_server, servers: Optional[Sequence[ServerProfile]] = None,
                 policy="fcfs", slo: str = "observe",
                 epoch_interval: float = 0.0,
                 provider: Optional[CostProvider] = None,
                 retry: Optional[RetryPolicy] = None,
                 faults: Optional[FaultInjector] = None,
                 journal: str = "full", records: str = "full",
                 admission: str = "vectorized",
                 reprice_cache: bool = True,
                 draft_tokens: int = 0,
                 accept_rate: Optional[float] = None,
                 prefill_chunk_tokens: Optional[int] = None):
        if slo not in SLO_MODES:
            raise ValueError(f"slo must be one of {SLO_MODES}, got {slo!r}")
        if journal not in JOURNAL_MODES:
            raise ValueError(f"journal must be one of {JOURNAL_MODES}, "
                             f"got {journal!r}")
        if records not in RECORD_MODES:
            raise ValueError(f"records must be one of {RECORD_MODES}, "
                             f"got {records!r}")
        if admission not in ADMISSION_MODES:
            raise ValueError(f"admission must be one of {ADMISSION_MODES}, "
                             f"got {admission!r}")
        self.qs = qpart_server
        profiles = list(servers) if servers is not None \
            else [qpart_server.server]
        if not profiles:
            raise ValueError("fleet needs at least one server")
        self._profiles = profiles
        self.servers = [ServerState(p) for p in profiles]
        self.policy: AdmissionPolicy = get_policy(policy)
        self.slo = slo
        self.epoch_interval = float(epoch_interval)
        self.context: Optional[ReferenceContext] = None
        self.journal_mode = journal
        self.records_mode = records
        self.admission_mode = admission
        self._reprice_enabled = bool(reprice_cache)
        self._choose = self._choose_vectorized \
            if admission == "vectorized" else self._choose_reference
        # CostModel v2: pricing, SLO finish estimates, reservations and
        # breakdowns all run through the provider (default: the
        # qpart_server's — AnalyticCost unless overridden, e.g. with a
        # CalibratedCost to re-price reservations from measured rates)
        if provider is None:
            provider = getattr(qpart_server, "provider", None)
        if provider is None:
            from repro.core.cost_model import ANALYTIC
            provider = ANALYTIC
        self.provider: CostProvider = provider
        self.retry: RetryPolicy = retry if retry is not None else RetryPolicy()
        if faults is None:
            faults = FaultInjector()
        elif not isinstance(faults, FaultInjector):
            faults = FaultInjector(faults)
        self.faults: FaultInjector = faults
        # device_id -> set of (model, accuracy level, p) the device holds
        self.caches: dict = {}
        self.dead_letters: List[DeadLetter] = []
        self.kv_ledger = PageLedger()
        self._kv_streams: dict = {}
        # serving-shape knobs (DESIGN.md §14), default-off: the zero-knob
        # engine is bit-for-bit the PR 9 engine (journal header included —
        # the keys below only exist when a knob is enabled)
        self.draft_tokens = int(draft_tokens)
        if self.draft_tokens < 0:
            raise ValueError("draft_tokens must be >= 0")
        if accept_rate is None and self.draft_tokens:
            # measured rate from a calibrated provider's ledger when one
            # exists (CalibratedCost.mean_accept_rate), else the neutral
            # prior — resolved ONCE so the journal header pins the value
            # replay reuses
            measured = getattr(self.provider, "mean_accept_rate", None)
            accept_rate = float(measured) if measured is not None else 0.5
        self.accept_rate = None if accept_rate is None \
            else float(accept_rate)
        if self.accept_rate is not None \
                and not 0.0 <= self.accept_rate <= 1.0:
            raise ValueError("accept_rate must be within [0, 1]")
        self.prefill_chunk_tokens = None if prefill_chunk_tokens is None \
            else int(prefill_chunk_tokens)
        if self.prefill_chunk_tokens is not None \
                and self.prefill_chunk_tokens < 2:
            raise ValueError("prefill_chunk_tokens must be >= 2")
        self._chunk_state: dict = {}
        # server -> {index: (requeue_time, chunk_s)} of deferred chunks:
        # _push_decode holds the lane for the earliest one so saturated
        # decode lanes (step_lag == 0) cannot starve a queued prompt
        self._chunk_wait: dict = {}

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[InferenceRequest],
            context: Optional[ReferenceContext] = None) -> FleetMetrics:
        """Run the trace to completion and return the fleet metrics
        (``.records`` is in trace order, one entry per request). Each
        run is an independent simulation: server queues, device caches
        and fault state start empty (the engine is re-runnable, not
        resumable). Every request ends terminal: completed, rejected,
        or dead-lettered with a reason."""
        self.context = context
        self.servers = [ServerState(p) for p in self._profiles]
        self.caches = {}
        st = RecordStore(requests, full=self.records_mode == "full")
        self._st = st
        self._queue = EventQueue()
        self._pending: List[_Pending] = []
        self._epochs = set()
        self._admit_rank = 0
        self._in_flight = 0
        # queue-depth samples as growing columns (one per commit/finish)
        self._s_t = np.empty(256, dtype=np.float64)
        self._s_d = np.empty(256, dtype=np.int64)
        self._s_len = 0
        self._horizon = 0.0
        # fault-tolerance state (all per-run)
        self._down: set = set()              # disconnected device_ids
        self._parked: dict = {}              # device_id -> [indices]
        self._channel_factor: dict = {}      # device_id -> capacity factor
        self._eff_channels: dict = {}        # (channel, factor) -> Channel
        self._inflight: dict = {}            # index -> _Flight
        self._live: set = set()              # valid admission tokens
        # decode lane (DESIGN.md §11): one continuous batcher per server,
        # per-(model, level, batch) per-token term rows
        self._batchers = [DecodeBatcher() for _ in self.servers]
        self._decode_rows_cache: dict = {}
        # block-granular device-KV residency (PR 9): streams of a backend
        # with ``kv_page_tokens`` set are tracked at page granularity —
        # open at prefill, grown as the ring fills, closed on finish or
        # severance. Empty (zero-overhead) for legacy dense backends.
        self.kv_ledger = PageLedger()
        self._kv_streams: dict = {}          # index -> (backend, batch, cut)
        self.dead_letters = []
        # per-run pricing caches (§12). All keyed through the shared
        # ``_price_cache``'s stable CandidateRows identities — dropping
        # the whole set at run start is the invalidation story.
        self._price_cache: dict = {}         # price_window row/spec cache
        self._reprice_tables: dict = {}      # ladder one-row WindowTables
        self._corr_cache: dict = {}          # (id(rows), weights, profile)
        self._tsrv_cache: dict = {}          # (id(rows), profile)
        self._tsrv_stacks: dict = {}         # id(rows) -> (S, C) matrix
        self._corr_stacks: dict = {}         # (id(rows), weights) -> matrix
        self._tdev_cache: dict = {}          # (id(rows), device)
        self._order_cache = None             # least-loaded server order
        self._stores: dict = {}              # model name -> OfflineStore
        # the fleet's heterogeneity layout is fixed for the run: which
        # servers price off the reference row directly (profile IS the
        # reference object) vs through a delta correction
        ref = self.servers[0].profile
        self._nonref_idx = np.array(
            [s for s in range(len(self.servers))
             if self.servers[s].profile is not ref], dtype=np.intp)
        self._homogeneous = self._nonref_idx.size == 0
        self._chunk_state = {}
        self._chunk_wait = {}
        header = {
            "policy": self.policy.name, "slo": self.slo,
            "epoch_interval": self.epoch_interval,
            "servers": len(self.servers),
            "retry": dataclasses.asdict(self.retry),
            "requests": st.n, "faults": len(self.faults)}
        # keys exist ONLY when a serving-shape knob is on, so a zero-knob
        # run's header (and hence journal) is byte-identical to PR 9's
        if self.draft_tokens:
            header["draft_tokens"] = self.draft_tokens
            header["accept_rate"] = self.accept_rate
        if self.prefill_chunk_tokens is not None:
            header["prefill_chunk_tokens"] = self.prefill_chunk_tokens
        if self.journal_mode == "full":
            self._journal = EventJournal(header=header)
        elif self.journal_mode == "light":
            self._journal = LightJournal(header=header)
        else:
            self._journal = None
        for f in self.faults.events:
            self._queue.push(float(f.time), FAULT, f)
        arrivals = ArrivalStream(st.arrival)
        queue = self._queue
        # sorted-merge dispatch: the arrival cursor races the heap on
        # (time, kind) — no ARRIVAL is ever IN the heap, so strict
        # lexicographic comparison reproduces the historical all-heap
        # order exactly (FAULT=0 still preempts same-time arrivals)
        while True:
            if arrivals.pos < arrivals.n:
                key = queue.peek_key()
                if key is None or (arrivals.times[arrivals.pos], ARRIVAL) \
                        < key:
                    t, i = arrivals.pop()
                    self._on_arrival(t, i)
                    continue
            elif not queue:
                break
            t, kind, payload = queue.pop()
            if kind == COMPLETE:
                self._on_complete(t, payload)
            elif kind == EPOCH:
                self._on_epoch(t)
            elif kind == CACHE_INSTALL:
                dev_id, key, token = payload
                applied = token in self._live
                if applied:
                    self.caches.setdefault(dev_id, set()).add(key)
                if self._journal is not None:
                    self._journal.record(t, CACHE_INSTALL, device=dev_id,
                                         model=key[0], level=key[1],
                                         p=key[2], applied=applied)
            elif kind == DECODE_STEP:
                self._on_decode(t, payload)
            elif kind == PREFILL_CHUNK:
                self._on_prefill_chunk(t, payload)
            elif kind == RETRY:
                self._on_retry(t, payload)
            elif kind == FAULT:
                self._on_fault(t, payload)
        # trace drained: whoever is still parked never saw a reconnect
        for dev in sorted(self._parked):
            for i in self._parked[dev]:
                self._dead_letter(i, REASON_ABANDONED, self._horizon)
        self._parked = {}
        samples = np.stack([self._s_t[:self._s_len],
                            self._s_d[:self._s_len].astype(np.float64)],
                           axis=1)
        return FleetMetrics(records=LazyRecords(st),
                            server_busy=[s.busy for s in self.servers],
                            queue_samples=samples,
                            horizon=self._horizon,
                            dead_letters=list(self.dead_letters),
                            journal=self._journal,
                            store=st)

    # ------------------------------------------------------------------
    def _sample(self, t: float) -> None:
        i = self._s_len
        if i == self._s_t.shape[0]:
            self._s_t = np.concatenate([self._s_t, np.empty_like(self._s_t)])
            self._s_d = np.concatenate([self._s_d, np.empty_like(self._s_d)])
        self._s_t[i] = t
        self._s_d[i] = self._in_flight
        self._s_len = i + 1

    def _schedule_epoch(self, t: float) -> None:
        """Queue the decision epoch covering instant ``t``. Epoch
        bucketing is EXACT: the smallest k with k·interval >= t, decided
        by comparing actual float products — ``ceil(t / interval)``
        alone drifts for non-dyadic intervals (an on-boundary arrival
        lands in the NEXT epoch, or a just-past-boundary arrival gets an
        epoch scheduled in its past; locked in tests/test_faults.py)."""
        if self.epoch_interval > 0:
            iv = self.epoch_interval
            k = math.ceil(t / iv)
            while (k - 1) * iv >= t:
                k -= 1
            while k * iv < t:
                k += 1
            t = k * iv
        if t not in self._epochs:
            self._epochs.add(t)
            self._queue.push(t, EPOCH, None)

    def _on_arrival(self, t: float, i: int) -> None:
        req = self._st.requests[i]
        parked = req.device_id is not None and req.device_id in self._down
        if parked:
            self._parked.setdefault(req.device_id, []).append(i)
            self._st.parked[i] += 1
        else:
            self._pending.append(_Pending(i, req, t))
            self._schedule_epoch(t)
        if self._journal is not None:
            self._journal.record(t, ARRIVAL, index=i, parked=parked)

    def _on_retry(self, t: float, payload) -> None:
        i, attempt = payload
        req = self._st.requests[i]
        parked = req.device_id is not None and req.device_id in self._down
        if parked:
            self._parked.setdefault(req.device_id, []).append(i)
            self._st.parked[i] += 1
        else:
            # deadline stays absolute: the pending entry keeps the
            # ORIGINAL arrival, so EDF/SLO see arrival + deadline
            self._pending.append(_Pending(i, req, req.arrival_time))
            self._schedule_epoch(t)
        if self._journal is not None:
            self._journal.record(t, RETRY, index=i, attempt=attempt,
                                 parked=parked)

    def _on_complete(self, t: float, payload) -> None:
        i, token = payload
        if token not in self._live:
            # a fault cancelled this attempt after its COMPLETE was
            # queued — a non-event, but journaled so replay sees it
            if self._journal is not None:
                self._journal.record(t, COMPLETE, index=i, stale=True)
            return
        self._live.discard(token)
        fl = self._inflight.pop(i)
        self.servers[fl.server].reservations.pop(token, None)
        self._in_flight -= 1
        self._sample(t)
        if t > self._horizon:
            self._horizon = t
        if self._journal is not None:
            self._journal.record(t, COMPLETE, index=i, stale=False)

    # -- decode lane (DESIGN.md §11) -----------------------------------
    def _decode_rows(self, req: InferenceRequest, a_star: float):
        """Per-token candidate term rows of the request's model at its
        resolved accuracy level — cached per (model, level, batch)."""
        key = (req.model, a_star, req.batch)
        rows = self._decode_rows_cache.get(key)
        if rows is None:
            m = self.qs.models[req.model]
            rows = decode_rows_for(m.backend, m.store(self.context),
                                   a_star, req.batch,
                                   self.provider.uses_bytes)
            self._decode_rows_cache[key] = rows
        return rows

    def _push_decode(self, s: int) -> None:
        """Queue a DECODE_STEP at server ``s``'s next round time. Called
        after EVERY batcher mutation; previously queued events whose time
        no longer matches are detected as stale at fire time. A waiting
        prefill chunk HOLDS the lane (DESIGN.md §14): the next round is
        pushed past that chunk's slot, so back-to-back rounds (step_lag
        = 0 full-offload streams) cannot starve a queued prompt — the
        two event kinds alternate fairly on the shared timeline."""
        t_next = self._batchers[s].next_time()
        if t_next is not None:
            wait = self._chunk_wait.get(s)
            if wait:
                tc, dt_c = min(wait.values())
                if tc <= t_next:
                    t_next = max(t_next, tc + dt_c)
            self._queue.push(t_next, DECODE_STEP, s)

    def _start_stream(self, finish: float, i: int, req: InferenceRequest,
                      plan, a_star: float, s: int, token: tuple,
                      n_tok: int) -> None:
        """Register an admitted request's decode stream with its server's
        batcher. The prefill delivers token 1 at ``finish`` (TTFT); each
        later token costs one device-segment step + one hidden-state hop
        (``step_lag``) before it can join a server round."""
        rows = self._decode_rows(req, a_star)
        c = plan.p
        dev_b, srv_b = rows.bytes_at(c)
        dt_dev = self.provider.device_seconds(req.device, float(rows.o1[c]),
                                              dev_b)
        # speculation needs a device segment to draft through AND a round
        # trip to amortize — full offload (p == 0) streams plainly
        draft_k = min(self.draft_tokens, n_tok - 2) if plan.p else 0
        if plan.p:
            backend = self.qs.models[req.model].backend
            if draft_k > 0:
                # one speculative round: k+1 device decode steps, then
                # (k+1) quantized cut hiddens + k draft ids uplink and
                # up to k+1 verified ids downlink — ONE channel latency
                # amortized over E[1 + alpha*k] emitted tokens
                hid = plan.bits_x * backend.cfg.d_model * req.batch
                wire_rnd = ((draft_k + 1) * hid
                            + 32.0 * draft_k * req.batch
                            + 32.0 * (draft_k + 1) * req.batch)
                step_lag = float((draft_k + 1) * dt_dev
                                 + wire_rnd / req.channel.capacity())
            else:
                wire_tok = (plan.bits_x * backend.cfg.d_model * req.batch
                            + 32.0 * req.batch)
                step_lag = float(dt_dev + wire_tok / req.channel.capacity())
        else:
            # full offload: the server feeds its own sample back — no
            # device hop on the decode path
            step_lag = 0.0
        stream = DecodeStream(
            index=i, token=token, device_id=req.device_id,
            remaining=n_tok - 1, ready_at=finish + step_lag,
            o2_tok=float(rows.o2[c]), srv_bytes_tok=srv_b,
            step_lag=step_lag)
        if draft_k > 0:
            stream.draft_k = draft_k
            stream.alpha = self.accept_rate
        self._batchers[s].add(stream)
        backend = self.qs.models[req.model].backend
        if plan.p and getattr(backend, "kv_page_tokens", None) is not None \
                and backend.decode_max_len is not None:
            self._kv_open(i, backend, req.batch, c)
        self._push_decode(s)

    # -- page-granular KV residency (PR 9) ------------------------------
    def _kv_resident(self, backend, batch: int, cut: int, tokens: int):
        """(bytes, context pages) a ``tokens``-token stream holds at cut
        ``cut`` under paged allocation — ``kv_bytes_row`` at the
        page-rounded context (cached per (batch, ctx) on the backend, so
        per-round lookups are dict hits)."""
        row = backend.kv_bytes_row(batch, tokens=tokens)
        ctx = paged_kv_ctx(tokens, backend.kv_page_tokens,
                           backend.decode_max_len)
        return float(row[cut]), ctx // backend.kv_page_tokens

    def _kv_open(self, i: int, backend, batch: int, cut: int) -> None:
        tokens = int(backend.seq_len) + 1
        nbytes, pages = self._kv_resident(backend, batch, cut, tokens)
        self.kv_ledger.open(i, nbytes, pages)
        self._kv_streams[i] = (backend, batch, cut)

    def _kv_grow(self, i: int) -> None:
        info = self._kv_streams.get(i)
        if info is None:
            return
        backend, batch, cut = info
        tokens = int(backend.seq_len) + int(self._st.tokens_emitted[i]) + 1
        self.kv_ledger.grow(i, *self._kv_resident(backend, batch, cut,
                                                  tokens))

    def _kv_close(self, i: int) -> None:
        if self._kv_streams.pop(i, None) is not None:
            self.kv_ledger.close(i)

    def _on_decode(self, t: float, s: int) -> None:
        """One continuous-batching round at server ``s``: every stream
        whose next input has arrived joins, the round is priced once for
        the batch (MAC terms add, the tail weight-stream term amortizes
        — ``server_seconds(Σ o2_tok, max srv_bytes_tok)``)."""
        batcher = self._batchers[s]
        t_next = batcher.next_time()
        if t_next is None or t < t_next:
            # the batcher mutated since this event was queued — a fresh
            # event exists at the re-derived time; this one is a no-op
            if self._journal is not None:
                self._journal.record(t, DECODE_STEP, server=s, stale=True)
            return
        st, srv = self._st, self.servers[s]
        due = batcher.due(t)
        if self.draft_tokens:
            # a speculative stream's round verifies k+1 rows in one tail
            # forward — its MAC term scales; the weight-stream byte term
            # is still read once for the whole round
            dt = float(self.provider.server_seconds(
                srv.profile,
                sum(stm.o2_tok * (stm.draft_k + 1) for stm in due),
                max(stm.srv_bytes_tok for stm in due)))
        else:
            dt = float(self.provider.server_seconds(
                srv.profile, sum(stm.o2_tok for stm in due),
                max(stm.srv_bytes_tok for stm in due)))
        t_end = t + dt
        srv.work_until = max(srv.work_until, t) + dt
        srv.busy += dt
        self._order_cache = None
        batcher.busy_until = t_end
        active, finished, emitted = [], [], []
        for stm in due:
            if stm.draft_k > 0:
                # deterministic stand-in for the measured acceptance: the
                # fractional accumulator floor((j+1)·α·k) − floor(j·α·k)
                # emits exactly E[1 + α·k] tokens per round on average
                # with no RNG, so journals replay bit-for-bit
                j = stm.rounds_done
                ak = stm.alpha * stm.draft_k
                acc = int(math.floor((j + 1) * ak) - math.floor(j * ak))
                m = min(1 + acc, stm.remaining)
                stm.rounds_done = j + 1
            else:
                m = 1
            emitted.append(m)
            stm.remaining -= m
            st.tokens_emitted[stm.index] += m
            if stm.remaining <= 0:
                batcher.remove(stm.index)
                self._kv_close(stm.index)
                st.decode_done[stm.index] = t_end
                finished.append(stm.index)
                self._queue.push(t_end, COMPLETE, (stm.index, stm.token))
            else:
                batcher.rearm(stm.index, t_end + stm.step_lag)
                self._kv_grow(stm.index)
                active.append(stm.index)
        if self._journal is not None:
            if self.draft_tokens:
                self._journal.record(t, DECODE_STEP, server=s, stale=False,
                                     round_s=dt, batch=len(due),
                                     active=active, finished=finished,
                                     emitted=emitted)
            else:
                self._journal.record(t, DECODE_STEP, server=s, stale=False,
                                     round_s=dt, batch=len(due),
                                     active=active, finished=finished)
        self._push_decode(s)

    # -- chunked prefill lane (DESIGN.md §14) ---------------------------
    def _on_prefill_chunk(self, t: float, payload) -> None:
        """One prompt chunk lands on the server's decode lane: it runs
        for ``t_server / n`` seconds on the batcher's shared
        ``busy_until`` timeline (decode rounds in progress defer it;
        it defers decode rounds symmetrically), and the LAST chunk ends
        the prefill — TTFT, stream start, COMPLETE scheduling."""
        i, token, j = payload
        cs = self._chunk_state.get(i)
        if token not in self._live or cs is None or cs["token"] != token:
            # a fault cancelled this attempt; chunk events of the dead
            # attempt are journaled non-events, like stale COMPLETEs
            if self._journal is not None:
                self._journal.record(t, PREFILL_CHUNK, index=i, chunk=j,
                                     stale=True)
            return
        s = cs["s"]
        batcher = self._batchers[s]
        if t < batcher.busy_until:
            # a decode round holds the lane — re-queue at its end (the
            # round that extended busy_until fired after this chunk was
            # queued, the same lazy-staleness dance DECODE_STEP does)
            self._queue.push(batcher.busy_until, PREFILL_CHUNK, payload)
            self._chunk_wait.setdefault(s, {})[i] = (batcher.busy_until,
                                                     cs["dt_c"])
            if self._journal is not None:
                self._journal.record(t, PREFILL_CHUNK, index=i, chunk=j,
                                     deferred=True)
            return
        srv = self.servers[s]
        self._chunk_wait.get(s, {}).pop(i, None)
        dt_c = cs["dt_c"]
        t_end = t + dt_c
        srv.work_until = max(srv.work_until, t) + dt_c
        srv.busy += dt_c
        self._order_cache = None
        batcher.busy_until = t_end
        if cs["started"] is None:
            cs["started"] = t
        last = j == cs["n"] - 1
        if self._journal is not None:
            self._journal.record(t, PREFILL_CHUNK, index=i, chunk=j,
                                 stale=False, chunk_s=dt_c, last=last)
        if not last:
            self._queue.push(max(cs["arrivals"][j + 1], t_end),
                             PREFILL_CHUNK, (i, token, j + 1))
            self._push_decode(s)
            return
        # final chunk — the prefill is done; the executed lane times
        # replace the provisional timeline committed at admission
        del self._chunk_state[i]
        st = self._st
        st.tl[i, 4] = cs["started"]
        st.tl[i, 5] = t_end
        fl = self._inflight.get(i)
        if fl is not None:
            fl.timeline.server_start = cs["started"]
            fl.timeline.finish = t_end
        n_tok = cs["n_tok"]
        req = cs["req"]
        if n_tok > 1 and req.device_id is not None \
                and req.device_id in self._down:
            # the device died while its chunks were already at the
            # server: the prefill completes as committed work, but the
            # decode stream can never be fed — sever exactly like
            # _cancel_device's mid-stream branch and retry
            self._live.discard(token)
            del self._inflight[i]
            self._in_flight -= 1
            self._sample(t_end)
            st.reset_attempt(i)
            st.faults[i] += 1
            self._retry_or_dead_letter(i, t_end)
            self._push_decode(s)
            return
        if n_tok > 1:
            self._start_stream(t_end, i, req, cs["plan"], cs["a_star"],
                               s, token, n_tok)
        else:
            if n_tok == 1:
                st.decode_done[i] = t_end
            self._queue.push(t_end, COMPLETE, (i, token))
        self._push_decode(s)

    # -- faults --------------------------------------------------------
    def _on_fault(self, t: float, f) -> None:
        if f.kind == DEGRADE:
            if f.factor == 1.0:
                self._channel_factor.pop(f.device_id, None)
            else:
                self._channel_factor[f.device_id] = f.factor
            if self._journal is not None:
                self._journal.record(t, FAULT, fault=DEGRADE,
                                     device=f.device_id, factor=f.factor)
        elif f.kind == DISCONNECT:
            self._down.add(f.device_id)
            cancelled = self._cancel_device(f.device_id, t)
            if self._journal is not None:
                self._journal.record(t, FAULT, fault=DISCONNECT,
                                     device=f.device_id, cancelled=cancelled)
        elif f.kind == RECONNECT:
            self._down.discard(f.device_id)
            released = self._parked.pop(f.device_id, [])
            for i in released:
                self._pending.append(
                    _Pending(i, self._st.requests[i],
                             self._st.requests[i].arrival_time))
            if released:
                self._schedule_epoch(t)
            if self._journal is not None:
                self._journal.record(t, FAULT, fault=RECONNECT,
                                     device=f.device_id,
                                     released=list(released))

    def _cancel_device(self, dev: str, t: float) -> list:
        """Cancel every in-flight attempt of ``dev`` still in its
        ship/device/transfer stage (an attempt whose cut activation
        already reached the server — t >= transfer_done — completes
        server-side as committed). Cancellation releases the server
        reservation and hands the request to the retry policy.

        Decode streams extend the window: a stream whose device is still
        feeding the batcher (tokens remaining) is severed even AFTER its
        prefill reached the server — the next hidden-state hop can never
        arrive. The prefill's server work stays billed (committed), only
        the reservation ledger entry is dropped, and the whole attempt
        retries from scratch. A stream that already emitted its last
        token (out of the batcher, COMPLETE queued) lands as committed."""
        cancelled = []
        st = self._st
        for i in sorted(self._inflight):
            fl = self._inflight[i]
            if fl.device_id != dev:
                continue
            stream = self._batchers[fl.server].remove(i)
            if t >= fl.timeline.transfer_done and stream is None:
                continue
            if stream is not None:
                self._kv_close(i)
                self._push_decode(fl.server)
            del self._inflight[i]
            self._live.discard(fl.token)
            cs = self._chunk_state.pop(i, None)  # queued chunks go stale
            if cs is not None:
                self._chunk_wait.get(cs["s"], {}).pop(i, None)
            if t < fl.timeline.transfer_done:
                self._release(fl)
            else:
                # mid-stream severance: no backlog refund, just drop the
                # reservation ledger entry (mirrors _release sans refund)
                srv = self.servers[fl.server]
                if srv.reservations.pop(fl.token, None) is not None:
                    srv.free = max(srv.reservations.values(), default=0.0)
            self._in_flight -= 1
            self._sample(t)
            # the failed attempt's deployment is void — reset the
            # per-attempt fields; a successful retry repopulates them
            st.reset_attempt(i)
            st.faults[i] += 1
            cancelled.append(i)
            self._retry_or_dead_letter(i, t)
        return cancelled

    def _release(self, fl: _Flight) -> None:
        """Roll back a cancelled attempt's server commitment: refund the
        pricing backlog (``work_until``/``busy``) and, if this was the
        tail reservation, the wall-clock ``free`` horizon. Committed
        LATER timelines never move (reservations are immutable): a
        mid-ledger hole is idle time, deliberately non-work-conserving."""
        srv = self.servers[fl.server]
        if srv.reservations.pop(fl.token, None) is not None:
            srv.free = max(srv.reservations.values(), default=0.0)
        srv.work_until -= fl.t_server
        srv.busy -= fl.t_server
        self._order_cache = None

    def _retry_or_dead_letter(self, i: int, t: float) -> None:
        used = int(self._st.attempts[i])
        if used >= self.retry.budget_for(self._st.requests[i]):
            self._dead_letter(i, REASON_EXHAUSTED, t)
        else:
            self._queue.push(t + self.retry.backoff(used + 1),
                             RETRY, (i, used + 1))

    def _dead_letter(self, i: int, reason: str, t: float) -> None:
        st = self._st
        st.rejected[i] = True
        st.drop_code[i] = DROP_CODES[reason]
        self.dead_letters.append(DeadLetter(i, reason, t,
                                            int(st.attempts[i]),
                                            st.requests[i].device_id))

    # -- pricing views -------------------------------------------------
    def _effective_channel(self, req: InferenceRequest) -> Channel:
        """The request's channel with any active degradation applied
        (memoized per (channel, factor) so provider coefficient caches
        stay hot)."""
        factor = self._channel_factor.get(req.device_id) \
            if req.device_id is not None else None
        if not factor or factor == 1.0:
            return req.channel
        key = (req.channel, factor)
        ch = self._eff_channels.get(key)
        if ch is None:
            ch = Channel(bandwidth_hz=req.channel.bandwidth_hz,
                         capacity_bps=req.channel.capacity() * factor)
            self._eff_channels[key] = ch
        return ch

    def _effective_request(self, req: InferenceRequest) -> InferenceRequest:
        """The request as admission sees it: degraded channel applied,
        caller's cache flag preserved (identity when no fault state —
        the zero-fault path stays bit-for-bit)."""
        ch = self._effective_channel(req)
        if ch is req.channel:
            return req
        return dataclasses.replace(req, channel=ch)

    def _pricing_request(self, req: InferenceRequest) -> InferenceRequest:
        """Engine-owned cache state: a request with a ``device_id`` is
        priced from the full-payload row and the cached candidates are
        re-priced individually; the caller's flag only survives for
        anonymous requests (the one-shot degenerate case). Channel
        degradation folds in here too."""
        eff = self._effective_request(req)
        if req.device_id is not None and req.segment_cached:
            eff = dataclasses.replace(eff, segment_cached=False)
        return eff

    def _on_epoch(self, t: float) -> None:
        self._epochs.discard(t)
        pending, self._pending = self._pending, []
        # a device that went down between arrival and epoch parks here
        parked = []
        if self._down:
            keep = []
            for p in pending:
                dev = p.request.device_id
                if dev is not None and dev in self._down:
                    self._parked.setdefault(dev, []).append(p.index)
                    self._st.parked[p.index] += 1
                    parked.append(p.index)
                else:
                    keep.append(p)
            pending = keep
        if not pending:
            if parked and self._journal is not None:
                self._journal.record(t, EPOCH, admitted=[], parked=parked)
            return
        pricing = [self._pricing_request(p.request) for p in pending]
        tab = price_window(self.qs.models, self.servers[0].profile, pricing,
                           context=self.context, provider=self.provider,
                           cache=self._price_cache)
        ref = self.servers[0].profile
        t_server_rows = [self._tsrv(rows, ref) for rows in tab.rows]
        order = self.policy.order(pending, tab, t_server_rows)
        if self._journal is not None:
            admitted = [self._admit(t, pending[j], tab, j) for j in order]
            self._journal.record(t, EPOCH, admitted=admitted, parked=parked)
        else:
            for j in order:
                self._admit(t, pending[j], tab, j)

    # ------------------------------------------------------------------
    def _cached_candidates(self, req: InferenceRequest,
                           a_star: float) -> np.ndarray:
        if req.device_id is None:
            return np.zeros(0, dtype=int)
        held = self.caches.get(req.device_id, ())
        return np.array(sorted(p for (m, lv, p) in held
                               if m == req.model and lv == a_star),
                        dtype=int)

    def _candidate_rows(self, req: InferenceRequest, tab, j, a_star: float):
        """(base objective row, wire vector) with the device segment
        cache applied: a cached candidate drops the weight-shipment share
        of its wire term (Eq. 14 Z_w amortized to zero)."""
        row = tab.obj[j]
        wire = tab.wire[j]
        cached = self._cached_candidates(req, a_star)
        cached = cached[cached < len(wire)]
        if len(cached):
            ep = self.provider.wire_coeff(req.weights, req.device,
                                          req.channel)
            pb, px = tab.pb[j], tab.px[j]
            adj = np.zeros_like(row)
            adj[cached] = ep * (pb[cached] - px[cached])
            row = row - adj
            wire = wire.copy()
            wire[cached] = px[cached]
        return row, wire

    # -- per-run row-keyed caches (§12). Keys lean on the stable
    # CandidateRows identities the shared price-window cache guarantees
    # (the rows objects live in self._price_cache for the whole run, so
    # id() cannot be recycled). ------------------------------------------
    def _tsrv(self, rows, profile: ServerProfile) -> np.ndarray:
        """server_seconds(profile, o2, srv_bytes) — cached per
        (rows identity, profile)."""
        key = (id(rows), profile)
        vec = self._tsrv_cache.get(key)
        if vec is None:
            vec = self.provider.server_seconds(profile, rows.o2,
                                               rows.srv_bytes)
            self._tsrv_cache[key] = vec
        return vec

    def _tdev(self, rows, device) -> np.ndarray:
        """device_seconds(device, o1, dev_bytes) — cached per
        (rows identity, device)."""
        key = (id(rows), device)
        vec = self._tdev_cache.get(key)
        if vec is None:
            vec = self.provider.device_seconds(device, rows.o1,
                                               rows.dev_bytes)
            self._tdev_cache[key] = vec
        return vec

    def _correction(self, req: InferenceRequest, profile: ServerProfile,
                    rows) -> np.ndarray:
        """server_correction(weights, ref, profile, rows) — cached per
        (rows identity, weights, profile); the reference profile is
        fixed for the run."""
        key = (id(rows), req.weights, profile)
        vec = self._corr_cache.get(key)
        if vec is None:
            vec = self.provider.server_correction(
                req.weights, self.servers[0].profile, profile, rows)
            self._corr_cache[key] = vec
        return vec

    def _server_order(self) -> list:
        """least_loaded's server ordering, hoisted: backlogs only change
        at commit/release/decode-round, so the sort is computed once per
        backlog change instead of once per pending request."""
        order = self._order_cache
        if order is None:
            order = sorted(range(len(self.servers)),
                           key=lambda s: (self.servers[s].work_until, s))
            self._order_cache = order
        return order

    def _finish_vec(self, req: InferenceRequest, t: float, rows, wire_vec,
                    px_row, srv: ServerState) -> np.ndarray:
        """Estimated wall-clock completion per candidate on ``srv`` under
        the reservation semantics (exact: reservations never move). Stage
        durations come from the provider, so a calibrated/roofline
        provider's SLO admission sees its own clock."""
        r_cap = req.channel.capacity()
        ship = np.maximum(wire_vec - px_row, 0.0)
        o2 = rows.o2
        ready = (t + ship / r_cap
                 + self.provider.device_seconds(req.device, rows.o1,
                                                rows.dev_bytes)
                 + px_row / r_cap)
        start = np.where(o2 > 0, np.maximum(ready, srv.free), ready)
        return start + self.provider.server_seconds(srv.profile, o2,
                                                    rows.srv_bytes)

    def _ready_vec(self, req: InferenceRequest, t: float, rows, wire_vec,
                   px_row) -> np.ndarray:
        """The server-independent prefix of ``_finish_vec`` (uplink +
        device segment + cut-activation transfer), computed once per
        admission instead of once per server — same accumulation order,
        so the floats are identical."""
        r_cap = req.channel.capacity()
        ship = np.maximum(wire_vec - px_row, 0.0)
        return (t + ship / r_cap
                + self._tdev(rows, req.device)
                + px_row / r_cap)

    # ------------------------------------------------------------------
    def _choose_vectorized(self, t: float, req: InferenceRequest,
                           arrival: float, tab, j: int, a_star: float,
                           enforce_slo: bool):
        """Best (server, candidate) under the policy's server rule as ONE
        masked (servers × candidates) argmin; None when ``enforce_slo``
        and no pair meets the deadline. Decision-for-decision identical
        to ``_choose_reference`` (locked in tests/test_fleet_scale.py):
        row construction preserves the scalar path's float-association
        order, and the flattened row-major argmin reproduces its
        tie-break (first server, then first candidate, strict <)."""
        row0, wire_vec = self._candidate_rows(req, tab, j, a_star)
        rows = tab.rows[j]
        uses_server = rows.o2 > 0
        servers = self.servers
        ref = servers[0].profile
        omega = req.weights.omega
        if self.policy.server_rule == "least_loaded":
            # load order; under an SLO the later servers are the
            # fallback, so a request is only rejected when EVERY
            # (server, candidate) pair misses the deadline
            order = self._server_order()
            if not enforce_slo:
                order = order[:1]
            ready = self._ready_vec(req, t, rows, wire_vec, tab.px[j]) \
                if enforce_slo else None
            for s in order:
                srv = servers[s]
                row = row0 if srv.profile is ref \
                    else row0 + self._correction(req, srv.profile, rows)
                queue = max(0.0, srv.work_until - t)
                row = row + omega * queue * uses_server
                if enforce_slo:
                    start = np.where(uses_server,
                                     np.maximum(ready, srv.free), ready)
                    finish = start + self._tsrv(rows, srv.profile)
                    row = np.where(
                        finish <= arrival + req.deadline + 1e-12,
                        row, np.inf)
                    if not np.isfinite(row).any():
                        continue
                c = int(np.argmin(row))
                # first feasible server in load order wins outright
                return (float(row[c]), s, c, queue, wire_vec)
            return None
        S, C = len(servers), len(row0)
        queues = np.fromiter((srv.work_until for srv in servers),
                             np.float64, S)
        np.subtract(queues, t, out=queues)
        np.maximum(queues, 0.0, out=queues)
        qterm = (omega * queues)[:, None] * uses_server
        if self._homogeneous:
            # every row is the reference row: one broadcast add computes
            # row0 + qterm[s] per element — bitwise what the scalar loop
            # produced (it never added a correction either; row0 + 0.0
            # would NOT be a no-op when row0 holds -0.0)
            mat = row0[None, :] + qterm
        else:
            base = np.repeat(row0[None, :], S, axis=0)
            ck = (id(rows), req.weights)
            corr = self._corr_stacks.get(ck)
            if corr is None:
                corr = np.stack(
                    [self._correction(req, servers[s].profile, rows)
                     for s in self._nonref_idx])
                self._corr_stacks[ck] = corr
            # in-place add keeps the scalar association (row0 + corr)
            # before the queue term lands
            base[self._nonref_idx] += corr
            mat = base + qterm
        if enforce_slo:
            ready = self._ready_vec(req, t, rows, wire_vec, tab.px[j])
            free = np.fromiter((srv.free for srv in servers),
                               np.float64, S)
            start = np.where(uses_server[None, :],
                             np.maximum(ready[None, :], free[:, None]),
                             ready[None, :])
            tsrv = self._tsrv_stacks.get(id(rows))
            if tsrv is None:
                tsrv = np.stack([self._tsrv(rows, srv.profile)
                                 for srv in servers])
                self._tsrv_stacks[id(rows)] = tsrv
            finish = start + tsrv
            mat = np.where(finish <= arrival + req.deadline + 1e-12,
                           mat, np.inf)
            if not np.isfinite(mat).any():
                return None
        k = int(np.argmin(mat))
        s, c = divmod(k, C)
        return (float(mat[s, c]), s, c, float(queues[s]), wire_vec)

    def _choose_reference(self, t: float, req: InferenceRequest,
                          arrival: float, tab, j: int, a_star: float,
                          enforce_slo: bool):
        """The historical per-server scalar loop — the equivalence
        oracle ``admission="reference"`` selects; kept verbatim."""
        row0, wire_vec = self._candidate_rows(req, tab, j, a_star)
        rows = tab.rows[j]
        o2_vec = rows.o2
        uses_server = o2_vec > 0
        ref = self.servers[0].profile
        least_loaded = self.policy.server_rule == "least_loaded"
        if least_loaded:
            order = sorted(range(len(self.servers)),
                           key=lambda s: (self.servers[s].work_until, s))
            if not enforce_slo:
                order = order[:1]
        else:
            order = range(len(self.servers))
        best = None
        for s in order:
            srv = self.servers[s]
            row = row0
            if srv.profile is not ref:
                row = row + self.provider.server_correction(
                    req.weights, ref, srv.profile, rows)
            queue = max(0.0, srv.work_until - t)
            row = row + req.weights.omega * queue * uses_server
            if enforce_slo:
                finish = self._finish_vec(req, t, rows, wire_vec,
                                          tab.px[j], srv)
                row = np.where(finish <= arrival + req.deadline + 1e-12,
                               row, np.inf)
                if not np.isfinite(row).any():
                    continue
            c = int(np.argmin(row))
            if least_loaded:
                # first feasible server in load order wins outright
                return (row[c], s, c, queue, wire_vec)
            if best is None or row[c] < best[0]:
                best = (row[c], s, c, queue, wire_vec)
        return best

    def _reprice_single(self, req: InferenceRequest, level: float):
        """One-row window at a relaxed accuracy level — the degrade
        ladder's re-pricing step (SLO degrade and retry degrade share
        it). ``req`` must be the ORIGINAL request: ``_pricing_request``
        applies the degraded channel itself (applying it to an already
        effective request would compound the factor).

        Tables are memoized per (model, level, batch, device, effective
        channel, weights, effective cached flag) — everything the table
        depends on — so ladders walk cached rows instead of calling
        ``price_window`` once per rung per request. ``reprice_cache=
        False`` disables the memo (the oracle the cache is locked
        against in tests/test_fleet.py)."""
        if self._reprice_enabled:
            eff_cached = req.segment_cached if req.device_id is None \
                else False
            key = (req.model, level, req.batch, req.device,
                   self._effective_channel(req), req.weights, eff_cached)
            tab = self._reprice_tables.get(key)
            if tab is None:
                relaxed = dataclasses.replace(self._pricing_request(req),
                                              accuracy_budget=level)
                tab = price_window(self.qs.models, self.servers[0].profile,
                                   [relaxed], context=self.context,
                                   provider=self.provider,
                                   cache=self._price_cache)
                self._reprice_tables[key] = tab
            return tab
        relaxed = dataclasses.replace(self._pricing_request(req),
                                      accuracy_budget=level)
        return price_window(self.qs.models, self.servers[0].profile,
                            [relaxed], context=self.context,
                            provider=self.provider,
                            cache=self._price_cache)

    # ------------------------------------------------------------------
    def _admit(self, t: float, pnd: _Pending, tab, j: int) -> list:
        """Admit (or drop) one pending request; returns the journal's
        ``[index, server]`` outcome pair (server -1 = dropped)."""
        st = self._st
        req = self._effective_request(pnd.request)
        store = self._stores.get(req.model)
        if store is None:
            store = self.qs.models[req.model].store(self.context)
            self._stores[req.model] = store
        a_star = store.level_for(req.accuracy_budget)
        attempt = int(st.attempts[pnd.index]) + 1
        degraded = None
        if attempt > 1 and self.retry.degrade_on_retry:
            # retry-with-degraded-budget: coarsen one store level per
            # retry (same ladder SLO degrade walks), floor at coarsest
            ladder = sorted(store.levels)
            k = min(ladder.index(a_star) + attempt - 1, len(ladder) - 1)
            if ladder[k] != a_star:
                a_star = ladder[k]
                tab, j = self._reprice_single(pnd.request, a_star), 0
                degraded = a_star
        enforce = req.deadline is not None and self.slo != "observe"
        choice = self._choose(t, req, pnd.arrival, tab, j, a_star, enforce)
        if choice is None and self.slo == "degrade":
            for lv in sorted(store.levels):
                if lv <= a_star:
                    continue
                tab_lv = self._reprice_single(pnd.request, lv)
                choice = self._choose(t, req, pnd.arrival, tab_lv, 0, lv,
                                      True)
                if choice is not None:
                    degraded, tab, j, a_star = lv, tab_lv, 0, lv
                    break
        if choice is None:
            st.rejected[pnd.index] = True
            st.drop_code[pnd.index] = DROP_CODES[REASON_SLO]
            # attempts stays attempt - 1: the reject consumed none
            return [pnd.index, -1]
        _, s, c, queue, wire_vec = choice
        self._commit(t, pnd, tab, j, s, c, queue, float(wire_vec[c]),
                     a_star, degraded, attempt, req)
        return [pnd.index, s]

    def _commit(self, t: float, pnd: _Pending, tab, j: int, s: int, c: int,
                queue: float, wire: float, a_star: float,
                degraded: Optional[float], attempt: int,
                req: InferenceRequest) -> None:
        st = self._st
        srv = self.servers[s]
        plan, o1, o2, _ = tab.select(j, c)
        dev_b, srv_b = tab.rows[j].bytes_at(c)
        backend = self.qs.models[req.model].backend
        if st.full:
            costs = self.provider.breakdown(o1, o2, wire, req.device,
                                            srv.profile, req.channel,
                                            dev_bytes=dev_b, srv_bytes=srv_b)
            res = ServingResult(plan=plan, costs=costs,
                                objective=costs.objective(req.weights)
                                + req.weights.omega
                                * (queue if o2 > 0 else 0.0),
                                payload_bits=wire, attempt=attempt)
            res.extra["queue_delay"] = queue if o2 > 0 else 0.0
            res.extra["server"] = s
            if degraded is not None:
                res.extra["degraded_to"] = degraded
            st.deployments[pnd.index] = Deployment(req.model, backend, req,
                                                   plan, res)
            t_local, t_server = costs.t_local, costs.t_server
        else:
            # light records: no Deployment/ServingResult objects. The
            # provider's stage clocks ARE breakdown's t_local/t_server
            # (base breakdown delegates to them; AnalyticCost's is the
            # same closed form) — locked in tests/test_fleet_scale.py
            t_local = float(self.provider.device_seconds(req.device, o1,
                                                         dev_b))
            t_server = float(self.provider.server_seconds(srv.profile, o2,
                                                          srv_b))

        # stage timeline (events.py): ship → device segment → transfer →
        # server segment, reserved FIFO on the chosen server
        r_cap = req.channel.capacity()
        ship = max(wire - plan.payload_x_bits, 0.0)
        x_share = wire - ship
        ship_done = t + ship / r_cap
        # the executed device stage is the provider's t_local — identical
        # to o1·gamma/f under the analytic default, memory-/measurement-
        # aware under the roofline/calibrated providers
        device_done = ship_done + t_local
        transfer_done = device_done + x_share / r_cap
        token = (pnd.index, attempt)
        # chunked prefill (DESIGN.md §14): the server prefill lands as
        # n PREFILL_CHUNK rounds on the decode lane's busy timeline
        # instead of one monolithic reservation, so live decode rounds
        # and later admissions interleave between chunks
        n_chunks = 0
        if self.prefill_chunk_tokens is not None and o2 > 0 \
                and t_server > 0.0:
            seq = int(getattr(backend, "seq_len", 0) or 0)
            if seq > self.prefill_chunk_tokens:
                n_chunks = len(_chunk_bounds(seq,
                                             self.prefill_chunk_tokens))
        if n_chunks >= 2:
            # provisional timeline — the last chunk overwrites
            # server_start/finish with the executed lane times
            server_start = transfer_done
            finish = transfer_done + t_server
        elif o2 > 0:
            server_start = max(srv.free, transfer_done)
            finish = server_start + t_server
            srv.free = finish
            srv.reservations[token] = finish
        else:
            server_start = transfer_done
            finish = server_start
        if n_chunks >= 2:
            pass      # chunk rounds accrue work_until/busy as they fire
        else:
            srv.work_until = max(srv.work_until, t) + t_server
            srv.busy += t_server
            self._order_cache = None
        tl = StageTimeline(t, ship_done, device_done, transfer_done,
                           server_start, finish)

        i = pnd.index
        st.tl[i, 0] = t
        st.tl[i, 1] = ship_done
        st.tl[i, 2] = device_done
        st.tl[i, 3] = transfer_done
        st.tl[i, 4] = server_start
        st.tl[i, 5] = finish
        st.server[i] = s
        st.start_order[i] = self._admit_rank
        st.backlog[i] = queue
        st.queue_delay[i] = queue if o2 > 0 else 0.0
        st.degraded_to[i] = np.nan if degraded is None else degraded
        st.attempts[i] = attempt
        st.payload_bits[i] = wire
        self._admit_rank += 1
        self._live.add(token)
        # a chunked flight's server work accrues chunk by chunk at fire
        # time, so severance has nothing to refund (t_server = 0)
        self._inflight[i] = _Flight(token, req.device_id, s,
                                    0.0 if n_chunks >= 2 else t_server, tl)

        if (req.device_id is not None and plan.p and ship > 0):
            self._queue.push(ship_done, CACHE_INSTALL,
                             (req.device_id,
                              (req.model, a_star, plan.p), token))
        self._in_flight += 1
        self._sample(t)
        # decode streams (DESIGN.md §11): the prefill's finish is token 1
        # (TTFT); the remaining tokens run through the server's
        # continuous-batching lane and COMPLETE moves to the last round
        n_tok = int(req.max_new_tokens)
        if n_tok > 0:
            if not getattr(backend, "supports_decode", False):
                raise ServingError(
                    f"request {i} asks for {n_tok} decode tokens "
                    f"but backend {type(backend).__name__!r} of model "
                    f"{req.model!r} has no autoregressive decode path")
            st.decode_tokens[i] = n_tok
            st.tokens_emitted[i] = 1
        if n_chunks >= 2:
            # stream start / COMPLETE move to the LAST chunk's end — the
            # device computes + uplinks chunks back-to-back, so chunk j
            # can land no earlier than its share of the device+transfer
            # pipeline (the last arrival IS the analytic transfer_done)
            if n_tok > 1:
                st.decode_done[i] = np.nan
            per = (t_local + x_share / r_cap) / n_chunks
            self._chunk_state[i] = {
                "token": token, "req": req, "plan": plan,
                "a_star": a_star, "s": s, "n_tok": n_tok,
                "n": n_chunks, "dt_c": t_server / n_chunks,
                "arrivals": [ship_done + (j + 1) * per
                             for j in range(n_chunks)],
                "started": None}
            self._queue.push(self._chunk_state[i]["arrivals"][0],
                             PREFILL_CHUNK, (i, token, 0))
        elif n_tok > 1:
            st.decode_done[i] = np.nan
            self._start_stream(finish, i, req, plan, a_star, s, token,
                               n_tok)
        else:
            if n_tok == 1:
                st.decode_done[i] = finish
            self._queue.push(finish, COMPLETE, (i, token))
