"""Replayable event journal of a fleet-engine run (DESIGN.md §10).

``FleetEngine.run`` appends one ``JournalEntry`` per event it PROCESSES
— in processing order, with the outcome facts the handler decided
(admitted indices, stale-completion flags, whether a cache install
applied) — plus a header naming the engine configuration. Because the
engine is a deterministic DES, the journal is a total account of a run:

  * ``replay(qs, requests)`` re-executes the run from scratch — the
    fault schedule is reconstructed FROM the journal's fault entries and
    the engine config from its header — and returns the fresh metrics;
    ``verify_replay`` additionally asserts the replayed journal is
    entry-for-entry identical (the determinism check the chaos tests
    lean on).
  * ``to_jsonl``/``from_jsonl`` give the journal a stable on-disk form
    (one JSON object per line, header first) for offline debugging of a
    faulted run.

The journal records event *processing*, not queue pushes: a cancelled
attempt's COMPLETE still pops and is journaled as ``stale`` — replay
must reproduce even the non-events.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

from repro.serving.engine.events import KIND_NAMES
from repro.serving.engine.faults import FaultEvent, FaultInjector


@dataclasses.dataclass(frozen=True)
class JournalEntry:
    """One processed event: (seq, time, kind, outcome data)."""
    seq: int
    time: float
    kind: str                      # KIND_NAMES value
    data: tuple                    # sorted (key, value) outcome facts

    def to_dict(self) -> dict:
        return {"seq": self.seq, "time": self.time, "kind": self.kind,
                **dict(self.data)}


class EventJournal:
    """Ordered record of every event a ``FleetEngine.run`` processed."""

    def __init__(self, header: Optional[dict] = None):
        self.header: dict = dict(header or {})
        self.entries: List[JournalEntry] = []

    # -- recording (engine-side) ---------------------------------------
    def record(self, time: float, kind: int, **data) -> None:
        self.entries.append(JournalEntry(
            len(self.entries), float(time), KIND_NAMES[kind],
            tuple(sorted(data.items()))))

    def __len__(self) -> int:
        return len(self.entries)

    def __eq__(self, other) -> bool:
        return (isinstance(other, EventJournal)
                and self.header == other.header
                and self.entries == other.entries)

    def diff(self, other: "EventJournal") -> Optional[str]:
        """First divergence between two journals, human-readable; None
        when identical."""
        if self.header != other.header:
            return f"headers differ: {self.header} != {other.header}"
        for a, b in zip(self.entries, other.entries):
            if a != b:
                return f"entry {a.seq}: {a.to_dict()} != {b.to_dict()}"
        if len(self.entries) != len(other.entries):
            return (f"lengths differ: {len(self.entries)} != "
                    f"{len(other.entries)}")
        return None

    # -- fault-schedule reconstruction ---------------------------------
    def fault_trace(self) -> List[FaultEvent]:
        """The run's fault schedule, reconstructed from the journaled
        FAULT entries (what ``replay`` injects)."""
        out = []
        for e in self.entries:
            if e.kind == "fault":
                d = dict(e.data)
                out.append(FaultEvent(e.time, d["fault"], d["device"],
                                      float(d.get("factor", 1.0))))
        return out

    # -- replay --------------------------------------------------------
    def replay(self, qs, requests, servers=None, provider=None):
        """Re-execute the journaled run: fresh engine, same config (from
        the header), same requests, fault schedule reconstructed from
        the journal. Returns the replayed ``FleetMetrics`` (carrying its
        own journal)."""
        from repro.serving.engine.fleet import FleetEngine
        from repro.serving.engine.retry import RetryPolicy
        h = self.header
        retry = RetryPolicy(**h["retry"]) if h.get("retry") else None
        eng = FleetEngine(qs, servers=servers, policy=h.get("policy", "fcfs"),
                          slo=h.get("slo", "observe"),
                          epoch_interval=h.get("epoch_interval", 0.0),
                          provider=provider,
                          retry=retry,
                          faults=FaultInjector(self.fault_trace()))
        return eng.run(requests)

    def verify_replay(self, qs, requests, servers=None, provider=None):
        """Replay and assert the journals match entry-for-entry; returns
        the replayed metrics. Raises ``AssertionError`` naming the first
        divergence — the determinism contract of DESIGN.md §10."""
        metrics = self.replay(qs, requests, servers=servers,
                              provider=provider)
        delta = self.diff(metrics.journal)
        assert delta is None, f"journal replay diverged: {delta}"
        return metrics

    # -- serialization -------------------------------------------------
    def to_jsonl(self) -> str:
        lines = [json.dumps({"header": self.header}, sort_keys=True)]
        lines += [json.dumps(e.to_dict(), sort_keys=True)
                  for e in self.entries]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "EventJournal":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        jr = cls(json.loads(lines[0])["header"])
        for ln in lines[1:]:
            d = json.loads(ln)
            seq, time, kind = d.pop("seq"), d.pop("time"), d.pop("kind")
            jr.entries.append(JournalEntry(seq, time, kind,
                                           tuple(sorted(d.items()))))
        return jr
