"""Replayable event journal of a fleet-engine run (DESIGN.md §10).

``FleetEngine.run`` appends one ``JournalEntry`` per event it PROCESSES
— in processing order, with the outcome facts the handler decided
(admitted indices, stale-completion flags, whether a cache install
applied) — plus a header naming the engine configuration. Because the
engine is a deterministic DES, the journal is a total account of a run:

  * ``replay(qs, requests)`` re-executes the run from scratch — the
    fault schedule is reconstructed FROM the journal's fault entries and
    the engine config from its header — and returns the fresh metrics;
    ``verify_replay`` additionally asserts the replayed journal is
    entry-for-entry identical (the determinism check the chaos tests
    lean on).
  * ``to_jsonl``/``from_jsonl`` give the journal a stable on-disk form
    (one JSON object per line, header first) for offline debugging of a
    faulted run.

The journal records event *processing*, not queue pushes: a cancelled
attempt's COMPLETE still pops and is journaled as ``stale`` — replay
must reproduce even the non-events.

Journaling modes (``FleetEngine(journal=...)``, DESIGN.md §12): "full"
is this class — one entry with outcome facts per processed event, the
only mode ``replay``/``verify_replay`` work from. "light" is
``LightJournal`` — a columnar (time, kind) tape with per-kind counts
and none of the outcome kwargs, for cheap observability at scale.
"off" journals nothing: the engine holds no journal object at all, so
the per-event cost is one ``is not None`` test (a true no-op — locked
by a hypothesis property that terminal records are unchanged).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

import numpy as np

from repro.serving.engine.events import KIND_NAMES
from repro.serving.engine.faults import FaultEvent, FaultInjector

JOURNAL_MODES = ("full", "light", "off")


@dataclasses.dataclass(frozen=True)
class JournalEntry:
    """One processed event: (seq, time, kind, outcome data)."""
    seq: int
    time: float
    kind: str                      # KIND_NAMES value
    data: tuple                    # sorted (key, value) outcome facts

    def to_dict(self) -> dict:
        return {"seq": self.seq, "time": self.time, "kind": self.kind,
                **dict(self.data)}


class EventJournal:
    """Ordered record of every event a ``FleetEngine.run`` processed."""

    def __init__(self, header: Optional[dict] = None):
        self.header: dict = dict(header or {})
        self.entries: List[JournalEntry] = []

    # -- recording (engine-side) ---------------------------------------
    def record(self, time: float, kind: int, **data) -> None:
        self.entries.append(JournalEntry(
            len(self.entries), float(time), KIND_NAMES[kind],
            tuple(sorted(data.items()))))

    def __len__(self) -> int:
        return len(self.entries)

    def __eq__(self, other) -> bool:
        return (isinstance(other, EventJournal)
                and self.header == other.header
                and self.entries == other.entries)

    def diff(self, other: "EventJournal") -> Optional[str]:
        """First divergence between two journals, human-readable; None
        when identical."""
        if self.header != other.header:
            return f"headers differ: {self.header} != {other.header}"
        for a, b in zip(self.entries, other.entries):
            if a != b:
                return f"entry {a.seq}: {a.to_dict()} != {b.to_dict()}"
        if len(self.entries) != len(other.entries):
            return (f"lengths differ: {len(self.entries)} != "
                    f"{len(other.entries)}")
        return None

    # -- fault-schedule reconstruction ---------------------------------
    def fault_trace(self) -> List[FaultEvent]:
        """The run's fault schedule, reconstructed from the journaled
        FAULT entries (what ``replay`` injects)."""
        out = []
        for e in self.entries:
            if e.kind == "fault":
                d = dict(e.data)
                out.append(FaultEvent(e.time, d["fault"], d["device"],
                                      float(d.get("factor", 1.0))))
        return out

    # -- replay --------------------------------------------------------
    def replay(self, qs, requests, servers=None, provider=None):
        """Re-execute the journaled run: fresh engine, same config (from
        the header), same requests, fault schedule reconstructed from
        the journal. Returns the replayed ``FleetMetrics`` (carrying its
        own journal)."""
        from repro.serving.engine.fleet import FleetEngine
        from repro.serving.engine.retry import RetryPolicy
        h = self.header
        retry = RetryPolicy(**h["retry"]) if h.get("retry") else None
        eng = FleetEngine(qs, servers=servers, policy=h.get("policy", "fcfs"),
                          slo=h.get("slo", "observe"),
                          epoch_interval=h.get("epoch_interval", 0.0),
                          provider=provider,
                          retry=retry,
                          faults=FaultInjector(self.fault_trace()),
                          # serving-shape knobs (DESIGN.md §14): absent
                          # from zero-knob headers, so their defaults —
                          # and the header the replayed engine builds —
                          # stay bit-identical to PR 9's
                          draft_tokens=h.get("draft_tokens", 0),
                          accept_rate=h.get("accept_rate"),
                          prefill_chunk_tokens=h.get("prefill_chunk_tokens"))
        return eng.run(requests)

    def verify_replay(self, qs, requests, servers=None, provider=None):
        """Replay and assert the journals match entry-for-entry; returns
        the replayed metrics. Raises ``AssertionError`` naming the first
        divergence — the determinism contract of DESIGN.md §10."""
        metrics = self.replay(qs, requests, servers=servers,
                              provider=provider)
        delta = self.diff(metrics.journal)
        assert delta is None, f"journal replay diverged: {delta}"
        return metrics

    # -- serialization -------------------------------------------------
    def to_jsonl(self) -> str:
        lines = [json.dumps({"header": self.header}, sort_keys=True)]
        lines += [json.dumps(e.to_dict(), sort_keys=True)
                  for e in self.entries]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "EventJournal":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        jr = cls(json.loads(lines[0])["header"])
        for ln in lines[1:]:
            d = json.loads(ln)
            seq, time, kind = d.pop("seq"), d.pop("time"), d.pop("kind")
            jr.entries.append(JournalEntry(seq, time, kind,
                                           tuple(sorted(d.items()))))
        return jr


class LightJournal:
    """Columnar journal: the (time, kind) tape of every processed event
    in two doubling NumPy buffers, outcome kwargs discarded at the call
    site. Same event COUNT and ORDER as the full journal on the same
    run (asserted in tests/test_fleet_scale.py), none of the per-entry
    tuple/dict cost — the scale-sweep observability tier."""

    def __init__(self, header: Optional[dict] = None, capacity: int = 1024):
        self.header: dict = dict(header or {})
        self._times = np.empty(max(int(capacity), 16), dtype=np.float64)
        self._kinds = np.empty(self._times.shape[0], dtype=np.int8)
        self._len = 0

    def record(self, time: float, kind: int, **data) -> None:
        i = self._len
        if i == self._times.shape[0]:
            self._times = np.concatenate(
                [self._times, np.empty_like(self._times)])
            self._kinds = np.concatenate(
                [self._kinds, np.empty_like(self._kinds)])
        self._times[i] = time
        self._kinds[i] = kind
        self._len = i + 1

    def __len__(self) -> int:
        return self._len

    @property
    def times(self) -> np.ndarray:
        return self._times[:self._len]

    @property
    def kinds(self) -> np.ndarray:
        return self._kinds[:self._len]

    def counts(self) -> dict:
        """Processed-event counts by kind name (only kinds that fired)."""
        kinds, counts = np.unique(self.kinds, return_counts=True)
        return {KIND_NAMES[int(k)]: int(c)
                for k, c in zip(kinds, counts)}
