"""Event taxonomy + queue of the fleet serving engine (DESIGN.md §8/§10).

The engine is a discrete-event simulator over a continuous clock. Seven
event kinds, processed in (time, kind, seq) order so simultaneous events
resolve deterministically:

  FAULT          — a ``FaultEvent`` (engine/faults.py) fires: device
                   disconnect/reconnect or channel degradation. First at
                   equal times, so an arrival / epoch / cache install at
                   the same instant already sees the new world.
  ARRIVAL        — a timestamped ``InferenceRequest`` enters the system
                   and joins the pending set.
  RETRY          — a fault-cancelled request's backoff expired; it
                   rejoins the pending set (engine/retry.py). Before
                   EPOCH at equal times so the epoch's window sees it.
  CACHE_INSTALL  — a model shipment finished downlinking: the device's
                   segment cache now holds (model, level, p). Ordered
                   before EPOCH at equal times so a repeat request
                   admitted at the same instant already sees the cache.
  EPOCH          — a decision epoch: every pending request is priced as
                   one ``price_window`` matrix and admitted under the
                   engine's ``AdmissionPolicy`` (policies.py).
  COMPLETE       — a request's last stage finished; bookkeeping only
                   (queue-depth sample, horizon). Carries the admission
                   token: a cancelled attempt's COMPLETE is stale and
                   skipped.
  DECODE_STEP    — a server's continuous-batching decode lane can start
                   its next round (serving/decode/batching.py): every
                   live stream whose next token input has arrived joins,
                   the round is priced once for the whole batch. Stale
                   events (the batcher state changed since queueing) are
                   detected by re-deriving the round time at fire time.

Admission computes the whole per-request stage timeline analytically
(``StageTimeline``): plan → uplink (model shipment) → device segment →
cut-activation transfer → server segment → complete. Servers reserve
work in admission order, so a timeline never changes after admission —
the ONLY thing that can undo a reservation is a fault cancelling the
attempt (the reservation is released, never moved; DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

FAULT = 0
ARRIVAL = 1
RETRY = 2
CACHE_INSTALL = 3
EPOCH = 4
COMPLETE = 5
DECODE_STEP = 6

KIND_NAMES = {FAULT: "fault", ARRIVAL: "arrival", RETRY: "retry",
              CACHE_INSTALL: "cache_install", EPOCH: "epoch",
              COMPLETE: "complete", DECODE_STEP: "decode_step"}


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    kind: int                     # ARRIVAL | CACHE_INSTALL | EPOCH | COMPLETE
    payload: object = None        # kind-specific (request index, cache key…)


class EventQueue:
    """Min-heap of events ordered by (time, kind, insertion seq)."""

    def __init__(self):
        self._heap = []
        self._seq = itertools.count()

    def push(self, ev: Event) -> None:
        heapq.heappush(self._heap, (ev.time, ev.kind, next(self._seq), ev))

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


@dataclasses.dataclass
class StageTimeline:
    """Wall-clock stage boundaries of one admitted request. Durations are
    priced by the same cost model as the objective (core.cost_model); the
    server stage starts when BOTH the cut activation has arrived and the
    server's previously reserved work has drained."""
    admit: float                  # decision-epoch time
    ship_done: float              # model shipment (weight bits) downlinked
    device_done: float            # device segment computed
    transfer_done: float          # cut activation uplinked
    server_start: float           # server segment starts (>= transfer_done)
    finish: float                 # server segment done — request complete

    @property
    def server_wait(self) -> float:
        """Actual seconds the cut activation sat in the server queue."""
        return self.server_start - self.transfer_done

    @property
    def stage_seconds(self) -> dict:
        """Per-stage durations — the timeline as the cost model priced
        it (provider stage times; CostModel v2 fidelity checks compare
        these against ``Deployment.execute``'s measured dict)."""
        return {"ship": self.ship_done - self.admit,
                "device": self.device_done - self.ship_done,
                "transfer": self.transfer_done - self.device_done,
                "server_wait": self.server_wait,
                "server": self.finish - self.server_start}

    def latency_from(self, arrival: float) -> float:
        return self.finish - arrival
