"""Event taxonomy + queue of the fleet serving engine (DESIGN.md §8/§10).

The engine is a discrete-event simulator over a continuous clock. Seven
event kinds, processed in (time, kind, seq) order so simultaneous events
resolve deterministically:

  FAULT          — a ``FaultEvent`` (engine/faults.py) fires: device
                   disconnect/reconnect or channel degradation. First at
                   equal times, so an arrival / epoch / cache install at
                   the same instant already sees the new world.
  ARRIVAL        — a timestamped ``InferenceRequest`` enters the system
                   and joins the pending set.
  RETRY          — a fault-cancelled request's backoff expired; it
                   rejoins the pending set (engine/retry.py). Before
                   EPOCH at equal times so the epoch's window sees it.
  CACHE_INSTALL  — a model shipment finished downlinking: the device's
                   segment cache now holds (model, level, p). Ordered
                   before EPOCH at equal times so a repeat request
                   admitted at the same instant already sees the cache.
  EPOCH          — a decision epoch: every pending request is priced as
                   one ``price_window`` matrix and admitted under the
                   engine's ``AdmissionPolicy`` (policies.py).
  COMPLETE       — a request's last stage finished; bookkeeping only
                   (queue-depth sample, horizon). Carries the admission
                   token: a cancelled attempt's COMPLETE is stale and
                   skipped.
  DECODE_STEP    — a server's continuous-batching decode lane can start
                   its next round (serving/decode/batching.py): every
                   live stream whose next token input has arrived joins,
                   the round is priced once for the whole batch. Stale
                   events (the batcher state changed since queueing) are
                   detected by re-deriving the round time at fire time.
                   With speculation on, one round verifies k drafts and
                   emits 1..k+1 tokens per stream (DESIGN.md §14).
  PREFILL_CHUNK  — one page-aligned chunk of an admitted stream's prompt
                   lands on the server's decode lane (DESIGN.md §14):
                   the chunk's server work shares the batcher's
                   ``busy_until`` timeline with decode rounds, so long
                   prompts interleave with live streams instead of
                   head-of-line-blocking them. The final chunk starts
                   the stream (TTFT).

Admission computes the whole per-request stage timeline analytically
(``StageTimeline``): plan → uplink (model shipment) → device segment →
cut-activation transfer → server segment → complete. Servers reserve
work in admission order, so a timeline never changes after admission —
the ONLY thing that can undo a reservation is a fault cancelling the
attempt (the reservation is released, never moved; DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

FAULT = 0
ARRIVAL = 1
RETRY = 2
CACHE_INSTALL = 3
EPOCH = 4
COMPLETE = 5
DECODE_STEP = 6
PREFILL_CHUNK = 7

KIND_NAMES = {FAULT: "fault", ARRIVAL: "arrival", RETRY: "retry",
              CACHE_INSTALL: "cache_install", EPOCH: "epoch",
              COMPLETE: "complete", DECODE_STEP: "decode_step",
              PREFILL_CHUNK: "prefill_chunk"}


@dataclasses.dataclass(frozen=True)
class Event:
    """Descriptive form of one event — kept for callers and tests that
    build events by name; the engine's hot loop moves plain
    ``(time, kind, payload)`` tuples through ``EventQueue`` instead (no
    per-event object at 10⁶ scale)."""
    time: float
    kind: int                     # ARRIVAL | CACHE_INSTALL | EPOCH | COMPLETE
    payload: object = None        # kind-specific (request index, cache key…)


class EventQueue:
    """Min-heap of bare ``(time, kind, seq, payload)`` tuples ordered by
    (time, kind, insertion seq) — same total order as the historical
    Event-object heap, minus the dataclass allocation per push."""

    __slots__ = ("_heap", "_seq")

    def __init__(self):
        self._heap = []
        self._seq = itertools.count()

    def push(self, time: float, kind: int, payload=None) -> None:
        heapq.heappush(self._heap, (time, kind, next(self._seq), payload))

    def push_event(self, ev: Event) -> None:
        self.push(ev.time, ev.kind, ev.payload)

    def pop(self) -> tuple:
        """-> (time, kind, payload) of the earliest event."""
        t, kind, _, payload = heapq.heappop(self._heap)
        return t, kind, payload

    def peek_key(self):
        """(time, kind) of the head event, or None when empty — what the
        engine's sorted-arrival cursor merges against."""
        if not self._heap:
            return None
        head = self._heap[0]
        return head[0], head[1]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class ArrivalStream:
    """Bulk-loaded arrival cursor: ONE stable argsort over the trace's
    arrival times replaces 10⁶ individual ``heappush``es. The engine
    merges the cursor against the heap lexicographically on
    (time, kind): an arrival fires strictly before any same-time heap
    event of a later kind, and after FAULT (kind 0) at the same instant
    — exactly the order the old all-in-one heap produced, because no
    ARRIVAL ever lived alongside another ARRIVAL in the heap (stable
    sort preserves trace order for ties, matching insertion seq)."""

    __slots__ = ("times", "order", "pos", "n")

    def __init__(self, times):
        t = np.asarray(times, dtype=np.float64)
        self.order = np.argsort(t, kind="stable")
        self.times = t[self.order]
        self.pos = 0
        self.n = int(t.shape[0])

    def __len__(self) -> int:
        return self.n - self.pos

    def pop(self) -> tuple:
        """-> (arrival time, trace index) of the next arrival."""
        i = self.pos
        self.pos = i + 1
        return float(self.times[i]), int(self.order[i])


@dataclasses.dataclass
class StageTimeline:
    """Wall-clock stage boundaries of one admitted request. Durations are
    priced by the same cost model as the objective (core.cost_model); the
    server stage starts when BOTH the cut activation has arrived and the
    server's previously reserved work has drained."""
    admit: float                  # decision-epoch time
    ship_done: float              # model shipment (weight bits) downlinked
    device_done: float            # device segment computed
    transfer_done: float          # cut activation uplinked
    server_start: float           # server segment starts (>= transfer_done)
    finish: float                 # server segment done — request complete

    @property
    def server_wait(self) -> float:
        """Actual seconds the cut activation sat in the server queue."""
        return self.server_start - self.transfer_done

    @property
    def stage_seconds(self) -> dict:
        """Per-stage durations — the timeline as the cost model priced
        it (provider stage times; CostModel v2 fidelity checks compare
        these against ``Deployment.execute``'s measured dict)."""
        return {"ship": self.ship_done - self.admit,
                "device": self.device_done - self.ship_done,
                "transfer": self.transfer_done - self.device_done,
                "server_wait": self.server_wait,
                "server": self.finish - self.server_start}

    def latency_from(self, arrival: float) -> float:
        return self.finish - arrival
