"""Arrival-trace generators beyond Poisson (DESIGN.md §10).

The fleet bench's Poisson trace (serving/testing.py) models memoryless
traffic; real edge fleets see BURSTS (flash crowds, synchronized
retries) and DIURNAL swings (day/night load). Two seeded generators
grow the realism, both returning plain arrival-time arrays plus a
``materialize`` helper that decorates them into full
``InferenceRequest`` traces with the same heterogeneous
device/channel/budget/deadline mixing the Poisson fixture uses:

  * ``mmpp_arrivals`` — a 2-state Markov-modulated Poisson process:
    the rate switches between a calm and a burst state with
    exponential dwell times. Burstiness stresses admission ordering
    and, under fault injection, piles retries onto already-congested
    epochs — the regime the chaos bench measures.
  * ``diurnal_arrivals`` — an inhomogeneous Poisson process with a
    sinusoidal rate profile, sampled by thinning (Lewis & Shedler):
    peak-hour load tests that the engine drains overnight what it
    queued at noon.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.cost_model import (Channel, DeviceProfile,
                                   ObjectiveWeights)
from repro.serving.errors import FaultConfigError
from repro.serving.simulator import InferenceRequest


def mmpp_arrivals(n: int, rates=(200.0, 1400.0),
                  mean_dwell=(0.5, 0.1), seed: int = 0) -> np.ndarray:
    """First ``n`` arrival times of a 2-state MMPP: Poisson at
    ``rates[s]`` while in state ``s``, states alternating with
    exponential ``mean_dwell[s]`` sojourns. State 0 is the calm state,
    state 1 the burst state."""
    if len(rates) != 2 or len(mean_dwell) != 2:
        raise FaultConfigError("mmpp takes exactly two (rate, dwell) states")
    if min(rates) <= 0 or min(mean_dwell) <= 0:
        raise FaultConfigError("mmpp rates and dwells must be > 0")
    rng = np.random.default_rng(seed)
    out = np.empty(n, np.float64)
    t, state, k = 0.0, 0, 0
    switch = float(rng.exponential(mean_dwell[0]))
    while k < n:
        t = t + float(rng.exponential(1.0 / rates[state]))
        while t >= switch:          # sojourn ended before this arrival:
            # re-draw the residual gap at the new state's rate
            # (memorylessness makes the residual another exponential)
            t = switch + float(rng.exponential(1.0 / rates[1 - state]))
            state = 1 - state
            switch = switch + float(rng.exponential(mean_dwell[state]))
        out[k] = t
        k += 1
    return out


def diurnal_arrivals(n: int, base_rate: float = 700.0,
                     amplitude: float = 0.8, period: float = 2.0,
                     seed: int = 0) -> np.ndarray:
    """First ``n`` arrivals of an inhomogeneous Poisson process with
    rate ``base_rate · (1 + amplitude·sin(2π t / period))``, sampled by
    thinning against the peak rate. ``period`` is the full day-night
    cycle in trace seconds (scaled down so tests/benches span cycles)."""
    if not 0 <= amplitude < 1:
        raise FaultConfigError(f"amplitude must be in [0, 1), got {amplitude}")
    if base_rate <= 0 or period <= 0:
        raise FaultConfigError("base_rate and period must be > 0")
    rng = np.random.default_rng(seed)
    lam_max = base_rate * (1.0 + amplitude)
    out = np.empty(n, np.float64)
    t, k = 0.0, 0
    while k < n:
        t = t + float(rng.exponential(1.0 / lam_max))
        rate = base_rate * (1.0 + amplitude * np.sin(2 * np.pi * t / period))
        if rng.uniform() * lam_max <= rate:
            out[k] = t
            k += 1
    return out


def materialize(model: str, arrivals: np.ndarray,
                devices: Sequence[DeviceProfile],
                channels: Sequence[Channel],
                weights: ObjectiveWeights,
                budgets: Sequence[float],
                deadlines: Optional[Sequence[float]] = None,
                batches: Sequence[int] = (1,),
                device_pool: int = 200, seed: int = 0) -> list:
    """Decorate raw arrival times into ``InferenceRequest``s with the
    same heterogeneous mixing as ``testing.poisson_trace``: per-request
    device/channel/budget/batch/deadline draws and a finite requester
    population (``device_pool`` distinct ``device_id``s) so segment
    caches — and fault injection, which targets device_ids — see repeat
    traffic."""
    rng = np.random.default_rng(seed)
    return [InferenceRequest(
        model, budgets[rng.integers(len(budgets))],
        devices[rng.integers(len(devices))],
        channels[rng.integers(len(channels))], weights,
        batch=int(batches[rng.integers(len(batches))]),
        arrival_time=float(t),
        deadline=float(deadlines[rng.integers(len(deadlines))])
        if deadlines else None,
        device_id=f"dev-{rng.integers(device_pool)}")
        for t in arrivals]
