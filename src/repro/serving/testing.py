"""Pricing-only serving fixtures shared by tests, benchmarks and
examples.

A QPART server can be exercised end-to-end through plan → deploy →
(fleet) without ever executing a model: the online path only reads the
offline store and the cost model. ``stub_calibration`` installs
synthetic noise constants (unit energies, flat rho, a linear Delta(a)
table) so ``build_store`` runs the REAL Alg. 1 solve on them — no
training, no probe forwards, params may be ``None``. This is the
single copy of the recipe `tests/test_fleet.py`,
`benchmarks/fleet_bench.py` and `examples/fleet_simulation.py` build
on (it started life in test_scheduler's mixed-model window).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.backends import ClassifierBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest


def stub_calibration(srv: QPARTServer, name: str, cfg,
                     device: DeviceProfile, channel: Channel,
                     weights: ObjectiveWeights) -> None:
    """Register classifier ``cfg`` under ``name`` with synthetic
    calibration constants and build its offline store for the given
    reference context."""
    x = np.zeros((4,) + tuple(np.atleast_1d(cfg.input_shape)), np.float32) \
        if hasattr(cfg, "input_shape") else np.zeros((4, 28, 28), np.float32)
    srv.register(name, ClassifierBackend(cfg, None), x,
                 np.zeros(4, np.int32))
    m = srv.models[name]
    L = cfg.num_layers
    m.s_w, m.s_x, m.rho = np.ones(L), np.ones(L), np.full(L, 0.1)
    m.delta_table = {a: a * 50 for a in srv.levels}
    srv.build_store(name, device, channel, weights)


def stub_transformer_calibration(srv: QPARTServer, name: str, cfg,
                                 device: DeviceProfile, channel: Channel,
                                 weights: ObjectiveWeights,
                                 seq_len: int = 32,
                                 decode_max_len: Optional[int] = None,
                                 kv_page_tokens: Optional[int] = None,
                                 ) -> None:
    """Register transformer ``cfg`` under ``name`` with synthetic
    calibration constants (params may stay ``None`` — pricing never
    touches them) and build its offline store. A non-None
    ``decode_max_len`` marks the backend decode-planned: KV-cache
    feasibility and the fleet decode lane activate; ``kv_page_tokens``
    additionally switches KV admission/residency to block-granular
    (page-rounded actual context instead of the max_len worst case)."""
    from repro.serving.backends import TransformerBackend
    srv.register(name, TransformerBackend(cfg, None, seq_len,
                                          decode_max_len=decode_max_len,
                                          kv_page_tokens=kv_page_tokens),
                 np.zeros((4, seq_len), np.int32), np.zeros(4, np.int32))
    m = srv.models[name]
    L = cfg.num_layers
    m.s_w, m.s_x, m.rho = np.ones(L), np.ones(L), np.full(L, 0.1)
    m.delta_table = {a: a * 50 for a in srv.levels}
    srv.build_store(name, device, channel, weights)


def stub_classifier_server(configs, server: Optional[ServerProfile] = None,
                           device: Optional[DeviceProfile] = None,
                           channel: Optional[Channel] = None,
                           weights: Optional[ObjectiveWeights] = None,
                           ) -> QPARTServer:
    """A ``QPARTServer`` with every ``(name, cfg)`` of ``configs``
    stub-calibrated against one shared reference context."""
    srv = QPARTServer(server)
    device = device or DeviceProfile()
    channel = channel or Channel(capacity_bps=2e6)
    weights = weights or ObjectiveWeights()
    for name, cfg in configs:
        stub_calibration(srv, name, cfg, device, channel, weights)
    return srv


def poisson_trace(model: str, n: int, rate: float,
                  devices: Sequence[DeviceProfile],
                  channels: Sequence[Channel],
                  weights: ObjectiveWeights,
                  budgets: Sequence[float],
                  deadlines: Sequence[float],
                  batches: Sequence[int] = (1,),
                  device_pool: int = 200, seed: int = 0,
                  ) -> list:
    """A Poisson-arrival request trace over heterogeneous devices,
    channels, budgets, batch sizes and SLOs, with a finite requester
    population (``device_pool`` distinct ``device_id``s) so the fleet
    engine's segment caches see repeat traffic."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [InferenceRequest(
        model, budgets[rng.integers(len(budgets))],
        devices[rng.integers(len(devices))],
        channels[rng.integers(len(channels))], weights,
        batch=int(batches[rng.integers(len(batches))]),
        arrival_time=float(arrivals[i]),
        deadline=float(deadlines[rng.integers(len(deadlines))]),
        device_id=f"dev-{rng.integers(device_pool)}") for i in range(n)]
