"""Continuous batching state for the fleet engine's decode lane.

Each fleet server owns one ``DecodeBatcher``: the set of live decode
streams whose tail segment it hosts. The engine advances the batcher in
ROUNDS — at each DECODE_STEP event every stream whose next token input
has arrived (``ready_at <= t``) joins the round, and the round's server
time is priced ONCE for the whole batch:

    round_s = provider.server_seconds(profile, sum_i o2_tok_i,
                                      max_i srv_bytes_tok_i)

MAC terms add across streams; the weight-stream byte term does NOT —
the tail weights are read once per round regardless of how many streams
share it (the continuous-batching amortization that makes the decode
lane scale). Streams that finish a round re-arm at ``round_end +
step_lag`` (their device-segment + wire round trip); new streams join
whenever their prefill pipeline delivers the first decode input.

``due``/``next_time`` are heap-backed (PR 9): entries are keyed on
``ready_at`` with lazy invalidation (a per-stream version stamp — a
re-arm or removal strands the old entry, skipped when it surfaces), so
both are O(log n) amortized instead of the linear scans that dominated
at 10^5-stream fleets. The OBSERVABLE semantics are locked by
``tests/test_decode.py``: ``due`` returns joiners in ADMISSION order
(what dict insertion order used to provide) and ``next_time`` is
``max(busy_until, min ready_at)`` over live streams.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class DecodeStream:
    """One live decode stream at a server's tail segment."""
    index: int                # FleetRecord index
    token: tuple              # (index, attempt) liveness token
    device_id: Optional[str]
    remaining: int            # tokens still to emit
    ready_at: float           # when the next step's input is at the server
    o2_tok: float             # server MACs per decode step
    srv_bytes_tok: float      # server tail bytes per decode step
    step_lag: float           # device step + wire seconds per round trip
    # speculative decode (DESIGN.md §14) — defaults keep the plain
    # one-token-per-round stream bit-for-bit
    draft_k: int = 0          # drafts verified per round (0 = plain)
    alpha: float = 0.0        # expected draft acceptance rate
    rounds_done: int = 0      # rounds this stream completed (the
                              # deterministic acceptance accumulator's j)


@dataclasses.dataclass
class DecodeBatcher:
    """Per-server continuous-batching state (engine-owned)."""
    streams: Dict[int, DecodeStream] = dataclasses.field(default_factory=dict)
    busy_until: float = 0.0          # current round's end time
    # heap of (ready_at, admission_seq, index, version); an entry is live
    # iff its index is registered AND its version matches the stream's
    # current stamp — re-arms/removals bump the stamp, stranding old
    # entries for lazy removal when they reach the top.
    _heap: List[Tuple[float, int, int, int]] = \
        dataclasses.field(default_factory=list)
    _seq: Dict[int, int] = dataclasses.field(default_factory=dict)
    _version: Dict[int, int] = dataclasses.field(default_factory=dict)
    _next_seq: int = 0

    def _push(self, index: int) -> None:
        heapq.heappush(self._heap, (self.streams[index].ready_at,
                                    self._seq[index], index,
                                    self._version[index]))

    def _live_entry(self, entry) -> bool:
        _, seq, index, version = entry
        return (index in self.streams and self._seq.get(index) == seq
                and self._version.get(index) == version)

    def add(self, stream: DecodeStream) -> None:
        if stream.index not in self._seq:
            # admission order survives re-arms; a removed-then-readmitted
            # stream re-enters at the back (dict-insertion semantics)
            self._seq[stream.index] = self._next_seq
            self._next_seq += 1
        self.streams[stream.index] = stream
        self._version[stream.index] = self._version.get(stream.index, 0) + 1
        self._push(stream.index)

    def remove(self, index: int) -> Optional[DecodeStream]:
        stream = self.streams.pop(index, None)
        if stream is not None:
            self._version[index] += 1         # strand heap entries
            self._seq.pop(index, None)
        return stream

    def rearm(self, index: int, ready_at: float) -> None:
        """Move stream ``index``'s next-step time (round finished: its
        device/wire round trip lands at ``ready_at``). O(log n)."""
        stream = self.streams.get(index)
        if stream is None:
            return
        stream.ready_at = float(ready_at)
        self._version[index] += 1
        self._push(index)

    def due(self, t: float) -> List[DecodeStream]:
        """Streams joining a round started at ``t``, in admission order
        (deterministic). Non-destructive: joiners stay armed until the
        engine re-arms or removes them."""
        popped = []
        while self._heap and self._heap[0][0] <= t:
            entry = heapq.heappop(self._heap)
            if self._live_entry(entry):
                popped.append(entry)
        for entry in popped:                  # still armed at ready_at
            heapq.heappush(self._heap, entry)
        return [self.streams[e[2]] for e in sorted(popped,
                                                   key=lambda e: e[1])]

    def next_time(self) -> Optional[float]:
        """Earliest time the next round can start: every state change
        (stream added/removed/re-armed, round finished) re-derives this
        and the engine queues a DECODE_STEP there; stale queued events
        are detected by re-deriving at fire time."""
        while self._heap:
            if not self._live_entry(self._heap[0]):
                heapq.heappop(self._heap)     # permanent lazy cleanup
                continue
            return max(self.busy_until, self._heap[0][0])
        return None
