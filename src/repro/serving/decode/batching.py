"""Continuous batching state for the fleet engine's decode lane.

Each fleet server owns one ``DecodeBatcher``: the set of live decode
streams whose tail segment it hosts. The engine advances the batcher in
ROUNDS — at each DECODE_STEP event every stream whose next token input
has arrived (``ready_at <= t``) joins the round, and the round's server
time is priced ONCE for the whole batch:

    round_s = provider.server_seconds(profile, sum_i o2_tok_i,
                                      max_i srv_bytes_tok_i)

MAC terms add across streams; the weight-stream byte term does NOT —
the tail weights are read once per round regardless of how many streams
share it (the continuous-batching amortization that makes the decode
lane scale). Streams that finish a round re-arm at ``round_end +
step_lag`` (their device-segment + wire round trip); new streams join
whenever their prefill pipeline delivers the first decode input.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class DecodeStream:
    """One live decode stream at a server's tail segment."""
    index: int                # FleetRecord index
    token: tuple              # (index, attempt) liveness token
    device_id: Optional[str]
    remaining: int            # tokens still to emit
    ready_at: float           # when the next step's input is at the server
    o2_tok: float             # server MACs per decode step
    srv_bytes_tok: float      # server tail bytes per decode step
    step_lag: float           # device step + wire seconds per round trip


@dataclasses.dataclass
class DecodeBatcher:
    """Per-server continuous-batching state (engine-owned)."""
    streams: Dict[int, DecodeStream] = dataclasses.field(default_factory=dict)
    busy_until: float = 0.0          # current round's end time

    def add(self, stream: DecodeStream) -> None:
        self.streams[stream.index] = stream

    def remove(self, index: int) -> Optional[DecodeStream]:
        return self.streams.pop(index, None)

    def due(self, t: float) -> List[DecodeStream]:
        """Streams joining a round started at ``t``, in admission
        order (dict order = insertion order — deterministic)."""
        return [st for st in self.streams.values() if st.ready_at <= t]

    def next_time(self) -> Optional[float]:
        """Earliest time the next round can start: every state change
        (stream added/removed, round finished) re-derives this and the
        engine queues a DECODE_STEP there; stale queued events are
        detected by re-deriving at fire time."""
        if not self.streams:
            return None
        return max(self.busy_until,
                   min(st.ready_at for st in self.streams.values()))
