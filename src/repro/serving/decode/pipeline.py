"""``DecodeSession`` — streaming greedy decode over the compile-once
segment programs, partitioned at the plan's cut point.

Prefill: the device embeds the prompt and runs its quantized segment
``[0, p)``, populating its own cache (stored at the deployed bit-width's
dtype, ``cache.kv_cache_dtype``); the cut hidden state crosses the
channel quantized at ``bits_x``; the server tail ``[p, L)`` fills its
full-precision cache and emits the first token (TTFT). Decode: each
step embeds the previous token on the device, advances the device
cache, ships ONE token's quantized hidden state, advances the server
cache and samples greedily. ``p == 0`` (full offload) runs entirely
server-side — the sampled token never has to cross the radio. ``p ==
L`` still unembeds server-side (the head weights stay with the server,
matching ``execute_plan``'s partition semantics).

Every session of every cut point reuses the SAME three jitted programs
(``TransformerBackend`` decode family): ``(start, stop, pos)`` are
dynamic operands and the cache tree is an operand, so ``trace_count``
is constant across cuts at a fixed (batch, prompt, max_len, dtype)
shape. Stage boundaries are wall-clock fenced (``block_until_ready``)
— the timings feed ``CalibrationLedger.record_decode``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import fake_quant
from repro.models import transformer as T
from repro.serving.decode.cache import (DEFAULT_PAGE_TOKENS, KVPagePool,
                                        PagedKVCache, kv_cache_dtype,
                                        segment_cache_bytes,
                                        segment_nonattn_cache_bytes,
                                        segment_page_pool)
from repro.serving.errors import ServingError


@dataclasses.dataclass
class GenerationResult:
    """One streamed generation. ``tokens`` (B, new_tokens) greedy ids;
    stage seconds are wall-clock, aggregated over the whole stream."""
    tokens: np.ndarray
    ttft_s: float                 # prefill → first token
    t_device_s: float             # device-segment seconds (incl. prefill)
    t_server_s: float             # server-tail seconds (incl. prefill)
    t_total_s: float
    per_token_s: List[float]      # decode-step seconds (len new_tokens-1)
    device_cache_bytes: int       # resident [0, p) cache footprint
    server_cache_bytes: int       # resident [p, L) cache footprint
    device_cache_dtype: str

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.shape[1])

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.t_total_s if self.t_total_s else 0.0


class DecodeSession:
    """One partitioned prefill→decode stream for a deployed plan.

    ``backend`` must support decode (``TransformerBackend``); ``segment``
    reuses an already-materialized quantized device segment (pass
    ``Deployment``'s). Prompts are token ids (B, S) — greedy text decode
    only; frontend archs (audio/vision) prefill from embeds and are not
    routed through sessions."""

    def __init__(self, backend, plan, *, max_len: int,
                 segment=None, qkernels: Optional[bool] = None,
                 paged: bool = False,
                 page_tokens: int = DEFAULT_PAGE_TOKENS,
                 page_pool: Optional[KVPagePool] = None):
        if not getattr(backend, "supports_decode", False):
            raise ServingError(
                f"{type(backend).__name__} has no autoregressive decode "
                "path — decode sessions need a transformer backend")
        self.backend = backend
        self.plan = plan
        self.max_len = int(max_len)
        cfg = backend.cfg
        self.cfg = cfg
        self.L = backend.num_layers
        self.p = int(plan.p)
        self.model_dtype = getattr(jnp, cfg.dtype)
        if qkernels is None:
            # default: quantized-kernel device weights only where the
            # compiled kernels actually run (TPU); the CPU default stays
            # the pre-kernel dense fake-quant path bit-for-bit.
            from repro.kernels import ops
            qkernels = ops.kernel_mode() == "kernel" and \
                hasattr(backend, "qstacked_for")
        self.qkernels = bool(qkernels)
        if self.p > 0:
            seg = segment if segment is not None else backend.split(plan)
            self.dev_params = (backend.qstacked_for(seg, plan)
                               if self.qkernels
                               else backend.stacked_for(seg, plan))
            self.bits_x = int(seg.bits_x)
            self.dev_dtype = kv_cache_dtype(self.bits_x, self.model_dtype)
        else:
            self.dev_params = None
            self.bits_x = 0
            self.dev_dtype = self.model_dtype
        self.dev_caches = None
        self.srv_caches = None
        # block-granular device-KV accounting (cache.PagedKVCache): the
        # jitted programs keep their dense cache operands; the paged
        # structure tracks the page-granular RESIDENT footprint and is
        # validated bit-for-bit against the dense ring.
        self.paged = bool(paged) and self.p > 0
        self.page_tokens = int(page_tokens)
        self.page_pool = page_pool
        self.paged_kv: Optional[PagedKVCache] = None
        self.pos = 0
        self.t_device_s = 0.0
        self.t_server_s = 0.0

    # -- pricing views ---------------------------------------------------
    def wire_bits_per_token(self, batch: int) -> float:
        """Uplink bits per decode step: the quantized cut hidden state
        plus the 32-bit sampled-token downlink; 0 for full offload (the
        stream never touches the radio after the prompt upload)."""
        if self.p == 0:
            return 0.0
        return float(self.bits_x * self.cfg.d_model * batch + 32 * batch)

    def device_cache_bytes(self) -> int:
        if self.dev_caches is None or self.p == 0:
            return 0
        if self.paged_kv is not None:
            # pages actually held + the dense non-attention remainder
            return self.paged_kv.resident_bytes + \
                segment_nonattn_cache_bytes(self.cfg, self.dev_caches, 0,
                                            self.p)
        return segment_cache_bytes(self.cfg, self.dev_caches, 0, self.p)

    def sever(self) -> int:
        """End the stream: return every held KV page to the pool (no-op
        for dense sessions). Returns the page count released."""
        if self.paged_kv is None:
            return 0
        return self.paged_kv.free_all()

    def server_cache_bytes(self) -> int:
        if self.srv_caches is None:
            return 0
        return segment_cache_bytes(self.cfg, self.srv_caches, self.p,
                                   self.L)

    # -- pipeline stages -------------------------------------------------
    def prefill(self, prompt):
        """Run the partitioned prefill; returns the first greedy token
        (B,) and records stage seconds (TTFT = their sum)."""
        prompt = jnp.asarray(prompt, jnp.int32)
        b, s = prompt.shape
        if s + 1 > self.max_len:
            raise ServingError(
                f"prompt ({s}) leaves no room in max_len={self.max_len}")
        t0 = time.perf_counter()
        if self.p > 0:
            h0 = self.backend.embed(prompt, params=self.dev_params)
            cache0 = T.init_cache(self.cfg, b, self.max_len,
                                  self.dev_dtype)
            h_dev, self.dev_caches = self.backend.prefill_segment(
                h0, cache0, 0, self.p, params=self.dev_params)
            h_in = fake_quant(h_dev, self.bits_x)
            jax.block_until_ready(h_in)
            if self.paged:
                if self.page_pool is None:
                    self.page_pool = segment_page_pool(
                        self.cfg, 0, self.p, b, self.max_len,
                        self.dev_dtype, page_tokens=self.page_tokens)
                self.paged_kv = PagedKVCache(self.page_pool, self.cfg, 0,
                                             self.p, b, self.max_len)
                self.paged_kv.ingest_prefill(self.dev_caches, s)
        t1 = time.perf_counter()
        if self.p == 0:
            h_in = self.backend.embed(prompt)
        cache0 = T.init_cache(self.cfg, b, self.max_len, self.model_dtype)
        h_srv, self.srv_caches = self.backend.prefill_segment(
            h_in, cache0, self.p, self.L)
        logits = self.backend.hidden_logits(h_srv[:, -1:, :])
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(token)
        t2 = time.perf_counter()
        self.t_device_s += t1 - t0
        self.t_server_s += t2 - t1
        self.pos = s
        return token

    def step(self, token):
        """One decode step feeding ``token`` (B,); returns the next
        greedy token (B,)."""
        if self.pos + 1 > self.max_len:
            raise ServingError(f"decode past max_len={self.max_len}")
        tok = jnp.asarray(token, jnp.int32).reshape(-1, 1)
        pos = jnp.asarray(self.pos, jnp.int32)
        t0 = time.perf_counter()
        if self.p > 0:
            x = self.backend.embed(tok, params=self.dev_params)
            x_dev, self.dev_caches = self.backend.decode_segment(
                x, self.dev_caches, pos, 0, self.p,
                params=self.dev_params)
            x_in = fake_quant(x_dev, self.bits_x)
            jax.block_until_ready(x_in)
            if self.paged_kv is not None:
                self.paged_kv.append_step(self.dev_caches, self.pos)
        t1 = time.perf_counter()
        if self.p == 0:
            x_in = self.backend.embed(tok)
        x_srv, self.srv_caches = self.backend.decode_segment(
            x_in, self.srv_caches, pos, self.p, self.L)
        logits = self.backend.hidden_logits(x_srv)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(nxt)
        t2 = time.perf_counter()
        self.t_device_s += t1 - t0
        self.t_server_s += t2 - t1
        self.pos += 1
        return nxt

    # -- drivers ----------------------------------------------------------
    def stream(self, prompt, max_new_tokens: int):
        """Generator of (step_index, token (B,) np.ndarray) — token 0 is
        the prefill's (TTFT); the session's stage clocks accumulate as
        the consumer drains it."""
        token = self.prefill(prompt)
        yield 0, np.asarray(token)
        for i in range(1, max_new_tokens):
            token = self.step(token)
            yield i, np.asarray(token)

    def generate(self, prompt, max_new_tokens: int,
                 stream_cb=None) -> GenerationResult:
        if max_new_tokens < 1:
            raise ServingError("max_new_tokens must be >= 1")
        toks: List[np.ndarray] = []
        per_token: List[float] = []
        t_start = time.perf_counter()
        ttft = None
        last = t_start
        for i, tok in self.stream(prompt, max_new_tokens):
            now = time.perf_counter()
            if i == 0:
                ttft = now - t_start
            else:
                per_token.append(now - last)
            last = now
            toks.append(tok)
            if stream_cb is not None:
                stream_cb(i, tok)
        total = time.perf_counter() - t_start
        return GenerationResult(
            tokens=np.stack(toks, axis=1),
            ttft_s=float(ttft),
            t_device_s=self.t_device_s,
            t_server_s=self.t_server_s,
            t_total_s=total,
            per_token_s=per_token,
            device_cache_bytes=self.device_cache_bytes(),
            server_cache_bytes=self.server_cache_bytes(),
            device_cache_dtype=np.dtype(self.dev_dtype).name)
