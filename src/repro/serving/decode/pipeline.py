"""``DecodeSession`` — streaming greedy decode over the compile-once
segment programs, partitioned at the plan's cut point.

Prefill: the device embeds the prompt and runs its quantized segment
``[0, p)``, populating its own cache (stored at the deployed bit-width's
dtype, ``cache.kv_cache_dtype``); the cut hidden state crosses the
channel quantized at ``bits_x``; the server tail ``[p, L)`` fills its
full-precision cache and emits the first token (TTFT). Decode: each
step embeds the previous token on the device, advances the device
cache, ships ONE token's quantized hidden state, advances the server
cache and samples greedily. ``p == 0`` (full offload) runs entirely
server-side — the sampled token never has to cross the radio. ``p ==
L`` still unembeds server-side (the head weights stay with the server,
matching ``execute_plan``'s partition semantics).

Every session of every cut point reuses the SAME three jitted programs
(``TransformerBackend`` decode family): ``(start, stop, pos)`` are
dynamic operands and the cache tree is an operand, so ``trace_count``
is constant across cuts at a fixed (batch, prompt, max_len, dtype)
shape. Stage boundaries are wall-clock fenced (``block_until_ready``)
— the timings feed ``CalibrationLedger.record_decode``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN
from repro.core.quantizer import dequantize, quantize
from repro.models import transformer as T
from repro.serving.decode.cache import (DEFAULT_PAGE_TOKENS, KVPagePool,
                                        PagedKVCache, kv_cache_dtype,
                                        segment_cache_bytes,
                                        segment_nonattn_cache_bytes,
                                        segment_page_pool)
from repro.serving.errors import ServingError


@dataclasses.dataclass
class GenerationResult:
    """One streamed generation. ``tokens`` (B, new_tokens) greedy ids;
    stage seconds are wall-clock, aggregated over the whole stream.

    Per-round semantics: generation advances in server ROUNDS — the
    prefill round emits token 0, then each decode round emits one token
    (plain greedy) or 1..k+1 tokens (a speculative draft/verify round).
    ``per_token_s`` stays length-consistent at ``new_tokens - 1``
    regardless: a round that emitted ``m`` tokens contributes ``m``
    equal entries of ``round_seconds / m``, so summing any slice of it
    still measures wall-clock. ``rounds`` counts decode rounds (the
    prefill is not a round); with speculation on, ``rounds <
    new_tokens - 1`` is exactly the round-trip amortization."""
    tokens: np.ndarray
    ttft_s: float                 # prefill → first token
    t_device_s: float             # device-segment seconds (incl. prefill)
    t_server_s: float             # server-tail seconds (incl. prefill)
    t_total_s: float
    per_token_s: List[float]      # per-token seconds (len new_tokens-1)
    device_cache_bytes: int       # resident [0, p) cache footprint
    server_cache_bytes: int       # resident [p, L) cache footprint
    device_cache_dtype: str
    rounds: int = 0               # decode rounds after the prefill
    draft_tokens: int = 0         # configured draft length k (0 = off)
    drafts_proposed: int = 0
    drafts_accepted: int = 0
    prefill_chunks: int = 1       # 1 = monolithic prefill

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.shape[1])

    @property
    def tokens_per_s(self) -> float:
        """0.0 for a degenerate zero-duration window (clock granularity
        can collapse a tiny stream's wall time to 0)."""
        return self.new_tokens / self.t_total_s if self.t_total_s > 0 \
            else 0.0

    @property
    def accept_rate(self) -> Optional[float]:
        """Measured draft acceptance (accepted / proposed); None when no
        drafts were proposed (plain greedy or zero decode rounds)."""
        if self.drafts_proposed <= 0:
            return None
        return self.drafts_accepted / self.drafts_proposed


class DecodeSession:
    """One partitioned prefill→decode stream for a deployed plan.

    ``backend`` must support decode (``TransformerBackend``); ``segment``
    reuses an already-materialized quantized device segment (pass
    ``Deployment``'s). Prompts are token ids (B, S) — greedy text decode
    only; frontend archs (audio/vision) prefill from embeds and are not
    routed through sessions."""

    def __init__(self, backend, plan, *, max_len: int,
                 segment=None, qkernels: Optional[bool] = None,
                 paged: bool = False,
                 page_tokens: int = DEFAULT_PAGE_TOKENS,
                 page_pool: Optional[KVPagePool] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 draft_tokens: int = 0):
        if not getattr(backend, "supports_decode", False):
            raise ServingError(
                f"{type(backend).__name__} has no autoregressive decode "
                "path — decode sessions need a transformer backend")
        self.backend = backend
        self.plan = plan
        self.max_len = int(max_len)
        cfg = backend.cfg
        self.cfg = cfg
        self.L = backend.num_layers
        self.p = int(plan.p)
        self.model_dtype = getattr(jnp, cfg.dtype)
        if qkernels is None:
            # default: quantized-kernel device weights only where the
            # compiled kernels actually run (TPU); the CPU default stays
            # the pre-kernel dense fake-quant path bit-for-bit.
            from repro.kernels import ops
            qkernels = ops.kernel_mode() == "kernel" and \
                hasattr(backend, "qstacked_for")
        self.qkernels = bool(qkernels)
        if self.p > 0:
            seg = segment if segment is not None else backend.split(plan)
            self.dev_params = (backend.qstacked_for(seg, plan)
                               if self.qkernels
                               else backend.stacked_for(seg, plan))
            self.bits_x = int(seg.bits_x)
            self.dev_dtype = kv_cache_dtype(self.bits_x, self.model_dtype)
        else:
            self.dev_params = None
            self.bits_x = 0
            self.dev_dtype = self.model_dtype
        self.dev_caches = None
        self.srv_caches = None
        # block-granular device-KV accounting (cache.PagedKVCache): the
        # jitted programs keep their dense cache operands; the paged
        # structure tracks the page-granular RESIDENT footprint and is
        # validated bit-for-bit against the dense ring.
        self.paged = bool(paged) and self.p > 0
        self.page_tokens = int(page_tokens)
        self.page_pool = page_pool
        self.paged_kv: Optional[PagedKVCache] = None
        # serving-shape knobs (DESIGN.md §14), both default-off so the
        # zero-knob session is bit-for-bit the plain pipeline. Both rely
        # on slot == position in the ring (no wraparound) and on the
        # K/V cache being position-addressable, so they are gated to
        # attention-only, full-context (no sliding window) stacks.
        self.draft_tokens = int(draft_tokens)
        if self.draft_tokens < 0:
            raise ServingError("draft_tokens must be >= 0")
        plen = T.period_len(cfg)
        # full-context attention stacks prefill through the cache-
        # mediated extend program (monolithic prefill == the one-chunk
        # admission), so the prefill attention reads K/V through the
        # same narrowed cache dtype every later decode step reads —
        # and chunked prefill is bitwise the monolithic one
        self._cache_extendable = (
            cfg.sliding_window is None
            and all(cfg.block_kind(i) == ATTN for i in range(plen)))
        self.prefill_chunk_tokens: Optional[int] = None
        if prefill_chunk_tokens is not None or self.draft_tokens:
            if any(cfg.block_kind(i) != ATTN for i in range(plen)):
                raise ServingError(
                    "chunked prefill / speculative decode need an "
                    "attention-only stack: SSM state is a running "
                    "reduction, not position-addressable")
            if cfg.sliding_window is not None:
                raise ServingError(
                    "chunked prefill / speculative decode need the full-"
                    "context ring (slot == position); sliding-window "
                    "wraparound would overwrite live context")
        if prefill_chunk_tokens is not None:
            c = int(prefill_chunk_tokens) or 2 * self.page_tokens
            if c < 2:
                raise ServingError(
                    "prefill_chunk_tokens must be >= 2 (a 1-row chunk's "
                    "matvec lowering breaks the bitwise prefill lock) or "
                    "0 for the default of 2 * page_tokens")
            if self.paged and c % self.page_tokens:
                raise ServingError(
                    f"prefill_chunk_tokens={c} must be page-aligned "
                    f"(kv page = {self.page_tokens} tokens)")
            self.prefill_chunk_tokens = c
        self.pos = 0
        self.t_device_s = 0.0
        self.t_server_s = 0.0
        self.rounds = 0
        self.drafts_proposed = 0
        self.drafts_accepted = 0
        self.prefill_chunks = 1

    # -- pricing views ---------------------------------------------------
    def wire_bits_per_token(self, batch: int) -> float:
        """Uplink bits per decode step: the quantized cut hidden state
        plus the 32-bit sampled-token downlink; 0 for full offload (the
        stream never touches the radio after the prompt upload)."""
        if self.p == 0:
            return 0.0
        return float(self.bits_x * self.cfg.d_model * batch + 32 * batch)

    def wire_bits_per_round(self, batch: int,
                            k: Optional[int] = None) -> float:
        """Wire bits for ONE speculative round: the device ships k
        drafted ids (32-bit) + k+1 quantized cut hiddens uplink and
        receives up to k+1 verified ids downlink. Bytes stay ~linear in
        tokens — the win over k+1 plain steps is ROUND TRIPS: one
        channel latency is paid per round instead of per token, which
        is the term that bounds tokens/s on a slow channel."""
        if self.p == 0:
            return 0.0
        k = self.draft_tokens if k is None else int(k)
        hidden = self.bits_x * self.cfg.d_model * batch
        return float((k + 1) * hidden + 32 * k * batch
                     + 32 * (k + 1) * batch)

    def _quant_hop(self, h):
        """Quantize the cut hidden ``h`` (B, S, D) for the channel hop
        with one grid PER TOKEN POSITION (min/max over that position's
        (B, 1, D) slab) — the grid a decode step uses for its
        single-token slab. Per-position grids make the hop partition-
        invariant: a chunk's rows quantize exactly as the monolithic
        prefill's same rows (a whole-tensor grid would couple every row
        to the prompt's global range and break the bitwise chunked ==
        monolithic lock), and a (B, 1, D) call reduces to the plain
        per-tensor ``fake_quant`` bit for bit (min/max are order-exact),
        so decode steps are unchanged."""
        mu = jnp.min(h, axis=(0, 2), keepdims=True)
        phi = jnp.max(h, axis=(0, 2), keepdims=True)
        codes, scale, mu = quantize(h, self.bits_x, mu=mu, phi=phi)
        return dequantize(codes, scale, mu, h.dtype)

    def device_cache_bytes(self) -> int:
        if self.dev_caches is None or self.p == 0:
            return 0
        if self.paged_kv is not None:
            # pages actually held + the dense non-attention remainder
            return self.paged_kv.resident_bytes + \
                segment_nonattn_cache_bytes(self.cfg, self.dev_caches, 0,
                                            self.p)
        return segment_cache_bytes(self.cfg, self.dev_caches, 0, self.p)

    def sever(self) -> int:
        """End the stream: return every held KV page to the pool (no-op
        for dense sessions). Returns the page count released."""
        if self.paged_kv is None:
            return 0
        return self.paged_kv.free_all()

    def server_cache_bytes(self) -> int:
        if self.srv_caches is None:
            return 0
        return segment_cache_bytes(self.cfg, self.srv_caches, self.p,
                                   self.L)

    # -- pipeline stages -------------------------------------------------
    @staticmethod
    def chunk_bounds(s: int, c: int) -> List[tuple]:
        """Chunk boundaries [(lo, hi), ...] covering ``[0, s)`` in
        ``c``-token chunks, folding a remainder of 1 into the final
        chunk — a 1-row chunk's matvec lowering would break the bitwise
        chunked == monolithic prefill lock (``_attn_extend_with_cache``)."""
        bounds, lo = [], 0
        while lo < s:
            hi = min(lo + c, s)
            if s - hi == 1:
                hi = s
            bounds.append((lo, hi))
            lo = hi
        return bounds

    def prefill(self, prompt):
        """Run the partitioned prefill; returns the first greedy token
        (B,) and records stage seconds (TTFT = their sum). With
        ``prefill_chunk_tokens`` set the prompt is admitted chunk by
        chunk through ``extend_segment`` — same caches and first token
        bit-for-bit (lossless storage), but the compiled programs are
        shape-keyed on the CHUNK length, so a new prompt length no
        longer costs a fresh XLA trace and TTFT stops scaling with it."""
        prompt = jnp.asarray(prompt, jnp.int32)
        b, s = prompt.shape
        if s + 1 > self.max_len:
            raise ServingError(
                f"prompt ({s}) leaves no room in max_len={self.max_len}")
        if self.prefill_chunk_tokens is not None:
            return self._prefill_chunked(prompt, b, s,
                                         self.prefill_chunk_tokens)
        if self._cache_extendable:
            # monolithic prefill IS the one-chunk admission: routing it
            # through the same cache-mediated extend program means the
            # prefill attention reads K/V through the narrowed device
            # cache dtype — exactly what every decode step reads — and
            # a chunked prefill is bitwise this monolithic one (a
            # direct ``prefill_segment`` would attend on full-precision
            # K/V the cache then rounds, an answer no later step can
            # reproduce)
            return self._prefill_chunked(prompt, b, s, None)
        t0 = time.perf_counter()
        if self.p > 0:
            h0 = self.backend.embed(prompt, params=self.dev_params)
            cache0 = T.init_cache(self.cfg, b, self.max_len,
                                  self.dev_dtype)
            h_dev, self.dev_caches = self.backend.prefill_segment(
                h0, cache0, 0, self.p, params=self.dev_params)
            h_in = self._quant_hop(h_dev)
            jax.block_until_ready(h_in)
            if self.paged:
                if self.page_pool is None:
                    self.page_pool = segment_page_pool(
                        self.cfg, 0, self.p, b, self.max_len,
                        self.dev_dtype, page_tokens=self.page_tokens)
                self.paged_kv = PagedKVCache(self.page_pool, self.cfg, 0,
                                             self.p, b, self.max_len)
                self.paged_kv.ingest_prefill(self.dev_caches, s)
        t1 = time.perf_counter()
        if self.p == 0:
            h_in = self.backend.embed(prompt)
        cache0 = T.init_cache(self.cfg, b, self.max_len, self.model_dtype)
        h_srv, self.srv_caches = self.backend.prefill_segment(
            h_in, cache0, self.p, self.L)
        logits = self.backend.hidden_logits(h_srv[:, -1:, :])
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(token)
        t2 = time.perf_counter()
        self.t_device_s += t1 - t0
        self.t_server_s += t2 - t1
        self.pos = s
        return token

    def _prefill_chunked(self, prompt, b: int, s: int,
                         chunk_tokens: Optional[int]):
        """Chunk-granular prefill (``chunk_tokens=None`` = one chunk —
        the monolithic case): each chunk runs device extend → quantized
        hop → server extend, and (when paged) its pages are ingested as
        it lands — the paged footprint grows with the admitted prefix,
        not the final prompt."""
        bounds = [(0, s)] if chunk_tokens is None \
            else self.chunk_bounds(s, chunk_tokens)
        self.prefill_chunks = len(bounds)
        if self.p > 0:
            self.dev_caches = T.init_cache(self.cfg, b, self.max_len,
                                           self.dev_dtype)
            if self.paged:
                if self.page_pool is None:
                    self.page_pool = segment_page_pool(
                        self.cfg, 0, self.p, b, self.max_len,
                        self.dev_dtype, page_tokens=self.page_tokens)
                self.paged_kv = PagedKVCache(self.page_pool, self.cfg, 0,
                                             self.p, b, self.max_len)
        self.srv_caches = T.init_cache(self.cfg, b, self.max_len,
                                       self.model_dtype)
        h_srv = None
        for lo, hi in bounds:
            chunk = prompt[:, lo:hi]
            pos0 = jnp.asarray(lo, jnp.int32)
            t0 = time.perf_counter()
            if self.p > 0:
                h0 = self.backend.embed(chunk, params=self.dev_params)
                h_dev, self.dev_caches = self.backend.extend_segment(
                    h0, self.dev_caches, pos0, 0, self.p,
                    params=self.dev_params)
                h_in = self._quant_hop(h_dev)
                jax.block_until_ready(h_in)
                if self.paged_kv is not None:
                    self.paged_kv.ingest_range(self.dev_caches, lo, hi)
            t1 = time.perf_counter()
            if self.p == 0:
                h_in = self.backend.embed(chunk)
            h_srv, self.srv_caches = self.backend.extend_segment(
                h_in, self.srv_caches, pos0, self.p, self.L)
            jax.block_until_ready(h_srv)
            t2 = time.perf_counter()
            self.t_device_s += t1 - t0
            self.t_server_s += t2 - t1
        t1 = time.perf_counter()
        logits = self.backend.hidden_logits(h_srv[:, -1:, :])
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(token)
        self.t_server_s += time.perf_counter() - t1
        self.pos = s
        return token

    def step(self, token):
        """One decode step feeding ``token`` (B,); returns the next
        greedy token (B,)."""
        if self.pos + 1 > self.max_len:
            raise ServingError(f"decode past max_len={self.max_len}")
        tok = jnp.asarray(token, jnp.int32).reshape(-1, 1)
        pos = jnp.asarray(self.pos, jnp.int32)
        t0 = time.perf_counter()
        if self.p > 0:
            x = self.backend.embed(tok, params=self.dev_params)
            x_dev, self.dev_caches = self.backend.decode_segment(
                x, self.dev_caches, pos, 0, self.p,
                params=self.dev_params)
            x_in = self._quant_hop(x_dev)
            jax.block_until_ready(x_in)
            if self.paged_kv is not None:
                self.paged_kv.append_step(self.dev_caches, self.pos)
        t1 = time.perf_counter()
        if self.p == 0:
            x_in = self.backend.embed(tok)
        x_srv, self.srv_caches = self.backend.decode_segment(
            x_in, self.srv_caches, pos, self.p, self.L)
        logits = self.backend.hidden_logits(x_srv)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(nxt)
        t2 = time.perf_counter()
        self.t_device_s += t1 - t0
        self.t_server_s += t2 - t1
        self.pos += 1
        return nxt

    def _spec_round(self, token, k: int) -> List[np.ndarray]:
        """One speculative round: draft ``k`` tokens through the device
        segment + draft head, verify all of them in ONE server call,
        emit the longest matching greedy prefix + the server's next
        token (1..k+1 tokens) — bit-identical to plain greedy decode.

        Draft head: argmax over ``hidden_logits`` of the QUANTIZED cut
        hidden — the deployed segment at its planned bit-widths IS the
        draft model (at p == L it is the full model, so acceptance is
        exactly 1; at p == 0 it degenerates to an embedding-only guess).
        No cache rollback on rejection: every slot past the acceptance
        point is re-written by a later round before any query attends
        it (slot == position, writes precede reads), so stale draft K/V
        is unreachable by construction."""
        P = self.pos
        t0 = time.perf_counter()
        cur = jnp.asarray(token, jnp.int32).reshape(-1, 1)
        qs: List = []
        drafts: List = []
        for j in range(k + 1):
            pos = jnp.asarray(P + j, jnp.int32)
            if self.p > 0:
                x = self.backend.embed(cur, params=self.dev_params)
                x_dev, self.dev_caches = self.backend.decode_segment(
                    x, self.dev_caches, pos, 0, self.p,
                    params=self.dev_params)
                q = self._quant_hop(x_dev)
            else:
                q = self.backend.embed(cur)
            qs.append(q)
            if j < k:
                d = jnp.argmax(
                    self.backend.hidden_logits(q, params=self.dev_params),
                    -1).astype(jnp.int32)
                drafts.append(np.asarray(d))
                cur = d.reshape(-1, 1)
        hh = jnp.concatenate(qs, axis=1)           # (B, k+1, D)
        jax.block_until_ready(hh)
        if self.paged_kv is not None:
            for j in range(k + 1):
                self.paged_kv.append_step(self.dev_caches, P + j)
        t1 = time.perf_counter()
        logits, self.srv_caches = self.backend.verify_segment(
            hh, self.srv_caches, jnp.asarray(P, jnp.int32), self.p,
            self.L)
        g = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        t2 = time.perf_counter()
        # acceptance = longest prefix where every batch row's draft
        # matches the verified greedy token (min over rows keeps all
        # rows on their true greedy trajectory)
        d_np = np.stack(drafts, axis=1)            # (B, k)
        a = k
        for i in range(k):
            if not np.array_equal(d_np[:, i], g[:, i]):
                a = i
                break
        if self.p > 0:
            self.t_device_s += t1 - t0
        else:
            self.t_server_s += t1 - t0
        self.t_server_s += t2 - t1
        self.drafts_proposed += k
        self.drafts_accepted += a
        self.pos = P + a + 1
        return [g[:, i] for i in range(a + 1)]

    # -- drivers ----------------------------------------------------------
    def round_stream(self, prompt, max_new_tokens: int):
        """Generator of per-round token lists: the first yield is the
        prefill's ``[token0]``; each later yield is one decode round's
        emissions — ``[token]`` for plain greedy, 1..k+1 tokens for a
        speculative round. ``self.rounds`` counts the decode rounds."""
        token = self.prefill(prompt)
        yield [np.asarray(token)]
        emitted = 1
        while emitted < max_new_tokens:
            remaining = max_new_tokens - emitted
            k = min(self.draft_tokens, remaining - 1,
                    self.max_len - 1 - self.pos)
            if k >= 1:
                out = self._spec_round(token, k)
                token = jnp.asarray(out[-1], jnp.int32)
            else:
                token = self.step(token)
                out = [np.asarray(token)]
            self.rounds += 1
            emitted += len(out)
            yield out

    def stream(self, prompt, max_new_tokens: int):
        """Generator of (step_index, token (B,) np.ndarray) — token 0 is
        the prefill's (TTFT); the session's stage clocks accumulate as
        the consumer drains it. A speculative round's tokens are yielded
        individually (they become available together)."""
        i = 0
        for out in self.round_stream(prompt, max_new_tokens):
            for tok in out:
                yield i, tok
                i += 1

    def generate(self, prompt, max_new_tokens: int,
                 stream_cb=None) -> GenerationResult:
        if max_new_tokens < 1:
            raise ServingError("max_new_tokens must be >= 1")
        toks: List[np.ndarray] = []
        per_token: List[float] = []
        t_start = time.perf_counter()
        ttft = None
        last = t_start
        i = 0
        for out in self.round_stream(prompt, max_new_tokens):
            now = time.perf_counter()
            if ttft is None:
                ttft = now - t_start
            else:
                # spread the round's wall seconds over its emissions so
                # len(per_token_s) == new_tokens - 1 (docstring above)
                per_token.extend([(now - last) / len(out)] * len(out))
            last = now
            for tok in out:
                toks.append(tok)
                if stream_cb is not None:
                    stream_cb(i, tok)
                i += 1
        total = time.perf_counter() - t_start
        return GenerationResult(
            tokens=np.stack(toks, axis=1),
            ttft_s=float(ttft),
            t_device_s=self.t_device_s,
            t_server_s=self.t_server_s,
            t_total_s=total,
            per_token_s=per_token,
            device_cache_bytes=self.device_cache_bytes(),
            server_cache_bytes=self.server_cache_bytes(),
            device_cache_dtype=np.dtype(self.dev_dtype).name,
            rounds=self.rounds,
            draft_tokens=self.draft_tokens,
            drafts_proposed=self.drafts_proposed,
            drafts_accepted=self.drafts_accepted,
            prefill_chunks=self.prefill_chunks)
