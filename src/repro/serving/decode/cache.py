"""KV-cache dtype plumbing for the partitioned decode pipeline.

The cut splits cache OWNERSHIP: the device holds the caches of its
quantized segment ``[0, p)``, the server the tail's ``[p, L)``. Each
side allocates a FULL stacked ``transformer.init_cache`` tree (the
compile-once segment programs scan all layers and mask the inactive
ones), but only its own segment's slices are ever written — the rest
stay zeros, a simulation artifact whose cost is excluded from the
footprint accounting below.

A quantized device segment stores its cache at the deployed bit-width's
storage dtype instead of silently upcasting to bf16: ≤8-bit plans get
``float8_e4m3fn`` (1 B/elem — storage only; attention always computes
in the query dtype, see ``models.attention.attention_decode``), ≤16-bit
plans bf16, and full-precision plans the model dtype. SSM recurrent
state stays f32 regardless (``init_ssm_cache`` pins it) — only the conv
ring follows the storage dtype.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN
from repro.models.transformer import num_periods, period_len
from repro.serving.errors import ServingError


def kv_cache_dtype(bits, model_dtype=jnp.bfloat16):
    """Storage dtype of a decode cache deployed at ``bits`` activation
    bits. ``None``/0 bits means full precision (the server tail)."""
    if not bits:
        return model_dtype
    b = int(math.ceil(float(bits)))
    if b <= 8:
        return jnp.float8_e4m3fn
    if b <= 16:
        return jnp.bfloat16
    return model_dtype


def tree_cache_bytes(caches) -> int:
    """Total allocated bytes of an ``init_cache`` tree (all layers)."""
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(caches)))


def segment_cache_bytes(cfg, caches, start: int, stop: int) -> int:
    """Bytes of the cache slices owned by segment ``[start, stop)`` of a
    stacked ``init_cache`` tree — what the segment's holder actually
    pays for (layer l lives at index ``l // plen`` of period-position
    ``l % plen``'s leaves, one of ``nper`` equal slices)."""
    plen, nper = period_len(cfg), num_periods(cfg)
    total = 0
    for layer in range(start, stop):
        pos = layer % plen
        total += sum(leaf.nbytes // nper
                     for leaf in jax.tree.leaves(caches[pos]))
    return total


def segment_nonattn_cache_bytes(cfg, caches, start: int, stop: int) -> int:
    """``segment_cache_bytes`` restricted to the NON-attention layers of
    the segment — the dense remainder (SSM recurrent/conv state, O(1) in
    context) a paged-KV session still holds at full reservation."""
    plen, nper = period_len(cfg), num_periods(cfg)
    total = 0
    for layer in range(start, stop):
        pos = layer % plen
        if cfg.block_kind(pos) != ATTN:
            total += sum(leaf.nbytes // nper
                         for leaf in jax.tree.leaves(caches[pos]))
    return total


# ---------------------------------------------------------------------------
# Block-granular (paged) KV allocation (PR 9, DESIGN.md §13).
#
# The dense decode path reserves ``decode_max_len`` KV rows per stream up
# front — a stream that generates 10 tokens against a 16-token prompt
# holds the same device memory as one that fills the whole window, and
# the plan-time feasibility mask rejects streams the hardware could
# actually hold. Here KV grows in PAGES of ``page_tokens`` ring slots: a
# fixed pool hands out pages on demand, per-stream block tables map ring
# blocks -> pages, and severed streams return every page. Attention
# layers only; SSM recurrent state is O(1) in context and keeps its
# dense (and already minimal) reservation.
#
# The compile-once jit programs keep DENSE cache operands (the masked
# scan's cache tree is part of the shape key); the paged structure is
# the allocator + residency ledger the serving layer runs against, and
# ``to_dense`` reconstructs the exact dense ring — bit-for-bit, which is
# how the property tests pin it.

DEFAULT_PAGE_TOKENS = 16


def paged_kv_ctx(tokens: int, page_tokens: int, max_len: int) -> int:
    """Context length a ``tokens``-token stream is PRICED at under paged
    allocation: rounded up to the page boundary, capped by the dense
    worst case. Strictly <= ``max_len`` — the admission mask can only
    widen."""
    if page_tokens <= 0:
        return max_len
    pages = -(-int(tokens) // int(page_tokens))
    return min(pages * int(page_tokens), int(max_len))


class KVPagePool:
    """Fixed pool of KV pages for one cache geometry. A page holds
    ``page_tokens`` ring slots of ONE (layer, batch-row) pair — both K
    and V — at the segment's storage dtype: (2, page_tokens, kvp, hd).
    Allocation is O(1) (free list); exhaustion raises ``ServingError``
    (the serving layer sizes pools from the same admission math that
    priced the streams, so a raise is a pricing bug surfacing)."""

    def __init__(self, num_pages: int, page_tokens: int, kvp: int, hd: int,
                 dtype=jnp.bfloat16):
        self.page_tokens = int(page_tokens)
        self.kvp, self.hd = int(kvp), int(hd)
        self.dtype = np.dtype(dtype)
        self.data = np.zeros((num_pages, 2, self.page_tokens, kvp, hd),
                             self.dtype)
        self._free = list(range(num_pages - 1, -1, -1))
        self.num_pages = int(num_pages)

    @property
    def page_bytes(self) -> int:
        return int(self.data[0].nbytes)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def used_bytes(self) -> int:
        return self.used_pages * self.page_bytes

    def alloc(self) -> int:
        if not self._free:
            raise ServingError(
                f"KV page pool exhausted ({self.num_pages} pages of "
                f"{self.page_tokens} tokens)")
        page = self._free.pop()
        self.data[page] = 0
        return page

    def release(self, page: int) -> None:
        self._free.append(int(page))


class PagedKVCache:
    """Per-stream block tables over a ``KVPagePool`` for the ATTENTION
    layers of segment ``[start, stop)``.

    Mirrors the ring-buffer layout of ``models.attention``: ring slot
    ``pos % buf`` lives at offset ``slot % page_tokens`` of the page
    mapped by block ``slot // page_tokens``; a block's page is allocated
    on first write and held until the stream severs (ring reuse
    overwrites in place — the page set saturates at
    ``ceil(buf / page_tokens)`` per (layer, batch-row), reached only by
    streams that actually fill the window).

    ``ingest_prefill`` / ``append_step`` copy written rows OUT of the
    dense jit-operand cache (the compiled programs stay dense — see the
    module note); ``to_dense`` rebuilds the dense ring bit-for-bit.
    """

    def __init__(self, pool: KVPagePool, cfg, start: int, stop: int,
                 batch: int, max_len: int):
        self.pool = pool
        self.cfg = cfg
        self.start, self.stop = int(start), int(stop)
        self.batch = int(batch)
        plen = period_len(cfg)
        buf = min(max_len, cfg.sliding_window) if cfg.sliding_window \
            else max_len
        if buf % pool.page_tokens and buf > pool.page_tokens:
            # a partial tail page is fine; buf never exceeds table range
            pass
        self.buf = int(buf)
        # attention layers owned by the segment: layer -> (pos, per)
        self.attn_layers = {
            l: (l % plen, l // plen) for l in range(self.start, self.stop)
            if cfg.block_kind(l % plen) == ATTN}
        # (layer, batch_row) -> {block -> page id}
        self.tables: Dict[Tuple[int, int], Dict[int, int]] = {
            (l, b): {} for l in self.attn_layers for b in range(batch)}
        self.length = 0                     # absolute positions ingested

    # -- allocation ------------------------------------------------------
    def _page_for(self, layer: int, b: int, block: int) -> int:
        table = self.tables[(layer, b)]
        page = table.get(block)
        if page is None:
            page = table[block] = self.pool.alloc()
        return page

    def _write_slot(self, layer: int, slot: int, k_rows, v_rows) -> None:
        """k_rows/v_rows (B, kvp, hd) host arrays for ring slot ``slot``."""
        block, off = divmod(slot, self.pool.page_tokens)
        for b in range(self.batch):
            page = self._page_for(layer, b, block)
            self.pool.data[page, 0, off] = k_rows[b]
            self.pool.data[page, 1, off] = v_rows[b]

    # -- ingest from the dense jit-operand cache -------------------------
    def append_step(self, caches, pos: int) -> None:
        """Copy the decode step's written ring slot (``pos % buf``) of
        every owned attention layer out of the dense cache tree."""
        slot = int(pos) % self.buf
        for layer, (p_pos, per) in self.attn_layers.items():
            k = np.asarray(caches[p_pos]["k"][per, :, slot])
            v = np.asarray(caches[p_pos]["v"][per, :, slot])
            self._write_slot(layer, slot, k, v)
        self.length = max(self.length, int(pos) + 1)

    def ingest_range(self, caches, lo: int, hi: int) -> None:
        """Copy positions ``[lo, hi)`` of the dense ring into pages —
        chunked prefill calls this once per admitted chunk, so the
        paged footprint grows page-by-page as the prompt streams in
        instead of materializing at the end of a monolithic prefill."""
        lo, hi = int(lo), int(hi)
        if hi <= lo:
            return
        for layer, (p_pos, per) in self.attn_layers.items():
            k = np.asarray(caches[p_pos]["k"][per])     # (B, buf, kvp, hd)
            v = np.asarray(caches[p_pos]["v"][per])
            for p in range(lo, hi):
                slot = p % self.buf
                self._write_slot(layer, slot, k[:, slot], v[:, slot])
        self.length = max(self.length, hi)

    def ingest_prefill(self, caches, seq_len: int) -> None:
        """Copy every live ring slot after a ``seq_len``-token prefill
        (positions ``max(0, seq_len - buf) .. seq_len - 1``)."""
        self.ingest_range(caches, max(0, int(seq_len) - self.buf), seq_len)

    # -- views -----------------------------------------------------------
    def to_dense(self, template_caches):
        """Rebuild the stacked dense cache tree from the pages: owned
        attention slices are reconstructed (unwritten blocks as zeros —
        the dense init state); every other leaf/slice is taken from
        ``template_caches`` verbatim. The bit-for-bit round-trip target
        of the property tests."""
        out = [dict(c) for c in template_caches]
        per_pos: Dict[int, Dict[str, np.ndarray]] = {}
        for layer, (p_pos, per) in self.attn_layers.items():
            if p_pos not in per_pos:
                per_pos[p_pos] = {
                    "k": np.asarray(template_caches[p_pos]["k"]).copy(),
                    "v": np.asarray(template_caches[p_pos]["v"]).copy()}
            dense_k = np.zeros(
                (self.batch, self.buf, self.pool.kvp, self.pool.hd),
                self.pool.dtype)
            dense_v = np.zeros_like(dense_k)
            for b in range(self.batch):
                for block, page in self.tables[(layer, b)].items():
                    s0 = block * self.pool.page_tokens
                    s1 = min(s0 + self.pool.page_tokens, self.buf)
                    dense_k[b, s0:s1] = self.pool.data[page, 0, :s1 - s0]
                    dense_v[b, s0:s1] = self.pool.data[page, 1, :s1 - s0]
            per_pos[p_pos]["k"][per] = dense_k
            per_pos[p_pos]["v"][per] = dense_v
        for p_pos, kv in per_pos.items():
            out[p_pos] = {**out[p_pos], "k": jnp.asarray(kv["k"]),
                          "v": jnp.asarray(kv["v"])}
        return out

    @property
    def held_pages(self) -> int:
        return sum(len(t) for t in self.tables.values())

    @property
    def resident_bytes(self) -> int:
        """Page-granular resident footprint of the owned attention
        caches — monotone in held pages by construction."""
        return self.held_pages * self.pool.page_bytes

    def free_all(self) -> int:
        """Sever: return every page to the pool. Returns the count."""
        n = 0
        for key, table in self.tables.items():
            for page in table.values():
                self.pool.release(page)
                n += 1
            self.tables[key] = {}
        return n


def segment_page_pool(cfg, start: int, stop: int, batch: int, max_len: int,
                      dtype=jnp.bfloat16,
                      page_tokens: int = DEFAULT_PAGE_TOKENS,
                      streams: int = 1) -> KVPagePool:
    """A pool sized for ``streams`` concurrent worst-case streams of
    segment ``[start, stop)`` — the dense reservation expressed in
    pages, the upper bound paged allocation stays under."""
    hd = cfg.resolved_head_dim()
    kvp, _ = cfg.padded_heads()
    buf = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    plen = period_len(cfg)
    n_attn = sum(1 for l in range(start, stop)
                 if cfg.block_kind(l % plen) == ATTN)
    pages = -(-buf // page_tokens) * n_attn * batch * streams
    return KVPagePool(max(pages, 1), page_tokens, kvp, hd, dtype)


class PageLedger:
    """Pure residency accounting for the fleet engine's decode lane —
    the pricing-only twin of ``KVPagePool`` (the fleet simulates at
    cost-model granularity; no tensors move). Tracks per-stream
    page-granular device-KV bytes, the fleet-wide current/peak, and the
    no-leak invariant: after every stream finishes or severs,
    ``resident_bytes == 0`` and ``open_streams == 0``."""

    def __init__(self):
        self._held: Dict[int, float] = {}       # stream index -> bytes
        self._pages: Dict[int, int] = {}        # stream index -> pages
        self.resident_bytes = 0.0
        self.peak_bytes = 0.0
        self.total_page_allocs = 0
        self.total_page_frees = 0

    @property
    def open_streams(self) -> int:
        return len(self._held)

    @property
    def resident_pages(self) -> int:
        return sum(self._pages.values())

    def open(self, index: int, nbytes: float, pages: int) -> None:
        self.close(index)                       # idempotent re-open
        self._held[index] = float(nbytes)
        self._pages[index] = int(pages)
        self.resident_bytes += float(nbytes)
        self.total_page_allocs += int(pages)
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)

    def grow(self, index: int, nbytes: float, pages: int) -> None:
        """Raise stream ``index``'s residency to ``nbytes``/``pages``
        (monotone: paged KV never shrinks mid-stream — ring reuse
        overwrites in place)."""
        if index not in self._held:
            return
        d_bytes = max(0.0, float(nbytes) - self._held[index])
        d_pages = max(0, int(pages) - self._pages[index])
        self._held[index] += d_bytes
        self._pages[index] += d_pages
        self.resident_bytes += d_bytes
        self.total_page_allocs += d_pages
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)

    def close(self, index: int) -> int:
        """Finish/sever: release the stream's pages. Returns the count."""
        nbytes = self._held.pop(index, 0.0)
        pages = self._pages.pop(index, 0)
        self.resident_bytes -= nbytes
        if not self._held:
            self.resident_bytes = 0.0           # clamp fp residue at empty
        self.total_page_frees += pages
        return pages
