"""KV-cache dtype plumbing for the partitioned decode pipeline.

The cut splits cache OWNERSHIP: the device holds the caches of its
quantized segment ``[0, p)``, the server the tail's ``[p, L)``. Each
side allocates a FULL stacked ``transformer.init_cache`` tree (the
compile-once segment programs scan all layers and mask the inactive
ones), but only its own segment's slices are ever written — the rest
stay zeros, a simulation artifact whose cost is excluded from the
footprint accounting below.

A quantized device segment stores its cache at the deployed bit-width's
storage dtype instead of silently upcasting to bf16: ≤8-bit plans get
``float8_e4m3fn`` (1 B/elem — storage only; attention always computes
in the query dtype, see ``models.attention.attention_decode``), ≤16-bit
plans bf16, and full-precision plans the model dtype. SSM recurrent
state stays f32 regardless (``init_ssm_cache`` pins it) — only the conv
ring follows the storage dtype.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.transformer import num_periods, period_len


def kv_cache_dtype(bits, model_dtype=jnp.bfloat16):
    """Storage dtype of a decode cache deployed at ``bits`` activation
    bits. ``None``/0 bits means full precision (the server tail)."""
    if not bits:
        return model_dtype
    b = int(math.ceil(float(bits)))
    if b <= 8:
        return jnp.float8_e4m3fn
    if b <= 16:
        return jnp.bfloat16
    return model_dtype


def tree_cache_bytes(caches) -> int:
    """Total allocated bytes of an ``init_cache`` tree (all layers)."""
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(caches)))


def segment_cache_bytes(cfg, caches, start: int, stop: int) -> int:
    """Bytes of the cache slices owned by segment ``[start, stop)`` of a
    stacked ``init_cache`` tree — what the segment's holder actually
    pays for (layer l lives at index ``l // plen`` of period-position
    ``l % plen``'s leaves, one of ``nper`` equal slices)."""
    plen, nper = period_len(cfg), num_periods(cfg)
    total = 0
    for layer in range(start, stop):
        pos = layer % plen
        total += sum(leaf.nbytes // nper
                     for leaf in jax.tree.leaves(caches[pos]))
    return total
