"""Autoregressive decode serving (DESIGN.md §11): the prefill→decode
pipeline partitioned at the QPART cut point. The device holds the
quantized segment's KV cache at the deployed bit-width's storage dtype,
the server holds the full-precision tail cache, and each decode step
ships one token's quantized hidden state across the channel.

  * ``cache``    — cache dtype ladder + device-segment footprint math
  * ``pipeline`` — ``DecodeSession`` / ``GenerationResult``: streaming
                   greedy decode over the compile-once segment programs
  * ``batching`` — ``DecodeBatcher``: the fleet engine's per-server
                   continuous-batching state for concurrent streams
"""
from repro.serving.decode.batching import DecodeBatcher, DecodeStream
from repro.serving.decode.cache import (kv_cache_dtype, segment_cache_bytes,
                                        tree_cache_bytes)
from repro.serving.decode.pipeline import DecodeSession, GenerationResult

__all__ = [
    "DecodeBatcher", "DecodeStream", "DecodeSession", "GenerationResult",
    "kv_cache_dtype", "segment_cache_bytes", "tree_cache_bytes",
]
