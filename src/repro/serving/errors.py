"""Serving-error hierarchy.

The online path used to guard its preconditions with bare ``assert``
statements (gone under ``python -O``) and raw ``KeyError`` on unknown
model names. Every serving-layer failure now raises a ``ServingError``
subclass so callers can catch one root type and error messages name the
missing lifecycle step.
"""
from __future__ import annotations


class ServingError(Exception):
    """Root of all QPART serving-layer errors."""


class UnknownModelError(ServingError, KeyError):
    """Request names a model that was never ``register()``-ed."""

    def __init__(self, name: str, registered):
        self.name = name
        super().__init__(
            f"unknown model {name!r}; registered: {sorted(registered) or '[]'}")

    def __str__(self):            # KeyError quotes its arg; keep the message
        return self.args[0]


class NotCalibratedError(ServingError):
    """Model lacks noise calibration or any built offline store — run
    ``calibrate()`` then ``build_store()`` before serving."""


class StoreMissingError(ServingError):
    """A store exists, but not for the requested ``ReferenceContext``."""


class PlanInfeasibleError(ServingError):
    """No stored partition candidate satisfies the request's device
    constraints (e.g. every quantized segment exceeds the device memory)."""


class FaultConfigError(ServingError, ValueError):
    """Invalid fault-injection or retry configuration (unknown fault
    kind, non-positive dwell times, attempt budget < 1, ...) — raised at
    construction so a chaos run never discovers a bad schedule
    mid-simulation."""
