"""The QPART inference-serving server.

Lifecycle (paper Fig. 1–2):
  1. ``register_model`` stores a pre-trained model + calibration data.
  2. ``calibrate``   — offline noise calibration: per-layer (s_w, s_x, rho)
     probes + Delta(a) table (Alg. 1 steps 7–10).
  3. ``build_offline_store`` — Alg. 1: closed-form bit patterns for 5
     accuracy levels x all partition points.
  4. ``serve``       — Alg. 2: pick the stored pattern minimizing the
     runtime objective for the request's device/channel, quantize the
     segment, price the plan, and (optionally) measure real accuracy of
     the partitioned, quantized execution.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.classifier import ClassifierConfig
from repro.core import noise as noise_lib
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile, classifier_layer_specs,
                                   cost_breakdown, delta_coeff, eps_coeff,
                                   xi_coeff)
from repro.core.partition import split_classifier
from repro.core.quantizer import fake_quant, round_bits
from repro.core.solver import (OfflineStore, build_offline_store,
                               plan_for_partition)
from repro.models.classifier import (classifier_forward, forward_from_layer,
                                     layer_activations)
from repro.serving.pricing import price_window
from repro.serving.simulator import InferenceRequest, ServingResult, simulate_plan

DEFAULT_ACCURACY_LEVELS = (0.001, 0.0025, 0.005, 0.01, 0.02)


@dataclasses.dataclass
class RegisteredModel:
    cfg: ClassifierConfig
    params: list
    calib_x: jnp.ndarray
    calib_y: jnp.ndarray
    s_w: np.ndarray = None
    s_x: np.ndarray = None
    rho: np.ndarray = None
    delta_table: dict = None
    base_accuracy: float = None
    store: OfflineStore = None


class QPARTServer:
    def __init__(self, server_profile: Optional[ServerProfile] = None,
                 levels: Sequence[float] = DEFAULT_ACCURACY_LEVELS):
        self.server = server_profile or ServerProfile()
        self.levels = tuple(levels)
        self.models: Dict[str, RegisteredModel] = {}

    # ------------------------------------------------------------------
    def register_model(self, name: str, cfg: ClassifierConfig, params,
                       calib_x, calib_y) -> None:
        self.models[name] = RegisteredModel(cfg, params,
                                            jnp.asarray(calib_x),
                                            jnp.asarray(calib_y))

    # ------------------------------------------------------------------
    # Offline phase (Alg. 1)
    def calibrate(self, name: str, probe_bits: int = noise_lib.PROBE_BITS) -> None:
        m = self.models[name]
        cfg, params = m.cfg, m.params
        x = m.calib_x

        def apply_fn(p, a, start: int = 0):
            if start == 0:
                return classifier_forward(p, cfg, a)
            return forward_from_layer(p, cfg, a, start)

        acts, logits = layer_activations(params, cfg, x)
        adv = noise_lib.adversarial_noise_energy(logits)
        adv_mean = float(jnp.mean(adv))

        L = cfg.num_layers
        s_w = np.zeros(L)
        s_x = np.zeros(L)
        rho = np.zeros(L)
        n_calib = x.shape[0]
        for l in range(L):
            wq = {k: fake_quant(v, probe_bits) for k, v in params[l].items()}
            noisy = list(params)
            noisy[l] = wq
            e_w = float(noise_lib.output_noise_energy(
                lambda p, a: apply_fn(p, a), params, noisy, x))
            aq = fake_quant(acts[l], probe_bits)
            d = apply_fn(params, aq, start=l) - apply_fn(params, acts[l], start=l)
            e_x = float(jnp.sum(jnp.square(d.astype(jnp.float32))))
            s_w[l] = e_w / n_calib * 4.0 ** probe_bits
            s_x[l] = e_x / n_calib * 4.0 ** probe_bits
            # Eq. 22: mean quantization noise / mean adversarial noise
            rho[l] = max((0.5 * (e_w + e_x) / n_calib) / adv_mean, 1e-12)
        m.s_w, m.s_x, m.rho = s_w, s_x, rho

        m.delta_table, m.base_accuracy = noise_lib.calibrate_delta(
            lambda p, a: apply_fn(p, a), params, x, m.calib_y, rho,
            targets=self.levels)

    def build_store(self, name: str, device: DeviceProfile, channel: Channel,
                    weights: ObjectiveWeights) -> None:
        """Alg. 1 proper: precompute {(b_a^p, p)} for the reference context."""
        m = self.models[name]
        specs = classifier_layer_specs(m.cfg)
        m.store = build_offline_store(
            levels=self.levels, budgets=m.delta_table,
            layer_z_w=[sp.z_w for sp in specs],
            layer_z_x=[sp.z_x for sp in specs],
            layer_s_w=m.s_w, layer_s_x=m.s_x, layer_rho=m.rho,
            layer_o=[sp.o for sp in specs],
            xi=xi_coeff(weights, device), delta_cost=delta_coeff(weights, self.server),
            eps=eps_coeff(weights, device, channel),
            input_z=float(np.prod(m.cfg.input_shape)))

    # ------------------------------------------------------------------
    # Online phase (Alg. 2)
    def serve(self, req: InferenceRequest, test_x=None, test_y=None) -> ServingResult:
        m = self.models[req.model]
        assert m.store is not None, "run calibrate() + build_store() first"
        specs = classifier_layer_specs(m.cfg, batch=req.batch)
        xi = xi_coeff(req.weights, req.device)
        dl = delta_coeff(req.weights, self.server)
        ep = eps_coeff(req.weights, req.device, req.channel)
        o = np.array([sp.o for sp in specs])
        o_cum = np.cumsum(o)

        def runtime_objective(plan):
            o1 = o_cum[plan.p - 1] if plan.p else 0.0
            wire = plan.payload_x_bits if req.segment_cached \
                else plan.payload_bits
            return xi * o1 + dl * (o_cum[-1] - o1) + ep * wire

        plan = m.store.lookup(req.accuracy_budget, runtime_objective)
        wire = plan.payload_x_bits if req.segment_cached else plan.payload_bits
        result = simulate_plan(plan, specs, req.device, self.server,
                               req.channel, req.weights, payload_bits=wire)

        if test_x is not None:
            acc = self.execute_partitioned(req.model, plan, test_x, test_y)
            result.accuracy = acc
            # degrade vs the SAME test set (base_accuracy is measured on the
            # calibration split, which may differ in difficulty)
            base_logits = classifier_forward(m.params, m.cfg, test_x)
            base_acc = float(jnp.mean(jnp.argmax(base_logits, -1) == test_y))
            result.accuracy_degradation = base_acc - acc
        result.extra["bits_w"] = np.asarray(round_bits(plan.bits_w)) if plan.p else []
        result.extra["bits_x"] = plan.bits_x
        return result

    # ------------------------------------------------------------------
    def serve_batch(self, requests: Sequence[InferenceRequest],
                    ) -> List[ServingResult]:
        """Alg. 2 for a whole request window: price every request against
        the plan table as one objective matrix per model group
        (serving.pricing, shared with WorkloadBalancer) instead of the
        per-request Python loop in ``serve``. Result-for-result identical
        to ``[self.serve(r) for r in requests]``."""
        tab = price_window(self.models, self.server, requests)
        choices = tab.argmin_choices()
        bits_cache: Dict[int, np.ndarray] = {}   # windows share few plans
        results: List[ServingResult] = []
        for i, r in enumerate(requests):
            plan, o1, o2, wire = tab.select(i, int(choices[i]))
            # cost of the CHOSEN plan only — one scalar call per request
            # keeps Eq. 5–8 in a single place (cost_model)
            costs = cost_breakdown(o1, o2, wire, r.device, self.server,
                                   r.channel)
            res = ServingResult(plan=plan, costs=costs,
                                objective=costs.objective(r.weights),
                                payload_bits=wire)
            # same ceil/clip as round_bits, but numpy: no per-request
            # JAX dispatch on the batched path
            # fresh array/list per result, like serve(): no aliasing
            if plan.p:
                if id(plan) not in bits_cache:
                    bits_cache[id(plan)] = np.clip(
                        np.ceil(plan.bits_w), 2, 16).astype(np.int32)
                res.extra["bits_w"] = bits_cache[id(plan)].copy()
            else:
                res.extra["bits_w"] = []
            res.extra["bits_x"] = plan.bits_x
            results.append(res)
        return results

    # ------------------------------------------------------------------
    def execute_partitioned(self, name: str, plan, x, y) -> float:
        """Really run the two segments: device side with quantized weights
        + quantized cut activation, server side full precision."""
        m = self.models[name]
        specs = classifier_layer_specs(m.cfg)
        seg, server_params = split_classifier(m.params, plan, specs)
        p = plan.p
        if p == 0:
            logits = classifier_forward(m.params, m.cfg, x)
        else:
            from repro.configs.classifier import DenseSpec
            from repro.models.classifier import _apply_layer, _ensure_batched
            # device: layers 1..p on quantized weights, then quantize the
            # cut activation for the uplink; server: full-precision tail.
            h = _ensure_batched(x, m.cfg)
            if isinstance(m.cfg.layers[0], DenseSpec):
                h = h.reshape(h.shape[0], -1)
            for l in range(p):
                h = _apply_layer(m.cfg.layers[l], seg.params[l], h,
                                 last=l == m.cfg.num_layers - 1)
            h = fake_quant(h, int(round_bits(np.array([plan.bits_x]))[0]))
            logits = forward_from_layer(m.params, m.cfg, h, p)
        return float(jnp.mean(jnp.argmax(logits, -1) == y))
