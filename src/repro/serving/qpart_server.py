"""The QPART inference-serving server.

Lifecycle (paper Fig. 1–2), model-agnostic via ``ModelBackend``:
  1. ``register``    — name a backend (which owns the architecture:
     config, params, layer specs, forward fns, quantized execution) plus
     its calibration data.
  2. ``calibrate``   — offline noise calibration: per-layer (s_w, s_x,
     rho) probes + Delta(a) table (Alg. 1 steps 7–10), through the
     backend's forward family only.
  3. ``build_store`` — Alg. 1: closed-form bit patterns for 5 accuracy
     levels x all partition points, per ``ReferenceContext`` (device,
     channel, weights) — one model serves many contexts side by side.
  4. ``serve``       — Alg. 2: plan (pick the stored pattern minimizing
     the runtime objective, device-memory-feasible only) → deploy
     (a ``Deployment`` bundling plan, priced costs and a callable
     quantized device segment) → execute (``Deployment.execute``
     measures real accuracy of the partitioned, quantized model).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import noise as noise_lib
from repro.core.cost_model import (CalibratedCost, CalibrationLedger, Channel,
                                   CostProvider, DeviceProfile,
                                   ObjectiveWeights, ServerProfile)
from repro.core.quantizer import round_bits
from repro.core.solver import OfflineStore, build_offline_store
from repro.serving.backends.base import ModelBackend
from repro.serving.deployment import Deployment, ReferenceContext
from repro.serving.errors import (NotCalibratedError, PlanInfeasibleError,
                                  StoreMissingError, UnknownModelError)
from repro.serving.pricing import candidate_rows_for, price_window
from repro.serving.simulator import InferenceRequest, ServingResult

DEFAULT_ACCURACY_LEVELS = (0.001, 0.0025, 0.005, 0.01, 0.02)


@dataclasses.dataclass
class ModelState:
    """Per-model serving state: the backend plus everything the offline
    phase derives from it. Replaces the old ``RegisteredModel`` field-bag
    (whose single ``store`` field each ``build_store`` silently
    overwrote)."""
    backend: ModelBackend
    calib_x: jnp.ndarray
    calib_y: jnp.ndarray
    s_w: np.ndarray = None
    s_x: np.ndarray = None
    rho: np.ndarray = None
    delta_table: dict = None
    base_accuracy: float = None
    stores: Dict[ReferenceContext, OfflineStore] = dataclasses.field(
        default_factory=dict)
    default_context: Optional[ReferenceContext] = None

    def store(self, context: Optional[ReferenceContext] = None) -> OfflineStore:
        """The pattern store for ``context`` (default: the most recently
        built one, matching the old single-store behavior)."""
        if not self.stores:
            raise NotCalibratedError(
                "no offline store — run calibrate() + build_store() first")
        ctx = self.default_context if context is None else context
        if ctx not in self.stores:
            raise StoreMissingError(
                f"no store built for context {ctx}; "
                f"{len(self.stores)} other context(s) available")
        return self.stores[ctx]


class QPARTServer:
    def __init__(self, server_profile: Optional[ServerProfile] = None,
                 levels: Sequence[float] = DEFAULT_ACCURACY_LEVELS,
                 provider: Optional[CostProvider] = None):
        from repro.core.cost_model import AnalyticCost
        self.server = server_profile or ServerProfile()
        self.levels = tuple(levels)
        self.models: Dict[str, ModelState] = {}
        # CostModel v2 (DESIGN.md §9): every online decision prices
        # through the provider. AnalyticCost is the bit-exact default.
        self.provider: CostProvider = provider or AnalyticCost()
        # measurement ledger closing the predict → measure loop
        # (``record_execution`` after ``Deployment.execute``)
        self.ledger = CalibrationLedger()

    # ------------------------------------------------------------------
    def register(self, name: str, backend: ModelBackend,
                 calib_x, calib_y) -> None:
        """Register a model backend + its calibration split."""
        self.models[name] = ModelState(backend, jnp.asarray(calib_x),
                                       jnp.asarray(calib_y))

    def _model(self, name: str) -> ModelState:
        if name not in self.models:
            raise UnknownModelError(name, self.models)
        return self.models[name]

    # ------------------------------------------------------------------
    # Offline phase (Alg. 1)
    def calibrate(self, name: str, probe_bits: int = noise_lib.PROBE_BITS,
                  vectorized: bool = True) -> None:
        """Noise calibration (Alg. 1 steps 7–10): per-layer (s_w, s_x,
        rho) + the Delta(a) budget table. The per-layer probe energies
        come from the backend's ``calibrate_probes`` — by default ONE
        compiled program emitting all L values (chunked ``lax.map`` over
        the "which layer is quantized" index); ``vectorized=False``
        forces the scalar reference loop (``core.noise
        .backend_layer_energies``: 1 full + 2 suffix forwards per layer)
        the vectorized path is regression-locked against."""
        m = self._model(name)
        b = m.backend
        x = m.calib_x

        if vectorized:
            e_w, e_x, logits = b.calibrate_probes(x, probe_bits)
        else:
            e_w, e_x, logits = noise_lib.backend_layer_energies(
                b, x, probe_bits)
        e_w = np.asarray(e_w, np.float64)
        e_x = np.asarray(e_x, np.float64)
        adv_mean = float(jnp.mean(noise_lib.adversarial_noise_energy(logits)))

        n_calib = x.shape[0]
        m.s_w = e_w / n_calib * 4.0 ** probe_bits
        m.s_x = e_x / n_calib * 4.0 ** probe_bits
        # Eq. 22: mean quantization noise / mean adversarial noise
        m.rho = np.maximum((0.5 * (e_w + e_x) / n_calib) / adv_mean, 1e-12)

        m.delta_table, m.base_accuracy = noise_lib.calibrate_delta(
            lambda p, a: b.forward(a, params=p), b.params, x, m.calib_y,
            m.rho, targets=self.levels)

    def build_store(self, name: str, device: DeviceProfile, channel: Channel,
                    weights: ObjectiveWeights) -> ReferenceContext:
        """Alg. 1 proper: precompute {(b_a^p, p)} for one reference
        context. Stores accumulate per context (keyed by the returned
        ``ReferenceContext``); the most recent build becomes the default
        the online phase uses when no context is passed."""
        m = self._model(name)
        if m.delta_table is None:
            raise NotCalibratedError(
                f"model {name!r} has no noise calibration — run calibrate() "
                "before build_store()")
        specs = m.backend.layer_specs()
        ctx = ReferenceContext(device, channel, weights)
        # offline objective coefficients come from the provider: the
        # analytic default prices xi/delta/eps only; roofline/calibrated
        # providers add the memory-traffic coefficients (byte rows from
        # the LayerSpec columns)
        oc = self.provider.offline_coeffs(weights, device, channel,
                                          self.server)
        price_bytes = oc["c_dev_bytes"] != 0.0 or oc["c_srv_bytes"] != 0.0
        m.stores[ctx] = build_offline_store(
            levels=self.levels, budgets=m.delta_table,
            layer_z_w=[sp.z_w for sp in specs],
            layer_z_x=[sp.z_x for sp in specs],
            layer_s_w=m.s_w, layer_s_x=m.s_x, layer_rho=m.rho,
            layer_o=[sp.o for sp in specs],
            xi=oc["xi"], delta_cost=oc["delta"], eps=oc["eps"],
            input_z=m.backend.input_elements(),
            c_dev_bytes=oc["c_dev_bytes"], c_srv_bytes=oc["c_srv_bytes"],
            layer_act_bytes=[sp.act_bytes for sp in specs]
            if price_bytes else None,
            layer_w_bytes16=[sp.w_bytes16 for sp in specs]
            if price_bytes else None)
        m.default_context = ctx
        return ctx

    # ------------------------------------------------------------------
    # Online phase (Alg. 2): plan → deploy (execute lives on Deployment)
    def serve(self, req: InferenceRequest,
              context: Optional[ReferenceContext] = None) -> Deployment:
        m = self._model(req.model)
        store = m.store(context)
        provider = self.provider
        rows = candidate_rows_for(
            m.backend, store, store.level_for(req.accuracy_budget),
            req.batch, bool(req.segment_cached), provider.uses_bytes)
        coeff = provider.coeffs_cached(req.weights, req.device, req.channel,
                                       self.server)
        terms = provider.terms(rows)

        def runtime_objective(plan):
            # candidate index == partition point (level_plans is ordered
            # by p); the generalized obj = sum_k c_k·T_k accumulated in
            # term order, matching the window path float-for-float
            c = plan.p
            obj = coeff[0] * terms[0][c]
            for k in range(1, len(terms)):
                obj = obj + coeff[k] * terms[k][c]
            return obj

        # decode-planned backends additionally hold the device segment's
        # KV cache for the stream's lifetime (None otherwise: the
        # prefill-only feasibility mask is unchanged). ``kv_page_tokens``
        # set -> priced at the stream's page-rounded actual context
        # instead of the max_len worst case (serving.decode.cache)
        if getattr(m.backend, "kv_page_tokens", None) is not None:
            kv_row = m.backend.kv_bytes_row(
                req.batch, tokens=int(m.backend.seq_len)
                + max(int(req.max_new_tokens), 1))
        else:
            kv_row = m.backend.kv_bytes_row(req.batch)

        def feasible(pl):
            kv = float(kv_row[pl.p]) if kv_row is not None else 0.0
            return pl.device_memory_bytes + kv <= req.device.memory_bytes

        try:
            plan = store.lookup(req.accuracy_budget, runtime_objective,
                                feasible_fn=feasible)
        except ValueError:
            raise PlanInfeasibleError(
                f"no stored pattern fits device memory "
                f"{req.device.memory_bytes:.0f} B for model {req.model!r}")
        wire = float(rows.wire[plan.p])
        o1 = float(rows.o1[plan.p])
        o2 = float(rows.o1[-1] - rows.o1[plan.p])
        dev_b, srv_b = rows.bytes_at(plan.p)
        costs = provider.breakdown(o1, o2, wire, req.device, self.server,
                                   req.channel, dev_bytes=dev_b,
                                   srv_bytes=srv_b)
        result = ServingResult(plan=plan, costs=costs,
                               objective=costs.objective(req.weights),
                               payload_bits=wire)
        result.extra["bits_w"] = np.asarray(round_bits(plan.bits_w)) if plan.p else []
        result.extra["bits_x"] = plan.bits_x
        return Deployment(req.model, m.backend, req, plan, result)

    # ------------------------------------------------------------------
    def serve_batch(self, requests: Sequence[InferenceRequest],
                    context: Optional[ReferenceContext] = None,
                    ) -> List[Deployment]:
        """Alg. 2 for a whole request window: price every request against
        the plan table as one objective matrix per model group
        (serving.pricing, shared with WorkloadBalancer) instead of the
        per-request Python loop in ``serve``. Result-for-result identical
        to ``[self.serve(r) for r in requests]``."""
        tab = price_window(self.models, self.server, requests,
                           context=context, provider=self.provider)
        choices = tab.argmin_choices()
        bits_cache: Dict[int, np.ndarray] = {}   # windows share few plans
        out: List[Deployment] = []
        for i, r in enumerate(requests):
            c = int(choices[i])
            plan, o1, o2, wire = tab.select(i, c)
            dev_b, srv_b = tab.rows[i].bytes_at(c)
            # cost of the CHOSEN plan only — one scalar call per request
            # keeps Eq. 5–8 in a single place (the provider's breakdown)
            costs = self.provider.breakdown(o1, o2, wire, r.device,
                                            self.server, r.channel,
                                            dev_bytes=dev_b, srv_bytes=srv_b)
            res = ServingResult(plan=plan, costs=costs,
                                objective=costs.objective(r.weights),
                                payload_bits=wire)
            # same ceil/clip as round_bits, but numpy: no per-request
            # JAX dispatch on the batched path
            # fresh array/list per result, like serve(): no aliasing
            if plan.p:
                if id(plan) not in bits_cache:
                    bits_cache[id(plan)] = np.clip(
                        np.ceil(plan.bits_w), 2, 16).astype(np.int32)
                res.extra["bits_w"] = bits_cache[id(plan)].copy()
            else:
                res.extra["bits_w"] = []
            res.extra["bits_x"] = plan.bits_x
            out.append(Deployment(r.model, self.models[r.model].backend,
                                  r, plan, res))
        return out

    # ------------------------------------------------------------------
    def fleet(self, servers=None, policy="fcfs", slo: str = "observe",
              epoch_interval: float = 0.0,
              provider: Optional[CostProvider] = None, **engine_kwargs):
        """Event-driven fleet serving over this server's registered
        models (serving.engine): ``srv.fleet(servers=[...],
        policy="edf").run(requests)`` — continuous-time arrivals,
        multi-server queues, engine-managed device segment caches,
        deadline-aware admission. With the defaults (one server, plain
        requests) it degenerates to the one-shot ``serve_batch``/
        ``WorkloadBalancer`` behavior. Extra kwargs (``retry``,
        ``faults``, and the §12 scale knobs ``journal``/``records``/
        ``admission``/``reprice_cache``) pass through to
        ``FleetEngine``."""
        from repro.serving.engine import FleetEngine
        return FleetEngine(self, servers=servers, policy=policy, slo=slo,
                           epoch_interval=epoch_interval, provider=provider,
                           **engine_kwargs)

    # ------------------------------------------------------------------
    # CostModel v2 measurement loop (DESIGN.md §9)
    def record_execution(self, deployment: Deployment) -> None:
        """Feed one executed deployment's wall-clock-fenced stage
        timings (``Deployment.execute`` fills
        ``result.extra['measured']``) into the calibration ledger."""
        self.ledger.record(deployment, self.server)

    def record_decode(self, deployment: Deployment) -> None:
        """Feed one streamed generation's aggregate stage timings
        (``Deployment.generate`` fills
        ``result.extra['measured_decode']``) into the same ledger: the
        sample regresses N_tokens × the per-token decode terms, so
        decode and prefill samples sharpen one set of StageRates."""
        self.ledger.record_decode(deployment, self.server)

    def calibrated_provider(self) -> CalibratedCost:
        """Least-squares fit of the ledger → the measurement-calibrated
        provider. Install it (``srv.provider = srv.calibrated_provider()``
        or ``FleetEngine(srv, provider=...)``) to re-price planning and
        fleet reservations from measured rates."""
        return self.ledger.fit()

    # ------------------------------------------------------------------
    def execute_partitioned(self, name: str, plan, x, y) -> float:
        """Really run the two segments of an arbitrary stored plan:
        device side with quantized weights + quantized cut activation,
        server side full precision (convenience over the backend's
        ``execute_plan``; ``Deployment.execute`` is the serving-path
        equivalent)."""
        m = self._model(name)
        logits = m.backend.execute_plan(plan, x)
        return float(jnp.mean(jnp.argmax(logits, -1) == y))
