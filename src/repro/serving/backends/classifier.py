"""``ClassifierBackend`` — the paper's own MLP/CNN evaluation models
behind the ``ModelBackend`` protocol.

This is the code that used to be inlined across ``qpart_server.py`` and
``baselines.py`` (both reaching into ``repro.models.classifier``'s
private ``_apply_layer``/``_ensure_batched``); it now lives here once.

The forward family runs through the shared ``ModelBackend.jitted``
compile cache: ``forward``/``layer_activations`` compile once per input
shape, ``forward_from_layer`` and the device-segment prefix once per
(start/p, input shape) — classifier layer stacks are heterogeneous
(dense/conv), so the resume point stays a static trace parameter, but
L is small (4–6) and the caches make every path compile-once across
requests. ``calibrate_probes`` emits all L Alg. 1 noise energies from a
single compiled program (a ``lax.map`` over the "which layer is
quantized" index, selecting pre-quantized vs clean leaves per layer with
a scalar ``jnp.where``), regression-locked against the scalar loop in
``core.noise.backend_layer_energies``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.classifier import ClassifierConfig, DenseSpec
from repro.core import noise as noise_lib
from repro.core.cost_model import LayerSpec, classifier_layer_specs
from repro.core.partition import DeviceSegment, split_classifier
from repro.core.quantizer import fake_quant
from repro.models.classifier import (apply_layer, classifier_forward,
                                     ensure_batched, forward_from_layer,
                                     layer_activations)
from repro.serving.backends.base import ModelBackend


@dataclasses.dataclass
class ClassifierBackend(ModelBackend):
    """cfg: ClassifierConfig; params: list of per-layer {"w", "b"} dicts
    (``repro.models.classifier.init_classifier``)."""
    cfg: ClassifierConfig
    params: list

    @property
    def num_layers(self) -> int:
        return self.cfg.num_layers

    def layer_specs(self, batch: int = 1,
                    seq_len: Optional[int] = None) -> List[LayerSpec]:
        return self.refine_specs(classifier_layer_specs(self.cfg,
                                                        batch=batch),
                                 batch=batch)

    def input_elements(self) -> float:
        return float(np.prod(self.cfg.input_shape))

    # -- forward family (jitted, shape-keyed) ---------------------------
    def forward(self, x, params=None):
        fn = self.jitted(
            "forward", lambda: lambda p, a: classifier_forward(p, self.cfg, a))
        return fn(self.params if params is None else params, x)

    def forward_from_layer(self, a, start: int, params=None):
        fn = self.jitted(
            ("from_layer", start),
            lambda: lambda p, h: forward_from_layer(p, self.cfg, h, start))
        return fn(self.params if params is None else params, a)

    def layer_activations(self, x, params=None):
        fn = self.jitted(
            "acts", lambda: lambda p, a: layer_activations(p, self.cfg, a))
        return fn(self.params if params is None else params, x)

    def with_layer_quantized(self, layer: int, bits: int):
        noisy = list(self.params)
        noisy[layer] = {k: fake_quant(v, bits)
                        for k, v in self.params[layer].items()}
        return noisy

    # -- vectorized Alg. 1 probes ---------------------------------------
    def calibrate_probes(self, x, probe_bits: int = noise_lib.PROBE_BITS):
        """All L per-layer noise energies from ONE compiled program.

        Classifier activations have per-layer shapes, so instead of
        resuming from stacked activations (the transformer's trick) the
        e_x probe re-runs the forward with ``fake_quant`` injected at
        the entry of the selected layer; the clean side uses the SAME
        masked program with the no-layer sentinel l = -1, so both sides
        of the subtraction share one op sequence."""
        cfg, L = self.cfg, self.cfg.num_layers

        def probe_all(params, xx):
            h0 = ensure_batched(xx, cfg)
            if isinstance(cfg.layers[0], DenseSpec):
                h0 = h0.reshape(h0.shape[0], -1)
            qparams = [jax.tree.map(lambda t: fake_quant(t, probe_bits), p)
                       for p in params]
            logits = classifier_forward(params, cfg, xx)

            def act_quant_logits(l):
                h = h0
                for i, (spec, p) in enumerate(zip(cfg.layers, params)):
                    h = jnp.where(i == l, fake_quant(h, probe_bits), h)
                    h = apply_layer(spec, p, h, last=i == L - 1)
                return h

            clean = act_quant_logits(jnp.int32(-1))

            def probe(l):
                params_l = [jax.tree.map(
                    lambda c, q, i=i: jnp.where(i == l, q, c),
                    params[i], qparams[i]) for i in range(L)]
                d_w = classifier_forward(params_l, cfg, xx) - logits
                e_w = jnp.sum(jnp.square(d_w.astype(jnp.float32)))
                d_x = act_quant_logits(l) - clean
                e_x = jnp.sum(jnp.square(d_x.astype(jnp.float32)))
                return e_w, e_x

            e_w, e_x = jax.lax.map(probe, jnp.arange(L))
            return e_w, e_x, logits

        fn = self.jitted(("probe_all", probe_bits), lambda: probe_all)
        e_w, e_x, logits = fn(self.params, x)
        return np.asarray(e_w, np.float64), np.asarray(e_x, np.float64), \
            logits

    # -- device-segment execution ---------------------------------------
    def run_prefix(self, x, p: int, params=None):
        """Activation leaving layer p when layers 1..p run with ``params``
        (default: the backend's own; a device segment's quantized list or
        a baseline's pruned list both index the same way)."""
        def make():
            def f(prm, a):
                h = ensure_batched(a, self.cfg)
                if isinstance(self.cfg.layers[0], DenseSpec):
                    h = h.reshape(h.shape[0], -1)
                for l in range(p):
                    h = apply_layer(self.cfg.layers[l], prm[l], h,
                                    last=l == self.cfg.num_layers - 1)
                return h
            return f

        fn = self.jitted(("prefix", p), make)
        return fn(self.params if params is None else params, x)

    def split(self, plan) -> DeviceSegment:
        seg, _server = split_classifier(self.params, plan, self.layer_specs())
        return seg

    def run_device_segment(self, seg: DeviceSegment, plan, x):
        h = self.run_prefix(x, plan.p, params=seg.params)
        return fake_quant(h, int(seg.bits_x))
