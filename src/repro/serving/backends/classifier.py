"""``ClassifierBackend`` — the paper's own MLP/CNN evaluation models
behind the ``ModelBackend`` protocol.

This is the code that used to be inlined across ``qpart_server.py`` and
``baselines.py`` (both reaching into ``repro.models.classifier``'s
private ``_apply_layer``/``_ensure_batched``); it now lives here once.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.configs.classifier import ClassifierConfig, DenseSpec
from repro.core.cost_model import LayerSpec, classifier_layer_specs
from repro.core.partition import DeviceSegment, split_classifier
from repro.core.quantizer import fake_quant
from repro.models.classifier import (apply_layer, classifier_forward,
                                     ensure_batched, forward_from_layer,
                                     layer_activations)
from repro.serving.backends.base import ModelBackend


@dataclasses.dataclass
class ClassifierBackend(ModelBackend):
    """cfg: ClassifierConfig; params: list of per-layer {"w", "b"} dicts
    (``repro.models.classifier.init_classifier``)."""
    cfg: ClassifierConfig
    params: list

    @property
    def num_layers(self) -> int:
        return self.cfg.num_layers

    def layer_specs(self, batch: int = 1,
                    seq_len: Optional[int] = None) -> List[LayerSpec]:
        return classifier_layer_specs(self.cfg, batch=batch)

    def input_elements(self) -> float:
        return float(np.prod(self.cfg.input_shape))

    # -- forward family -------------------------------------------------
    def forward(self, x, params=None):
        return classifier_forward(self.params if params is None else params,
                                  self.cfg, x)

    def forward_from_layer(self, a, start: int, params=None):
        return forward_from_layer(self.params if params is None else params,
                                  self.cfg, a, start)

    def layer_activations(self, x, params=None):
        return layer_activations(self.params if params is None else params,
                                 self.cfg, x)

    def with_layer_quantized(self, layer: int, bits: int):
        noisy = list(self.params)
        noisy[layer] = {k: fake_quant(v, bits)
                        for k, v in self.params[layer].items()}
        return noisy

    # -- device-segment execution ---------------------------------------
    def run_prefix(self, x, p: int, params=None):
        """Activation leaving layer p when layers 1..p run with ``params``
        (default: the backend's own; a device segment's quantized list or
        a baseline's pruned list both index the same way)."""
        params = self.params if params is None else params
        h = ensure_batched(x, self.cfg)
        if isinstance(self.cfg.layers[0], DenseSpec):
            h = h.reshape(h.shape[0], -1)
        for l in range(p):
            h = apply_layer(self.cfg.layers[l], params[l], h,
                            last=l == self.cfg.num_layers - 1)
        return h

    def split(self, plan) -> DeviceSegment:
        seg, _server = split_classifier(self.params, plan, self.layer_specs())
        return seg

    def run_device_segment(self, seg: DeviceSegment, plan, x):
        h = self.run_prefix(x, plan.p, params=seg.params)
        return fake_quant(h, int(seg.bits_x))
