"""The ``ModelBackend`` protocol: everything architecture-specific the
QPART serving pipeline needs, behind one interface (DESIGN.md §6).

The serving stack (``QPARTServer``, ``pricing``, ``scheduler``,
``baselines``) is model-agnostic: it speaks plans, costs and accuracy.
A backend owns the model family — its config, parameters, layer-spec
builder, forward functions and the quantized device-segment execution —
so a new architecture plugs into calibrate → build_store → serve by
implementing this class and nothing else.

Conventions shared by all backends:

  * "layers" are the partitionable units (classifier layers, decoder
    blocks). ``layer_specs()[l]`` describes layer ``l+1`` in the paper's
    1-indexed notation; a plan with ``p`` runs layers ``1..p`` on-device.
  * ``forward``-family methods return the logits the accuracy/noise
    calibration probes: shape (batch, num_classes) — for decoder LMs the
    next-token logits at the last position.
  * every forward method accepts a ``params`` override (default: the
    backend's own) so the calibration can probe perturbed weights and the
    baselines can run pruned ones without private model reach-ins.
  * the forward family is jit-compiled through the shared ``jitted``
    compile cache (DESIGN.md §7): compilations are keyed by (function
    key, input shape) — NEVER by partition point or probe layer — and
    counted by ``trace_count``, which tests assert is O(1) in depth.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import noise as noise_lib
from repro.core.cost_model import LayerSpec
from repro.core.partition import DeviceSegment, segment_memory_bytes
from repro.core.solver import PartitionPlan

_EVAL_MEMO_SLOTS = 4         # distinct test sets remembered per backend


class ModelBackend(abc.ABC):
    """Architecture adapter for the QPART serving pipeline."""

    cfg: object          # the family's config dataclass
    params: object       # canonical full-precision parameters

    # -- shared compile cache -------------------------------------------
    # Backends are dataclasses; caches live in __dict__ lazily so
    # subclasses don't have to declare (or hash/compare) them.
    def jitted(self, key, make_fn, **jit_kw):
        """The compiled executable for ``key`` — building and jitting
        ``make_fn()`` on first use. ``jax.jit`` keys recompilation by
        input shape under the hood, so a cache entry is really a family
        of executables keyed (key, input shape): deployments that share
        ``(p, input shape)`` share one compiled program across requests.
        Traces bump ``trace_count`` (the python body runs only when XLA
        traces), giving tests and benchmarks a compile counter."""
        cache = self.__dict__.setdefault("_jit_cache", {})
        if key not in cache:
            fn = make_fn()

            def counted(*a, _fn=fn, **k):
                self.__dict__["_trace_count"] = self.trace_count + 1
                return _fn(*a, **k)

            cache[key] = jax.jit(counted, **jit_kw)
        return cache[key]

    @property
    def trace_count(self) -> int:
        """XLA trace (compilation) count across the backend's jitted
        forward family — O(1) in depth for compile-once backends."""
        return self.__dict__.get("_trace_count", 0)

    # -- structure ------------------------------------------------------
    @property
    @abc.abstractmethod
    def num_layers(self) -> int:
        """Number of partitionable layers L."""

    @abc.abstractmethod
    def layer_specs(self, batch: int = 1,
                    seq_len: Optional[int] = None) -> List[LayerSpec]:
        """(z_w, z_x, o, byte columns) per partitionable layer for a
        request shape. Implementations pass their analytic builder's
        output through ``refine_specs`` so measured per-layer overrides
        (``set_layer_cost_overrides``) apply uniformly."""

    def set_layer_cost_overrides(self, per_layer,
                                 batch: int = 1) -> None:
        """Install measured per-layer cost columns (CostModel v2): a
        list of ``{"o": MACs, "act_bytes": B, "w_bytes16": B}`` dicts —
        e.g. from ``roofline.analysis.layer_costs_from_hlo`` on the
        compiled forward — normalized here by ``batch`` (the shape they
        were measured at) and re-scaled per request batch in
        ``refine_specs``. ``None`` entries / missing keys keep the
        analytic value. Pass ``per_layer=None`` to clear."""
        if per_layer is None:
            self.__dict__.pop("_spec_overrides", None)
            return
        if len(per_layer) != self.num_layers:
            raise ValueError(
                f"need {self.num_layers} per-layer overrides, "
                f"got {len(per_layer)}")
        norm = []
        for ov in per_layer:
            ov = dict(ov or {})
            for k in ("o", "act_bytes"):        # batch-scaled columns
                if k in ov:
                    ov[k] = float(ov[k]) / batch
            norm.append(ov)
        self.__dict__["_spec_overrides"] = norm

    def refine_specs(self, specs: List[LayerSpec],
                     batch: int = 1) -> List[LayerSpec]:
        """Apply installed per-layer cost overrides to an analytic spec
        list (identity when none are installed)."""
        overrides = self.__dict__.get("_spec_overrides")
        if overrides is None:
            return specs
        out = []
        for sp, ov in zip(specs, overrides):
            kw = {}
            if "o" in ov:
                kw["o"] = ov["o"] * batch
            if "act_bytes" in ov:
                kw["act_bytes"] = ov["act_bytes"] * batch
            if "w_bytes16" in ov:
                kw["w_bytes16"] = float(ov["w_bytes16"])
            out.append(dataclasses.replace(sp, **kw) if kw else sp)
        return out

    @abc.abstractmethod
    def input_elements(self) -> float:
        """Elements of one raw input example — what a full offload (p=0)
        uploads at 32 bits (the plan table's ``input_z``)."""

    # -- forward family (calibration + measurement) ---------------------
    @abc.abstractmethod
    def forward(self, x, params=None):
        """Full forward: input batch -> logits (B, C)."""

    @abc.abstractmethod
    def forward_from_layer(self, a, start: int, params=None):
        """Resume from the activation ENTERING layer ``start`` (0-based):
        the server-side tail after a partition at p = start."""

    @abc.abstractmethod
    def layer_activations(self, x, params=None):
        """(activations entering each layer [x_1..x_L], logits)."""

    @abc.abstractmethod
    def with_layer_quantized(self, layer: int, bits: int):
        """Params tree with layer ``layer``'s weights fake-quantized at
        ``bits`` — the Alg. 1 noise probe's perturbed model."""

    # -- autoregressive decode (optional capability) --------------------
    # Token-by-token serving (DESIGN.md §11). Backends without a decode
    # path (classifiers) keep the defaults: ``supports_decode`` False,
    # ``kv_bytes_row`` None (no cache feasibility term is priced in).
    supports_decode: bool = False

    def decode_layer_specs(self, batch: int = 1,
                           context_len: Optional[int] = None) -> List[LayerSpec]:
        """Per-layer specs of ONE decode step against a ``context_len``
        context — the per-token pricing terms (MACs, cache read/write
        bytes, per-token cut payload)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no autoregressive decode path")

    def kv_bytes_row(self, batch: int = 1):
        """(P+1,) cumulative device-resident decode-cache footprint per
        candidate cut, or ``None`` when no cache feasibility term
        applies (non-decode backends, or decode_max_len unset). Priced
        into the ``DeviceProfile.memory_bytes`` mask by ``price_window``
        and ``QPARTServer.serve``."""
        return None

    # -- calibration probes (Alg. 1 steps 7-9) --------------------------
    def calibrate_probes(self, x, probe_bits: int = noise_lib.PROBE_BITS):
        """Per-layer output-noise energies for the Alg. 1 calibration:
        (e_w (L,), e_x (L,), clean logits). e_w[l] is the squared logit
        perturbation from quantizing layer l's WEIGHTS at ``probe_bits``;
        e_x[l] the same for layer l's input ACTIVATION.

        Default: the scalar reference loop (``core.noise
        .backend_layer_energies`` — 1 full + 2 suffix forwards per
        layer). Compile-once backends override with a vectorized probe
        that emits all L energies from a single compiled program;
        overrides are regression-locked against this reference."""
        return noise_lib.backend_layer_energies(self, x, probe_bits)

    # -- quantized device-segment execution -----------------------------
    @abc.abstractmethod
    def split(self, plan: PartitionPlan) -> DeviceSegment:
        """Materialize the quantized device segment (layers 1..p at the
        plan's per-layer bit-widths). The server side keeps the backend's
        own full-precision params."""

    @abc.abstractmethod
    def run_device_segment(self, seg: DeviceSegment, plan: PartitionPlan, x):
        """Run layers 1..p on the quantized segment and return the cut
        activation, quantized at the plan's ``bits_x`` for the uplink."""

    # -- shared logic (family-independent) ------------------------------
    def device_executor(self, plan: PartitionPlan) -> "DeviceExecutor":
        """Callable quantized device segment for ``plan``."""
        return DeviceExecutor(self, plan, self.split(plan))

    def execute_plan(self, plan: PartitionPlan, x,
                     executor: Optional["DeviceExecutor"] = None):
        """Really run the partitioned, quantized model: quantized device
        segment, quantized cut activation, full-precision server tail.
        ``executor`` reuses an already-materialized device segment
        (``Deployment`` passes its cached one)."""
        if plan.p == 0:
            return self.forward(x)
        h = (executor or self.device_executor(plan))(x)
        return self.forward_from_layer(h, plan.p)

    def evaluate(self, x, y, params=None) -> float:
        """Top-1 accuracy of the (full-precision) forward on (x, y).

        Memoized per test-set IDENTITY (the exact array objects) when run
        on the backend's own params: a window of deployments executing
        against one test set pays for the baseline forward once
        (``Deployment.execute`` calls this per deployment)."""
        if params is not None:
            return self._measure(x, y, params)
        memo = self.__dict__.setdefault("_eval_memo", [])
        for mx, my, val in memo:
            if mx is x and my is y:
                return val
        val = self._measure(x, y, self.params)
        memo.append((x, y, val))
        del memo[:-_EVAL_MEMO_SLOTS]
        return val

    def _measure(self, x, y, params) -> float:
        logits = self.forward(x, params=params)
        return float(jnp.mean(jnp.argmax(logits, -1) == y))


@dataclasses.dataclass
class DeviceExecutor:
    """A materialized quantized device segment, callable on inputs: what a
    ``Deployment`` ships to the edge device. ``__call__`` maps a raw input
    batch to the quantized cut activation (the uplink payload). The
    compiled executable behind it comes from the backend's shared
    ``jitted`` cache, so executors for the same (p, input shape) reuse
    one compilation."""
    backend: ModelBackend
    plan: PartitionPlan
    segment: DeviceSegment

    def __call__(self, x):
        return self.backend.run_device_segment(self.segment, self.plan, x)

    @property
    def payload_bits(self) -> float:
        return self.segment.payload_bits

    @property
    def memory_bytes(self) -> float:
        return segment_memory_bytes(self.segment)
