"""Model backends: everything architecture-specific behind one protocol
(DESIGN.md §6). The serving stack is model-agnostic; a backend owns the
family's layer specs, forward functions and quantized device-segment
execution."""
from repro.serving.backends.base import DeviceExecutor, ModelBackend  # noqa: F401
from repro.serving.backends.classifier import ClassifierBackend  # noqa: F401
from repro.serving.backends.transformer import TransformerBackend  # noqa: F401
