"""``TransformerBackend`` — decoder LMs behind the ``ModelBackend``
protocol, so a transformer goes through the SAME calibrate →
``build_store`` → serve pipeline as the paper's classifiers.

Mapping onto the protocol:

  * partitionable layers = the decoder blocks (the embedding table always
    stays on-device — it starts the computation — and is not shipped, so
    it carries no payload term; ``transformer_layer_specs``'s embed row is
    dropped).
  * "logits" = next-token logits at the LAST sequence position, shape
    (B, V): the calibration's adversarial-margin and accuracy math
    (``core.noise``) applies unchanged, with y = the next token.
  * block-by-block execution uses the public non-scan entry points of
    ``repro.models.transformer`` (``embed_tokens`` / ``apply_block`` /
    ``unembed``) — numerically the same math ``forward`` runs under
    ``lax.scan``, needed here because calibration probes and partitioned
    execution address single blocks.

Intended for reduced/small configs on the serving host: the per-block
Python loop trades scan's compile-time depth-independence for block
addressability.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax

from repro.configs.base import ModelConfig
from repro.core.cost_model import LayerSpec, transformer_layer_specs
from repro.core.partition import DeviceSegment, split_blocks
from repro.core.quantizer import fake_quant
from repro.models import rope as rope_lib
from repro.models import transformer as T
from repro.serving.backends.base import ModelBackend


@dataclasses.dataclass
class TransformerBackend(ModelBackend):
    """cfg: ModelConfig; params: ``transformer.init_params`` tree.
    ``seq_len`` is the reference sequence length requests are planned at
    (inputs are token batches of shape (B, seq_len)); ``mode`` follows
    ``transformer_layer_specs`` ("prefill" | "decode")."""
    cfg: ModelConfig
    params: dict
    seq_len: int
    mode: str = "prefill"
    # jitted (embed →) blocks-from-start → last-position logits, keyed by
    # start block (-1 = token input). Calibration probes re-enter these
    # with perturbed params of the SAME pytree structure, so each start
    # traces once.
    _jits: dict = dataclasses.field(default_factory=dict, repr=False,
                                    compare=False)

    @property
    def num_layers(self) -> int:
        return self.cfg.num_layers

    def _logits_fn(self, start: int):
        if start not in self._jits:
            def f(params, a):
                if start < 0:
                    a = T.embed_tokens(params, self.cfg, a)
                h = self._run_blocks(params, a, max(start, 0),
                                     self.num_layers)
                return T.unembed(params, self.cfg, h)[:, -1, :]
            self._jits[start] = jax.jit(f)
        return self._jits[start]

    def layer_specs(self, batch: int = 1,
                    seq_len: Optional[int] = None) -> List[LayerSpec]:
        return transformer_layer_specs(
            self.cfg, seq_len or self.seq_len, batch=batch,
            mode=self.mode)[1:]                      # drop the embed row

    def input_elements(self) -> float:
        return float(self.seq_len)                   # token ids per example

    # -- block-by-block forward family ----------------------------------
    def _positions(self, b: int, s: int):
        return rope_lib.text_positions(b, s)

    def _run_blocks(self, params, h, start: int, stop: int):
        b, s, _ = h.shape
        positions = self._positions(b, s)
        for l in range(start, stop):
            bp, pos = T.block_at(params, self.cfg, l)
            h, _, _ = T.apply_block(bp, self.cfg, pos, h, positions)
        return h

    def forward(self, x, params=None):
        return self._logits_fn(-1)(self.params if params is None else params,
                                   x)

    def forward_from_layer(self, a, start: int, params=None):
        return self._logits_fn(start)(
            self.params if params is None else params, a)

    def layer_activations(self, x, params=None):
        params = self.params if params is None else params
        h = T.embed_tokens(params, self.cfg, x)
        b, s, _ = h.shape
        positions = self._positions(b, s)
        acts = []
        for l in range(self.num_layers):
            acts.append(h)
            bp, pos = T.block_at(params, self.cfg, l)
            h, _, _ = T.apply_block(bp, self.cfg, pos, h, positions)
        return acts, T.unembed(params, self.cfg, h)[:, -1, :]

    def with_layer_quantized(self, layer: int, bits: int):
        plen = T.period_len(self.cfg)
        per, pos = divmod(layer, plen)
        blocks = list(self.params["blocks"])
        blocks[pos] = jax.tree.map(
            lambda t: t.at[per].set(fake_quant(t[per], bits)), blocks[pos])
        return {**self.params, "blocks": blocks}

    # -- device-segment execution ---------------------------------------
    def _device_blocks(self, p: int):
        return [T.block_at(self.params, self.cfg, l)[0] for l in range(p)]

    def split(self, plan) -> DeviceSegment:
        return split_blocks(self._device_blocks(plan.p), plan,
                            self.layer_specs())

    def run_device_segment(self, seg: DeviceSegment, plan, x):
        h = T.embed_tokens(self.params, self.cfg, x)
        b, s, _ = h.shape
        positions = self._positions(b, s)
        for l in range(plan.p):
            pos = l % T.period_len(self.cfg)
            h, _, _ = T.apply_block(seg.params[l], self.cfg, pos, h, positions)
        return fake_quant(h, int(seg.bits_x))
