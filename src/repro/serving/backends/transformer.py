"""``TransformerBackend`` — decoder LMs behind the ``ModelBackend``
protocol, so a transformer goes through the SAME calibrate →
``build_store`` → serve pipeline as the paper's classifiers.

Mapping onto the protocol:

  * partitionable layers = the decoder blocks (the embedding table always
    stays on-device — it starts the computation — and is not shipped, so
    it carries no payload term; ``transformer_layer_specs``'s embed row is
    dropped).
  * "logits" = next-token logits at the LAST sequence position, shape
    (B, V): the calibration's adversarial-margin and accuracy math
    (``core.noise``) applies unchanged, with y = the next token.
  * the whole forward family — ``forward``, ``forward_from_layer`` at
    EVERY resume point, ``layer_activations`` and the quantized
    ``run_device_segment`` — runs on ``transformer.segment_forward``'s
    masked ``lax.scan`` with DYNAMIC ``(start, stop)`` operands: one XLA
    compilation per input shape, not one per split point (DESIGN.md §7).
    The pre-PR-3 design kept a ``_jits`` dict with one jitted unrolled
    block loop per start — O(L) compilations of O(L) traced blocks.
  * ``calibrate_probes`` (Alg. 1 steps 7–9) emits all L per-layer noise
    energies from a single compiled program: a chunked ``lax.map`` over
    the "which layer is quantized" index, selecting the perturbed layer
    by masked ``jnp.where`` on the stacked period axis. Regression-locked
    against the scalar loop in ``core.noise.backend_layer_energies``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import noise as noise_lib
from repro.core.cost_model import (LayerSpec, kv_bytes_row as _kv_row,
                                   transformer_layer_specs)
from repro.core.partition import DeviceSegment, split_blocks
from repro.core.quantizer import fake_quant
from repro.models import transformer as T
from repro.serving.backends.base import ModelBackend
from repro.serving.decode.cache import paged_kv_ctx

PROBE_CHUNK = 4      # layers probed per lax.map step (memory/parallelism)
_STACKED_CACHE_SLOTS = 4     # stacked quantized trees kept per backend


@dataclasses.dataclass
class TransformerBackend(ModelBackend):
    """cfg: ModelConfig; params: ``transformer.init_params`` tree.
    ``seq_len`` is the reference sequence length requests are planned at
    (inputs are token batches of shape (B, seq_len)); ``mode`` follows
    ``transformer_layer_specs`` ("prefill" | "decode")."""
    cfg: ModelConfig
    params: dict
    seq_len: int
    mode: str = "prefill"
    # context length decode streams are planned against (the KV cache is
    # allocated at this length). None = the backend is not planned for
    # decode and no cache-feasibility term is priced in — the prefill-
    # only pricing stays bit-identical.
    decode_max_len: Optional[int] = None
    # KV page size in ring slots (serving.decode.cache). None = legacy
    # worst-case reservation: every stream is priced at decode_max_len
    # context. Set -> admission prices streams at their page-rounded
    # ACTUAL context (prompt + max_new_tokens), admitting streams the
    # worst-case bound wrongly rejects.
    kv_page_tokens: Optional[int] = None

    supports_decode = True

    @property
    def num_layers(self) -> int:
        return self.cfg.num_layers

    def layer_specs(self, batch: int = 1,
                    seq_len: Optional[int] = None) -> List[LayerSpec]:
        specs = transformer_layer_specs(
            self.cfg, seq_len or self.seq_len, batch=batch,
            mode=self.mode)[1:]                      # drop the embed row
        return self.refine_specs(specs, batch=batch)

    def decode_layer_specs(self, batch: int = 1,
                           context_len: Optional[int] = None) -> List[LayerSpec]:
        """ONE decode step's per-layer terms at a ``context_len`` (default
        ``decode_max_len`` or ``seq_len``) context. HLO overrides
        (``set_layer_cost_overrides``) are measured on the PREFILL
        program, so they are deliberately NOT applied here."""
        ctx = context_len or self.decode_max_len or self.seq_len
        return transformer_layer_specs(self.cfg, ctx, batch=batch,
                                       mode="decode")[1:]

    def kv_bytes_row(self, batch: int = 1, tokens: Optional[int] = None):
        """Cumulative device-KV bytes by cut point for ONE decode stream.
        Default: the dense worst case (``decode_max_len`` ring slots per
        attention layer). With ``kv_page_tokens`` set and the stream's
        actual ``tokens`` (prompt + new tokens) given, the stream is
        priced at its page-rounded context instead — strictly <= the
        worst case, so the admission mask can only widen."""
        if self.decode_max_len is None:
            return None
        if tokens is None or self.kv_page_tokens is None:
            ctx = self.decode_max_len
        else:
            ctx = paged_kv_ctx(int(tokens), self.kv_page_tokens,
                               self.decode_max_len)
        cache = self.__dict__.setdefault("_kv_row_cache", {})
        key = (batch, ctx)
        row = cache.get(key)
        if row is None:
            row = cache[key] = _kv_row(
                self.decode_layer_specs(batch, context_len=ctx))
        return row

    def input_elements(self) -> float:
        return float(self.seq_len)                   # token ids per example

    # -- compile-once forward family ------------------------------------
    # Four programs total (ModelBackend.jitted: shape-keyed, trace-
    # counted), each taking the segment bounds as DYNAMIC operands:
    #   tokens_logits  (params, tokens, start, stop) -> (B, V)
    #   h_logits       (params, h,      start, stop) -> (B, V)
    #   acts           (params, tokens)              -> ((L,B,S,D), (B,V))
    #   cut            (params, tokens, stop)        -> (B, S, D)
    # Calibration probes re-enter them with perturbed params of the SAME
    # pytree structure, so the compile count stays O(1) in depth.
    def _tokens_logits(self):
        def f(params, tokens, start, stop):
            h = T.embed_tokens(params, self.cfg, tokens)
            return T.segment_logits(params, self.cfg, h, start, stop)
        return self.jitted("tokens_logits", lambda: f)

    def _h_logits(self):
        def f(params, h, start, stop):
            return T.segment_logits(params, self.cfg, h, start, stop)
        return self.jitted("h_logits", lambda: f)

    def _acts(self):
        def f(params, tokens):
            h = T.embed_tokens(params, self.cfg, tokens)
            h, acts = T.segment_forward(params, self.cfg, h, 0,
                                        self.num_layers, collect=True)
            return acts, T.unembed(params, self.cfg, h)[:, -1, :]
        return self.jitted("acts", lambda: f)

    def _cut(self):
        def f(params, tokens, stop):
            h = T.embed_tokens(params, self.cfg, tokens)
            return T.segment_forward(params, self.cfg, h, 0, stop)
        return self.jitted("cut", lambda: f)

    # -- compile-once decode programs (DESIGN.md §11) --------------------
    # Three more shape-keyed programs serve EVERY cut point of the
    # prefill→decode pipeline — (start, stop, pos) are dynamic operands
    # and the cache tree is an OPERAND (its max_len/dtype shape-key the
    # jit), so the device segment [0, p), the server tail [p, L) and
    # the monolithic [0, L) all reuse one compilation per shape:
    #   embed        (params, tokens)                        -> (B, S, D)
    #   prefill_seg  (params, h, cache0, start, stop)        -> (h, caches)
    #   decode_seg   (params, x, caches, pos, start, stop)   -> (x, caches)
    # Unembedding reuses ``h_logits`` with an EMPTY segment (start ==
    # stop == L): pure final-norm + head, no extra program.
    def _embed_prog(self):
        def f(params, tokens):
            return T.embed_tokens(params, self.cfg, tokens)
        return self.jitted("embed", lambda: f)

    def _prefill_seg(self):
        def f(params, h, cache0, start, stop):
            return T.segment_prefill(params, self.cfg, h, cache0, start,
                                     stop)
        return self.jitted("prefill_seg", lambda: f)

    def _decode_seg(self):
        def f(params, x, caches, pos, start, stop):
            return T.segment_decode_step(params, self.cfg, x, caches, pos,
                                         start, stop)
        return self.jitted("decode_seg", lambda: f)

    # -- chunked-prefill / speculative-verify programs (DESIGN.md §14) --
    # Two more shape-keyed programs with a DYNAMIC position offset, so
    # every chunk of every prompt — and every k-token verify batch —
    # reuses one compilation per (batch, s) shape:
    #   extend_seg  (params, h, caches, pos0, start, stop) -> (h, caches)
    #       chunked prefill: monolithic-prefill formula over the ring
    #   verify_seg  (params, h, caches, pos0, start, stop)
    #                                               -> (logits, caches)
    #       speculative verify: a lax.scan of the EXACT per-token decode
    #       step + unembed — one round trip, bitwise s sequential steps
    def _extend_seg(self):
        def f(params, h, caches, pos0, start, stop):
            return T.segment_extend(params, self.cfg, h, caches, pos0,
                                    start, stop)
        return self.jitted("extend_seg", lambda: f)

    def _verify_seg(self):
        def f(params, h, caches, pos0, start, stop):
            return T.segment_verify(params, self.cfg, h, caches, pos0,
                                    start, stop)
        return self.jitted("verify_seg", lambda: f)

    def embed(self, tokens, params=None):
        return self._embed_prog()(
            self.params if params is None else params, tokens)

    def prefill_segment(self, h, cache0, start, stop, params=None):
        return self._prefill_seg()(
            self.params if params is None else params, h, cache0, start,
            stop)

    def decode_segment(self, x, caches, pos, start, stop, params=None):
        return self._decode_seg()(
            self.params if params is None else params, x, caches, pos,
            start, stop)

    def extend_segment(self, h, caches, pos0, start, stop, params=None):
        """Chunked-prefill extend: blocks ``[start, stop)`` over the
        ``h`` rows entering at position ``pos0``, bitwise the monolithic
        ``segment_prefill`` formula (``T.segment_extend``)."""
        return self._extend_seg()(
            self.params if params is None else params, h, caches, pos0,
            start, stop)

    def verify_segment(self, h, caches, pos0, start, stop, params=None):
        """Speculative verify: the ``s`` drafted rows of ``h`` through
        blocks ``[start, stop)`` + per-row unembed in ONE program ->
        ``(logits (B, S, V), caches)`` — bitwise ``s`` sequential
        ``decode_segment`` + ``hidden_logits`` calls."""
        return self._verify_seg()(
            self.params if params is None else params, h, caches, pos0,
            start, stop)

    def hidden_logits(self, h, params=None):
        """Unembed hidden state ``h`` (B, S, D) -> (B, V) at the last
        position (empty segment of the shared ``h_logits`` program)."""
        return self._h_logits()(
            self.params if params is None else params, h,
            self.num_layers, self.num_layers)

    def forward(self, x, params=None):
        return self._tokens_logits()(
            self.params if params is None else params, x, 0, self.num_layers)

    def forward_from_layer(self, a, start: int, params=None):
        return self._h_logits()(
            self.params if params is None else params, a, start,
            self.num_layers)

    def layer_activations(self, x, params=None):
        acts, logits = self._acts()(
            self.params if params is None else params, x)
        return list(acts), logits

    def with_layer_quantized(self, layer: int, bits: int):
        plen = T.period_len(self.cfg)
        per, pos = divmod(layer, plen)
        blocks = list(self.params["blocks"])
        blocks[pos] = jax.tree.map(
            lambda t: t.at[per].set(fake_quant(t[per], bits)), blocks[pos])
        return {**self.params, "blocks": blocks}

    # -- vectorized Alg. 1 probes ---------------------------------------
    def calibrate_probes(self, x, probe_bits: int = noise_lib.PROBE_BITS,
                         chunk: int = PROBE_CHUNK):
        """All L per-layer noise energies from ONE compiled program.

        The probed model for layer l is selected functionally: every
        block's weights are pre-quantized per period slice (the same
        per-slice ``fake_quant`` as ``with_layer_quantized``) and the
        body of a chunked ``lax.map`` over l picks quantized vs clean
        leaves with a ``jnp.where`` mask on the stacked period axis — no
        per-layer params tree is ever rebuilt on the host. e_x probes
        resume from the stacked activations through the same masked
        segment forward ``forward_from_layer`` runs on."""
        L, plen = self.num_layers, T.period_len(self.cfg)
        nper = T.num_periods(self.cfg)
        cfg = self.cfg

        def probe_all(params, tokens):
            h0 = T.embed_tokens(params, cfg, tokens)
            h, acts = T.segment_forward(params, cfg, h0, 0, L, collect=True)
            logits = T.unembed(params, cfg, h)[:, -1, :]
            qblocks = [jax.tree.map(
                jax.vmap(lambda t: fake_quant(t, probe_bits)), bp)
                for bp in params["blocks"]]

            def probe(l):
                per = l // plen
                blocks_l = []
                for pos in range(plen):
                    sel = (jnp.arange(nper) == per) & (l % plen == pos)
                    blocks_l.append(jax.tree.map(
                        lambda c, q, sel=sel: jnp.where(
                            sel.reshape((nper,) + (1,) * (c.ndim - 1)),
                            q, c),
                        params["blocks"][pos], qblocks[pos]))
                params_l = {**params, "blocks": blocks_l}
                d_w = T.segment_logits(params_l, cfg, h0, 0, L) - logits
                e_w = jnp.sum(jnp.square(d_w.astype(jnp.float32)))
                a = acts[l]
                d_x = T.segment_logits(params, cfg, fake_quant(a, probe_bits),
                                       l, L) \
                    - T.segment_logits(params, cfg, a, l, L)
                e_x = jnp.sum(jnp.square(d_x.astype(jnp.float32)))
                return e_w, e_x

            e_w, e_x = jax.lax.map(probe, jnp.arange(L),
                                   batch_size=min(chunk, L))
            return e_w, e_x, logits

        fn = self.jitted(("probe_all", probe_bits, min(chunk, L)),
                         lambda: probe_all)
        e_w, e_x, logits = fn(self.params, x)
        return np.asarray(e_w, np.float64), np.asarray(e_x, np.float64), \
            logits

    # -- device-segment execution ---------------------------------------
    def _device_blocks(self, p: int):
        return [T.block_at(self.params, self.cfg, l)[0] for l in range(p)]

    def _stack_segment(self, seg_params: list):
        """Scatter the per-layer quantized trees back into the stacked
        period representation (full-precision beyond p — masked out by
        the segment forward's dynamic ``stop``), so the quantized device
        segment runs on the SAME compiled program as everything else."""
        plen = T.period_len(self.cfg)
        blocks = list(self.params["blocks"])
        for l, layer_tree in enumerate(seg_params):
            per, pos = divmod(l, plen)
            blocks[pos] = jax.tree.map(
                lambda full, q, per=per: full.at[per].set(q),
                blocks[pos], layer_tree)
        return {**self.params, "blocks": blocks}

    def split(self, plan) -> DeviceSegment:
        return split_blocks(self._device_blocks(plan.p), plan,
                            self.layer_specs())

    def stacked_for(self, seg: DeviceSegment, plan) -> dict:
        """The quantized segment scattered into a full stacked tree —
        built LAZILY on first execution (split alone — pricing, payload
        and memory queries — never pays for it) and cached per DEPLOYED
        plan on the backend, bounded: deployments sharing a plan (the
        common case — windows price onto few plans) share one copy, and
        N concurrent deployments never hold N model-size trees."""
        key = (plan.p, tuple(int(b) for b in np.asarray(seg.bits_w)),
               int(seg.bits_x))
        cache = self.__dict__.setdefault("_stacked_cache", {})
        if key not in cache:
            while len(cache) >= _STACKED_CACHE_SLOTS:
                cache.pop(next(iter(cache)))
            cache[key] = self._stack_segment(seg.params)
        return cache[key]

    def run_device_segment(self, seg: DeviceSegment, plan, x):
        h = self._cut()(self.stacked_for(seg, plan), x, plan.p)
        return fake_quant(h, int(seg.bits_x))

    # -- quantized-kernel device segment (PR 9) --------------------------
    def qstacked_for(self, seg: DeviceSegment, plan) -> dict:
        """``stacked_for``'s kernel twin: the routed projection/MLP
        weights (``transformer.KERNEL_ROUTED``) are carried as per-period
        quantized WIRE STRUCTS ({codes, scale, mu}) that ``models/``
        dispatch through the dequantize-fused qmatmul/qmatmul4 kernels,
        instead of pre-dequantized dense tensors. dequant(codes)
        reproduces ``split_blocks``' per-layer ``fake_quant`` exactly, so
        the numerics match the dense path up to matmul accumulation
        order. Struct trees key ONE extra jit program per decode entry
        point, but the pytree structure is CUT-INDEPENDENT (codes shapes
        depend only on the model and the packing layout), so the program
        count stays constant across cuts. Plans deploying > 8 bits fall
        back to ``stacked_for`` (the uint8 wire can't carry them)."""
        bits_w = [int(b) for b in np.asarray(seg.bits_w)]
        if any(b > 8 for b in bits_w):
            return self.stacked_for(seg, plan)
        key = (plan.p, tuple(bits_w), int(seg.bits_x))
        cache = self.__dict__.setdefault("_qstacked_cache", {})
        if key not in cache:
            while len(cache) >= _STACKED_CACHE_SLOTS:
                cache.pop(next(iter(cache)))
            cache[key] = self._build_qstacked(int(plan.p), bits_w)
        return cache[key]

    def _build_qstacked(self, p: int, bits_w: list) -> dict:
        """Build the struct tree: for each period position, routed leaves
        become per-period-per-tensor quantized structs at the deployed
        per-layer bit-widths (filler bits for periods beyond the cut —
        masked out by the dynamic ``stop``, values never observed);
        everything else (norms, biases, MoE expert stacks, SSM weights)
        is fake-quantized densely on the ACTIVE periods, mirroring
        ``_stack_segment`` + ``split_blocks`` leaf-for-leaf."""
        plen, nper = T.period_len(self.cfg), T.num_periods(self.cfg)

        def build_pos(pos: int):
            active = np.array([per * plen + pos < p for per in range(nper)])
            abits = [bits_w[per * plen + pos]
                     for per in range(nper) if active[per]]
            pack = bool(abits) and max(abits) <= 4
            fill = 4 if pack else 8
            bits = np.array([bits_w[per * plen + pos] if active[per]
                             else fill for per in range(nper)], np.float64)
            levels = jnp.asarray(2.0 ** bits - 1.0, jnp.float32)
            amask = jnp.asarray(active)

            def meta(leaf):
                axes = tuple(range(1, leaf.ndim))
                shape = (nper,) + (1,) * (leaf.ndim - 1)
                mu = jnp.min(leaf, axis=axes, keepdims=True)
                phi = jnp.max(leaf, axis=axes, keepdims=True)
                lv = levels.reshape(shape)
                scale = jnp.maximum((phi - mu) / lv, 1e-12)
                codes = jnp.clip(jnp.round((leaf - mu) / scale), 0, lv)
                return codes, scale, mu, lv

            def struct(leaf):
                codes, scale, mu, _ = meta(leaf)
                out = {"scale": scale.astype(jnp.float32),
                       "mu": mu.astype(jnp.float32)}
                codes = codes.astype(jnp.uint8)
                if pack and leaf.shape[-1] % 2 == 0:
                    out["codes_packed"] = \
                        codes[..., 0::2] | (codes[..., 1::2] << 4)
                else:
                    out["codes"] = codes
                return out

            def dense_fq(leaf):
                codes, scale, mu, _ = meta(leaf)
                fq = (codes.astype(jnp.float32) * scale
                      + mu).astype(leaf.dtype)
                mask = amask.reshape((nper,) + (1,) * (leaf.ndim - 1))
                return jnp.where(mask, fq, leaf)

            routed = T.KERNEL_ROUTED

            def walk(node, parent=None):
                if isinstance(node, dict):
                    return {k: (struct(v)
                                if parent in routed and k in routed[parent]
                                and not isinstance(v, dict)
                                else walk(v, k))
                            for k, v in node.items()}
                return dense_fq(node)

            return walk(self.params["blocks"][pos])

        return {**self.params,
                "blocks": [build_pos(pos) for pos in range(plen)]}
