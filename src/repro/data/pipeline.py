"""Data pipeline: synthetic token streams (LM training) and a synthetic
MNIST surrogate (the paper's classifier evaluation; the container has no
dataset downloads — DESIGN.md §7).

The token stream is a deterministic PRNG Markov-ish source: a random
low-rank bigram table gives the stream learnable structure, so a ~100M
model's loss visibly drops within a few hundred steps (examples/train_small).

The MNIST surrogate draws 28x28 images as class prototypes + structured
noise; a 6-FC-layer MLP reaches the ~96% band the paper reports on real
MNIST, making the <1% degradation claim testable.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Token stream

@dataclasses.dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    rank: int = 16            # low-rank structure of the transition table
    temperature: float = 1.0
    sharpness: float = 8.0    # logit scale: higher -> lower-entropy stream
    seed: int = 0


class TokenStream:
    """Deterministic, restartable synthetic LM data."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        key = jax.random.key(cfg.seed)
        k1, k2 = jax.random.split(key)
        v, r = cfg.vocab_size, cfg.rank
        self._emb_in = jax.random.normal(k1, (v, r)) / r ** 0.5
        self._emb_out = jax.random.normal(k2, (r, v)) / r ** 0.5

        def sample_batch(key):
            def step(tok, k):
                logits = (self._emb_in[tok] @ self._emb_out) * (
                    cfg.sharpness / cfg.temperature)
                nxt = jax.random.categorical(k, logits)
                return nxt, nxt

            k0, ks = jax.random.split(key)
            first = jax.random.randint(k0, (cfg.batch_size,), 0, v)
            keys = jax.random.split(ks, cfg.seq_len)
            _, toks = jax.lax.scan(step, first, keys)
            return jnp.transpose(toks)          # (B, S)

        self._sample = jax.jit(sample_batch)

    def batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            key = jax.random.fold_in(jax.random.key(self.cfg.seed + 1), step)
            toks = self._sample(key)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            step += 1


# ---------------------------------------------------------------------------
# Synthetic MNIST surrogate

def synthetic_mnist(n_train: int = 8192, n_test: int = 2048, seed: int = 0,
                    noise: float = 1.3) -> Tuple[np.ndarray, ...]:
    """Returns (x_train, y_train, x_test, y_test); images (N, 784) in [0,1]."""
    rng = np.random.default_rng(seed)
    protos = rng.uniform(0.0, 1.0, size=(10, 784)).astype(np.float32)
    # sparsify prototypes so images look digit-like (mostly dark background)
    protos *= (rng.uniform(size=protos.shape) < 0.25)

    def make(n, seed2):
        r = np.random.default_rng(seed2)
        y = r.integers(0, 10, size=n)
        x = protos[y] + noise * r.normal(size=(n, 784)).astype(np.float32)
        # per-class elastic jitter: scale each image randomly
        x *= r.uniform(0.8, 1.2, size=(n, 1)).astype(np.float32)
        return np.clip(x, 0.0, 1.5).astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = make(n_train, seed + 1)
    x_te, y_te = make(n_test, seed + 2)
    return x_tr, y_tr, x_te, y_te


def synthetic_images(input_shape, num_classes: int = 10, n_train: int = 4096,
                     n_test: int = 1024, seed: int = 0,
                     noise: float = 0.45) -> Tuple[np.ndarray, ...]:
    """Class-prototype + noise images of arbitrary shape (the CNN / Table IV
    surrogates: synthetic-SVHN, synthetic-CIFAR)."""
    rng = np.random.default_rng(seed)
    flat = int(np.prod(input_shape))
    protos = rng.uniform(0.0, 1.0, size=(num_classes, flat)).astype(np.float32)
    protos *= (rng.uniform(size=protos.shape) < 0.3)

    def make(n, seed2):
        r = np.random.default_rng(seed2)
        y = r.integers(0, num_classes, size=n)
        x = protos[y] + noise * r.normal(size=(n, flat)).astype(np.float32)
        x = np.clip(x, 0.0, 1.5).astype(np.float32)
        return x.reshape((n,) + tuple(input_shape)), y.astype(np.int32)

    x_tr, y_tr = make(n_train, seed + 1)
    x_te, y_te = make(n_test, seed + 2)
    return x_tr, y_tr, x_te, y_te


def minibatches(x, y, batch: int, seed: int = 0) -> Iterator[Tuple]:
    rng = np.random.default_rng(seed)
    n = len(x)
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            sl = idx[i:i + batch]
            yield jnp.asarray(x[sl]), jnp.asarray(y[sl])
