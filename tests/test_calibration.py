"""Compile-once partitioned execution + vectorized Alg. 1 calibration
(ISSUE 3): the masked segment forward matches the production scan forward
and the old per-start semantics at EVERY resume point, the vectorized
probes are regression-locked against the scalar reference loop in
``core.noise``, and the forward family's XLA compile count is O(1) in
depth (asserted via the backends' trace counter)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.classifier import MNIST_MLP
from repro.core import noise as noise_lib
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.models.classifier import init_classifier
from repro.serving.backends import ClassifierBackend, TransformerBackend
from repro.serving.qpart_server import QPARTServer

SEQ = 12
BATCH = 6


def lm_config(L: int = 4):
    # keep in sync with benchmarks/calibration_bench.py::_bench_cfg — the
    # bench measures the model these tests lock
    return dataclasses.replace(
        get_config("smollm-135m").reduced(), name=f"smollm-cal-L{L}",
        num_layers=L, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=32, tp_pad=1, dtype="float32")


def tokens(rng, cfg, n):
    start = rng.integers(0, cfg.vocab_size, size=(n, 1))
    toks = (start + np.arange(SEQ + 1)[None, :]) % cfg.vocab_size
    return (jnp.asarray(toks[:, :SEQ], jnp.int32),
            jnp.asarray(toks[:, SEQ], jnp.int32))


def make_plan(p: int, bits: float = 8.0) -> PartitionPlan:
    return PartitionPlan(p, np.full(p, bits), bits, 1.0, 0.0, 0.0, {})


@pytest.fixture(scope="module")
def lm():
    cfg = lm_config()
    params = T.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x, y = tokens(rng, cfg, BATCH)
    return cfg, params, x, y


@pytest.fixture(scope="module")
def mlp():
    params = init_classifier(jax.random.key(1), MNIST_MLP)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32, 28, 28)).astype(np.float32))
    y = np.asarray(rng.integers(0, 10, 32))
    return params, x, y


class TestSegmentForward:
    def test_full_range_matches_scan_forward(self, lm):
        cfg, params, x, _ = lm
        ref, _ = T.forward(params, cfg, x)
        h = T.embed_tokens(params, cfg, x)
        out = T.segment_forward(params, cfg, h, 0, cfg.num_layers)
        got = T.unembed(params, cfg, out)[:, -1, :]
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref[:, -1, :]),
                                   rtol=1e-4, atol=1e-5)

    def test_every_start_matches_eager_blocks(self, lm):
        """segment_forward(start, stop) == the eager per-block loop over
        [start, stop) — the old per-start jit family's semantics — for
        EVERY window of a small stack, from one compiled program."""
        cfg, params, x, _ = lm
        L = cfg.num_layers
        from repro.models import rope as rope_lib
        h0 = T.embed_tokens(params, cfg, x)
        b, s, _ = h0.shape
        positions = rope_lib.text_positions(b, s)

        seg = jax.jit(lambda h, a, z: T.segment_forward(params, cfg, h, a, z))
        for start in range(L + 1):
            for stop in range(start, L + 1):
                ref = h0
                for l in range(start, stop):
                    bp, pos = T.block_at(params, cfg, l)
                    ref, _, _ = T.apply_block(bp, cfg, pos, ref, positions)
                got = seg(h0, start, stop)
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5,
                    err_msg=f"window [{start}, {stop})")

    def test_collected_activations_match_layer_entries(self, lm):
        cfg, params, x, _ = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        acts, logits = backend.layer_activations(x)
        assert len(acts) == cfg.num_layers
        # resuming at every collected activation reproduces the logits
        for l in range(cfg.num_layers):
            got = backend.forward_from_layer(acts[l], l)
            np.testing.assert_allclose(np.asarray(got), np.asarray(logits),
                                       rtol=1e-4, atol=1e-5)


class TestVectorizedProbes:
    """Vectorized ``calibrate_probes`` vs the scalar reference loop
    (``core.noise.backend_layer_energies``) — ISSUE 3's regression lock,
    on both backend families."""

    def test_transformer_probes_match_reference(self, lm):
        cfg, params, x, _ = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        e_w_v, e_x_v, lg_v = backend.calibrate_probes(x)
        e_w_r, e_x_r, lg_r = noise_lib.backend_layer_energies(backend, x)
        np.testing.assert_allclose(e_w_v, e_w_r, rtol=2e-2)
        np.testing.assert_allclose(e_x_v, e_x_r, rtol=2e-2)
        np.testing.assert_allclose(np.asarray(lg_v), np.asarray(lg_r),
                                   rtol=1e-5, atol=1e-6)

    def test_classifier_probes_match_reference(self, mlp):
        params, x, _ = mlp
        backend = ClassifierBackend(MNIST_MLP, params)
        e_w_v, e_x_v, _ = backend.calibrate_probes(x)
        e_w_r, e_x_r, _ = noise_lib.backend_layer_energies(backend, x)
        np.testing.assert_allclose(e_w_v, e_w_r, rtol=2e-2)
        np.testing.assert_allclose(e_x_v, e_x_r, rtol=2e-2)

    def test_server_calibrate_vectorized_matches_scalar(self, lm):
        cfg, params, x, y = lm
        stats = {}
        for vectorized in (True, False):
            srv = QPARTServer()
            srv.register("lm", TransformerBackend(cfg, params, seq_len=SEQ),
                         x, y)
            srv.calibrate("lm", vectorized=vectorized)
            m = srv.models["lm"]
            stats[vectorized] = (m.s_w, m.s_x, m.rho)
        for v, r in zip(stats[True], stats[False]):
            np.testing.assert_allclose(v, r, rtol=2e-2)

    def test_probe_chunk_does_not_change_result(self, lm):
        """Chunk size is a memory/parallelism knob, not a semantic one."""
        cfg, params, x, _ = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        e_w_1, e_x_1, _ = backend.calibrate_probes(x, chunk=1)
        e_w_3, e_x_3, _ = backend.calibrate_probes(x, chunk=3)
        np.testing.assert_allclose(e_w_1, e_w_3, rtol=1e-5)
        np.testing.assert_allclose(e_x_1, e_x_3, rtol=1e-5)


class TestCompileOnce:
    """The tentpole's acceptance: XLA compile count for the forward
    family is O(1) in depth. The backends count traces (the python body
    of a jitted function runs only when XLA traces)."""

    def _exercise(self, cfg, params, x):
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        backend.forward(x)
        acts, _ = backend.layer_activations(x)
        for l in range(cfg.num_layers):
            backend.forward_from_layer(acts[l], l)
        for p in range(1, cfg.num_layers + 1):
            backend.execute_plan(make_plan(p), x)
        return backend.trace_count

    def test_transformer_trace_count_depth_independent(self):
        counts = {}
        for L in (2, 6):
            cfg = lm_config(L)
            params = T.init_params(jax.random.key(0), cfg)
            x, _ = tokens(np.random.default_rng(0), cfg, BATCH)
            counts[L] = self._exercise(cfg, params, x)
        # every start and every partition point, from a handful of
        # programs — and the SAME handful at both depths
        assert counts[2] == counts[6] <= 4, counts

    def test_quantized_segment_execution_shares_cut_program(self, lm):
        """Deployments at different partition points share the cut
        program: executing every p adds at most ONE trace (the cut
        program's first compile)."""
        cfg, params, x, _ = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        ref = backend.forward(x)
        before = backend.trace_count
        for p in range(1, cfg.num_layers + 1):
            logits = backend.execute_plan(make_plan(p, bits=16.0), x)
            assert logits.shape == ref.shape
        assert backend.trace_count <= before + 2
        # at generous bit-widths the partitioned model tracks the
        # full-precision one
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                   rtol=0.1, atol=0.1)

    def test_classifier_segment_cache_keyed_by_p(self, mlp):
        params, x, _ = mlp
        backend = ClassifierBackend(MNIST_MLP, params)
        backend.forward(x)
        n0 = backend.trace_count
        for _ in range(3):          # repeat executions reuse compilations
            backend.execute_plan(make_plan(3), x)
        n1 = backend.trace_count
        assert n1 - n0 == 2         # one prefix(p=3) + one from_layer(3)
        backend.execute_plan(make_plan(3), x)
        assert backend.trace_count == n1


class TestEvaluateMemo:
    def test_evaluate_memoized_per_test_set_identity(self, mlp):
        params, x, y = mlp
        backend = ClassifierBackend(MNIST_MLP, params)
        calls = []
        orig = backend._measure

        def spy(xx, yy, prm):
            calls.append(1)
            return orig(xx, yy, prm)

        backend.__dict__["_measure"] = spy    # instance-level override
        a1 = backend.evaluate(x, y)
        a2 = backend.evaluate(x, y)
        assert a1 == a2 and len(calls) == 1   # identity hit
        x2 = jnp.asarray(np.asarray(x))       # equal values, new identity
        backend.evaluate(x2, y)
        assert len(calls) == 2
        # params override is never memoized
        backend.evaluate(x, y, params=params)
        assert len(calls) == 3
