"""Import-or-stub hypothesis so the DETERMINISTIC tests in a module keep
running when hypothesis is not installed (a plain
``pytest.importorskip("hypothesis")`` would skip the whole file).

With hypothesis present (requirements-dev.txt) this re-exports the real
``given``/``settings``/``st``; without it, ``@given`` rewrites the test to
a zero-arg skipper and ``st`` strategies become inert placeholders.
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # replacement without named parameters: pytest must not see
            # the strategy parameters (it would demand fixtures for
            # them); bare *args still receives `self` on test methods
            def skipper(*a):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
