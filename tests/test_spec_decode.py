"""Speculative decode at the cut point + chunked prefill (DESIGN.md
§14): the bitwise lock speculative output == plain greedy output across
cut points and draft lengths, chunked prefill rebuilding the monolithic
KV caches bit for bit at any chunk size, compile-once across prompt
lengths, the gating errors, acceptance-rate pricing plumbing
(``expected_tokens_per_round`` / ledger pooling / chunk pricing rows),
and the fleet engine's PREFILL_CHUNK lane + speculative rounds with
their replay and zero-knob bit-identity contracts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.configs.base import get_config
from repro.core.cost_model import (CalibrationLedger, Channel,
                                   DeviceProfile, ObjectiveWeights,
                                   ServerProfile,
                                   expected_tokens_per_round)
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import DecodeSession
from repro.serving.engine import FleetEngine
from repro.serving.engine.faults import (DISCONNECT, RECONNECT, FaultEvent)
from repro.serving.errors import ServingError
from repro.serving.pricing import candidate_rows_for, prefill_chunk_rows_for
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_transformer_calibration

pytestmark = pytest.mark.smoke

KEY = jax.random.key(0)
SEQ = 16
MAX_LEN = 48
PAGE = 4


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def _manual_plan(p: int, bits: float = 16.0) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), name="smollm-spec",
        d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
        vocab_size=32, tp_pad=1, dtype="float32")
    return cfg, T.init_params(KEY, cfg)


@pytest.fixture(scope="module")
def backend(lm):
    cfg, params = lm
    return TransformerBackend(cfg, params, seq_len=SEQ,
                              decode_max_len=MAX_LEN)


def _prompt(cfg, s=8, b=1, seed=0):
    return np.asarray(jax.random.randint(jax.random.key(seed), (b, s), 0,
                                         cfg.vocab_size))


def _cache_trees_equal(a, b) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    return ta == tb and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


class TestSpecBitIdentity:
    """The lock every test hangs off: speculative decode emits the
    EXACT plain-greedy token sequence — verify-by-scan makes the
    verified rows bit-identical to sequential decode steps, so the
    accepted prefix can never diverge from the greedy trajectory."""

    def _generate(self, backend, p, prompt, n, **kw):
        s = DecodeSession(backend, _manual_plan(p), max_len=MAX_LEN, **kw)
        return s, s.generate(prompt, n)

    def test_spec_equals_greedy_across_cuts_and_k(self, lm, backend):
        cfg, _ = lm
        L = cfg.num_layers
        prompt = _prompt(cfg)
        for p in sorted({0, 1, L // 2, L}):
            _, ref = self._generate(backend, p, prompt, 10)
            for k in (1, 2, 3):
                _, out = self._generate(backend, p, prompt, 10,
                                        draft_tokens=k)
                assert np.array_equal(out.tokens, ref.tokens), \
                    f"spec (p={p}, k={k}) diverged from greedy"
                assert out.draft_tokens == k
                assert out.drafts_proposed > 0

    def test_full_device_cut_accepts_everything(self, lm, backend):
        """At p == L the draft head IS the verify head (the full model
        runs on the device; the server only unembeds), so every draft
        is accepted and rounds shrink as k grows."""
        cfg, _ = lm
        prompt = _prompt(cfg)
        rounds = []
        for k in (1, 2, 3):
            _, out = self._generate(backend, cfg.num_layers, prompt, 10,
                                    draft_tokens=k)
            assert out.accept_rate == 1.0
            rounds.append(out.rounds)
        assert rounds[0] >= rounds[1] >= rounds[2]
        assert rounds[0] > rounds[2]
        assert rounds[2] < 10 - 1   # strictly fewer rounds than tokens

    def test_batched_prompts_stay_greedy(self, lm, backend):
        """Acceptance is the min over batch rows — every row stays on
        its own greedy trajectory even when rows diverge."""
        cfg, _ = lm
        prompt = _prompt(cfg, b=3, seed=5)
        _, ref = self._generate(backend, 1, prompt, 8)
        _, out = self._generate(backend, 1, prompt, 8, draft_tokens=2)
        assert np.array_equal(out.tokens, ref.tokens)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 999), st.integers(1, 4), st.integers(2, 12))
    def test_property_spec_equals_greedy(self, lm, backend, seed, k, n):
        """For ANY seeded prompt, draft length, and generation length,
        speculative output == plain greedy output (cut fixed at 1 — the
        cut sweep is the deterministic test above)."""
        cfg, _ = lm
        prompt = _prompt(cfg, seed=seed)
        _, ref = self._generate(backend, 1, prompt, n)
        _, out = self._generate(backend, 1, prompt, n, draft_tokens=k)
        assert np.array_equal(out.tokens, ref.tokens)


class TestChunkedPrefill:
    def _sessions(self, backend, p, chunk, bits=16.0, **kw):
        mono = DecodeSession(backend, _manual_plan(p, bits),
                             max_len=MAX_LEN, **kw)
        chnk = DecodeSession(backend, _manual_plan(p, bits),
                             max_len=MAX_LEN,
                             prefill_chunk_tokens=chunk, **kw)
        return mono, chnk

    def test_chunk_bounds_folds_remainder_of_one(self):
        assert DecodeSession.chunk_bounds(8, 4) == [(0, 4), (4, 8)]
        assert DecodeSession.chunk_bounds(9, 4) == [(0, 4), (4, 9)]
        assert DecodeSession.chunk_bounds(10, 4) == [(0, 4), (4, 8),
                                                     (8, 10)]
        assert DecodeSession.chunk_bounds(3, 4) == [(0, 3)]
        # no (lo, hi) with hi - lo == 1 for any (s, c >= 2)
        for s in range(2, 20):
            for c in range(2, 8):
                assert all(hi - lo >= 2
                           for lo, hi in DecodeSession.chunk_bounds(s, c))

    def test_chunked_equals_monolithic_tokens(self, lm, backend):
        cfg, _ = lm
        prompt = _prompt(cfg, s=11, seed=3)
        for chunk in (2, 4, 5):
            mono, chnk = self._sessions(backend, 1, chunk)
            ref = mono.generate(prompt, 8)
            out = chnk.generate(prompt, 8)
            assert np.array_equal(out.tokens, ref.tokens)
            assert out.prefill_chunks == len(
                DecodeSession.chunk_bounds(11, chunk))
            assert ref.prefill_chunks == 1

    def test_chunked_rebuilds_caches_bitwise(self, lm, backend):
        """At a lossless device bit-width (32) the chunked prefill must
        rebuild BOTH segment caches bit for bit — same floats, not just
        same argmax."""
        cfg, _ = lm
        prompt = _prompt(cfg, s=13, seed=7)
        for chunk in (2, 4, 6):
            mono, chnk = self._sessions(backend, 1, chunk, bits=32.0)
            t_ref = mono.prefill(prompt)
            t_out = chnk.prefill(prompt)
            assert np.array_equal(np.asarray(t_out), np.asarray(t_ref))
            assert _cache_trees_equal(chnk.dev_caches, mono.dev_caches)
            assert _cache_trees_equal(chnk.srv_caches, mono.srv_caches)

    def test_paged_chunked_ingest_matches_dense(self, lm, backend):
        """Chunk-by-chunk page ingest reproduces the dense ring: the
        paged structure's ``to_dense`` is bitwise the session's live
        dense device cache after a chunked prefill + spec decode."""
        cfg, _ = lm
        prompt = _prompt(cfg, s=12, seed=2)
        s = DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          paged=True, page_tokens=PAGE,
                          prefill_chunk_tokens=PAGE, draft_tokens=2)
        out = s.generate(prompt, 6)
        plain = DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN)
        ref = plain.generate(prompt, 6)
        assert np.array_equal(out.tokens, ref.tokens)
        rebuilt = s.paged_kv.to_dense(s.dev_caches)
        assert _cache_trees_equal(rebuilt, s.dev_caches)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(2, 9), st.integers(4, 14))
    def test_property_any_chunk_size_rebuilds_prefill(self, lm, backend,
                                                      chunk, s_len):
        cfg, _ = lm
        prompt = _prompt(cfg, s=s_len, seed=chunk * 31 + s_len)
        mono, chnk = self._sessions(backend, 1, chunk, bits=32.0)
        t_ref = mono.prefill(prompt)
        t_out = chnk.prefill(prompt)
        assert np.array_equal(np.asarray(t_out), np.asarray(t_ref))
        assert _cache_trees_equal(chnk.srv_caches, mono.srv_caches)


class TestCompileOnce:
    def test_chunked_prefill_is_prompt_length_blind(self, lm, backend):
        """The chunk programs are shape-keyed on the CHUNK length:
        after the first chunked generation, new PROMPT lengths cost
        zero fresh XLA traces — the mechanism that decouples TTFT from
        prompt length (a monolithic prefill re-traces per length)."""
        cfg, _ = lm
        s0 = DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                           prefill_chunk_tokens=4)
        s0.generate(_prompt(cfg, s=8), 3)
        traces = backend.trace_count
        for s_len in (10, 12, 14):
            s = DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                              prefill_chunk_tokens=4)
            s.generate(_prompt(cfg, s=s_len, seed=s_len), 3)
        assert backend.trace_count == traces, \
            "chunked prefill re-traced on a new prompt length"

    def test_spec_rounds_share_programs_across_cuts(self, lm, backend):
        """(start, stop, pos) are dynamic operands of the draft/verify
        programs too: a second speculative session at a DIFFERENT cut
        compiles nothing new."""
        cfg, _ = lm
        prompt = _prompt(cfg)
        s0 = DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                           draft_tokens=2)
        s0.generate(prompt, 6)
        traces = backend.trace_count
        s1 = DecodeSession(backend, _manual_plan(2), max_len=MAX_LEN,
                           draft_tokens=2)
        s1.generate(prompt, 6)
        assert backend.trace_count == traces, \
            "speculative round re-traced on a new cut"


class TestGatesAndGuards:
    def test_ssm_stack_rejects_spec_and_chunking(self):
        cfg = _f32(get_config("mamba2-1.3b").reduced())
        params = T.init_params(KEY, cfg)
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        with pytest.raises(ServingError, match="attention-only"):
            DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          draft_tokens=2)
        with pytest.raises(ServingError, match="attention-only"):
            DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          prefill_chunk_tokens=4)

    def test_sliding_window_rejects_spec_and_chunking(self, lm):
        cfg, params = lm
        cfg = dataclasses.replace(cfg, sliding_window=8)
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        with pytest.raises(ServingError, match="sliding-window"):
            DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          draft_tokens=1)

    def test_bad_knob_values_reject(self, backend):
        with pytest.raises(ServingError, match="draft_tokens"):
            DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          draft_tokens=-1)
        with pytest.raises(ServingError, match=">= 2"):
            DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          prefill_chunk_tokens=1)
        with pytest.raises(ServingError, match="page-aligned"):
            DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN,
                          paged=True, page_tokens=PAGE,
                          prefill_chunk_tokens=PAGE + 1)

    def test_result_guards(self, lm, backend):
        """Degenerate-window guard + per-round accounting: tokens_per_s
        is 0.0 (not a ZeroDivisionError) on a zero-duration window;
        per_token_s stays length new_tokens - 1 when rounds emit >1
        token; accept_rate is None until a draft is proposed."""
        cfg, _ = lm
        s = DecodeSession(backend, _manual_plan(cfg.num_layers),
                          max_len=MAX_LEN, draft_tokens=3)
        out = s.generate(_prompt(cfg), 9)
        assert len(out.per_token_s) == out.new_tokens - 1
        assert out.rounds < out.new_tokens - 1   # amortization happened
        assert np.isclose(sum(out.per_token_s),
                          out.t_total_s - out.ttft_s, rtol=0.2) \
            or out.t_total_s < 1e-3
        zero = dataclasses.replace(out, t_total_s=0.0)
        assert zero.tokens_per_s == 0.0
        plain = DecodeSession(backend, _manual_plan(1), max_len=MAX_LEN)
        ref = plain.generate(_prompt(cfg), 3)
        assert ref.accept_rate is None and ref.rounds == 2


class TestPricingHooks:
    def test_expected_tokens_per_round(self):
        assert expected_tokens_per_round(0, 0.5) == 1.0
        assert expected_tokens_per_round(3, 0.0) == 1.0
        assert expected_tokens_per_round(3, 1.0) == 4.0
        assert expected_tokens_per_round(4, 0.5) == 3.0
        with pytest.raises(ValueError, match="draft_k"):
            expected_tokens_per_round(-1, 0.5)
        with pytest.raises(ValueError, match="accept_rate"):
            expected_tokens_per_round(2, 1.5)

    @pytest.fixture()
    def stub(self):
        cfg = _f32(get_config("smollm-135m").reduced())
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ, decode_max_len=64)
        return srv, (dev, ch, w)

    def test_prefill_chunk_rows(self, stub):
        srv, (dev, ch, w) = stub
        m = srv.models["lm"]
        store = m.store()
        full = candidate_rows_for(m.backend, store, 0.05, 1, False, False)
        chunk = prefill_chunk_rows_for(m.backend, store, 0.05, 1,
                                       chunk_tokens=SEQ // 4,
                                       need_bytes=False)
        assert chunk.o1.shape == full.o1.shape
        assert np.all(np.diff(chunk.o1) >= 0)
        # dense MAC terms are linear in sequence length, the attention
        # term quadratic: n standalone chunk rows lower-bound the
        # monolithic row (the gap is the cross-chunk attention the
        # chunk-local specs cannot see) and stay within the dense-
        # dominated ballpark
        assert np.all(4 * chunk.o1[1:] <= full.o1[1:])
        assert np.all(4 * chunk.o1[1:] >= 0.9 * full.o1[1:])
        assert np.all(4 * chunk.o2[:-1] <= full.o2[:-1])
        with pytest.raises(ValueError, match=">= 2"):
            prefill_chunk_rows_for(m.backend, store, 0.05, 1,
                                   chunk_tokens=1, need_bytes=False)

    def test_ledger_pools_acceptance(self):
        led = CalibrationLedger()
        assert led.mean_accept_rate is None

        class _Dep:
            pass

        for prop, acc in ((4, 2), (6, 6)):
            led.accept_samples.append((float(prop), float(acc)))
        assert led.mean_accept_rate == pytest.approx(8 / 10)

    def test_record_decode_feeds_acceptance(self, lm):
        """The full loop: Deployment.generate with drafts on →
        record_decode → pooled mean_accept_rate → fit() pins it on the
        CalibratedCost the fleet engine resolves its default from."""
        cfg, params = lm
        srv = QPARTServer()
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        toks = np.asarray(jax.random.randint(KEY, (8, SEQ), 0,
                                             cfg.vocab_size))
        srv.register("lm", backend, toks, np.zeros(8, np.int32))
        m = srv.models["lm"]
        L = cfg.num_layers
        m.s_w, m.s_x, m.rho = (np.ones(L), np.ones(L), np.full(L, 0.1))
        m.delta_table = {a: a * 50 for a in srv.levels}
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv.build_store("lm", dev, ch, w)
        dep = srv.serve(InferenceRequest("lm", 0.05, dev, ch, w))
        out = dep.generate(np.zeros((1, 8), np.int32), 6, draft_tokens=2)
        meas = dep.result.extra["measured_decode"]
        assert meas["draft_tokens"] == 2
        assert meas["accept_rate"] == out.accept_rate is not None
        srv.record_decode(dep)
        assert srv.ledger.mean_accept_rate == out.accept_rate
        fit = srv.ledger.fit()
        if fit is not None:
            assert fit.mean_accept_rate == out.accept_rate


class TestFleetSpecChunk:
    def _stub(self, server=None, cap=2e6):
        cfg = _f32(get_config("smollm-135m").reduced())
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=cap)
        w = ObjectiveWeights()
        srv = QPARTServer(server)
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ, decode_max_len=64)
        return srv, (dev, ch, w)

    def _reqs(self, dev, ch, w, n=5, **kw):
        return [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id=f"d{i}", max_new_tokens=20,
                                 **kw)
                for i in range(n)]

    def test_zero_knob_engine_is_bitwise_pr9(self):
        """Explicit default knobs journal EXACTLY what the knob-less
        engine journals — header keys absent, every entry identical —
        and the journal replays."""
        srv, (dev, ch, w) = self._stub()
        reqs = self._reqs(dev, ch, w)
        m0 = FleetEngine(srv).run(reqs)
        m1 = FleetEngine(srv, draft_tokens=0, accept_rate=None,
                         prefill_chunk_tokens=None).run(reqs)
        assert m0.journal.diff(m1.journal) is None
        assert "draft_tokens" not in m0.journal.header
        assert "prefill_chunk_tokens" not in m0.journal.header
        m0.journal.verify_replay(srv, reqs)

    def test_chunked_lane_interleaves_and_replays(self):
        srv, (dev, ch, w) = self._stub()
        reqs = self._reqs(dev, ch, w)
        m = FleetEngine(srv, prefill_chunk_tokens=4).run(reqs)
        m.assert_terminal()
        chunks = [e for e in m.journal.entries
                  if e.kind == "prefill_chunk"]
        ran = [e for e in chunks if dict(e.data).get("stale") is False]
        assert ran, "no chunk rounds executed"
        assert any(dict(e.data).get("last") for e in ran)
        assert m.journal.header["prefill_chunk_tokens"] == 4
        m.journal.verify_replay(srv, reqs)

    def test_chunked_single_request_ttft_pipelines(self):
        """With no lane contention, chunked prefill overlaps transfer
        with server compute: TTFT strictly below the monolithic
        ship→transfer→serve sum."""
        srv, (dev, ch, w) = self._stub()
        req = self._reqs(dev, ch, w, n=1)
        mono = FleetEngine(srv).run(req)
        chnk = FleetEngine(srv, prefill_chunk_tokens=4).run(req)
        assert chnk.records[0].ttft < mono.records[0].ttft
        assert chnk.records[0].tokens_emitted == \
            mono.records[0].tokens_emitted

    def _slow(self):
        """Device-favoring fleet: a slow server pushes the planner to
        p > 0 (the regime where drafting has a round trip to amortize)."""
        slow = ServerProfile(f_clock=1e7)
        srv, (dev, ch, w) = self._stub(server=slow, cap=200e6)
        return srv, slow, (dev, ch, w)

    def test_spec_rounds_amortize_and_replay(self):
        srv, slow, (dev, ch, w) = self._slow()
        reqs = self._reqs(dev, ch, w, n=4)
        m0 = FleetEngine(srv, servers=[slow]).run(reqs)
        assert m0.records[0].deployment.plan.p > 0

        def _rounds(m):
            return sum(1 for e in m.journal.entries
                       if e.kind == "decode_step"
                       and not dict(e.data)["stale"])

        m1 = FleetEngine(srv, servers=[slow], draft_tokens=3,
                         accept_rate=0.8).run(reqs)
        m1.assert_terminal()
        assert _rounds(m1) < _rounds(m0)
        for r0, r1 in zip(m0.records, m1.records):
            assert r1.tokens_emitted == r0.tokens_emitted
        assert m1.journal.header["draft_tokens"] == 3
        assert m1.journal.header["accept_rate"] == 0.8
        m1.journal.verify_replay(srv, reqs, servers=[slow])

    def test_spec_emission_is_deterministic_expected_rate(self):
        """The fractional-accumulator emission hits E[1 + α·k] exactly
        over the stream (no RNG): a 20-token stream at k=3, α=0.8 takes
        ceil(19 / 3.4) + ... rounds — just assert the journaled per-
        round emissions sum to the stream lengths and never exceed
        k + 1."""
        srv, slow, (dev, ch, w) = self._slow()
        reqs = self._reqs(dev, ch, w, n=2)
        m = FleetEngine(srv, servers=[slow], draft_tokens=3,
                        accept_rate=0.8).run(reqs)
        emitted = [dict(e.data)["emitted"]
                   for e in m.journal.entries
                   if e.kind == "decode_step"
                   and not dict(e.data)["stale"]]
        assert emitted and all(
            1 <= v <= 4 for row in emitted for v in row)
        total = sum(v for row in emitted for v in row)
        assert total == sum(r.tokens_emitted - 1 for r in m.records)

    def test_chaos_both_knobs_severs_and_replays(self):
        srv, slow, (dev, ch, w) = self._slow()
        reqs = self._reqs(dev, ch, w, n=4)
        base = FleetEngine(srv, servers=[slow]).run(reqs)
        faults = [FaultEvent(base.horizon / 4, DISCONNECT, "d0"),
                  FaultEvent(base.horizon, RECONNECT, "d0")]
        m = FleetEngine(srv, servers=[slow], draft_tokens=2,
                        accept_rate=0.6, prefill_chunk_tokens=4,
                        faults=faults).run(reqs)
        m.assert_terminal()
        assert not m.dead_letters
        assert sum(int(r.faults) for r in m.records) >= 1
        m.journal.verify_replay(srv, reqs, servers=[slow])

    def test_engine_knob_validation(self):
        srv, _ = self._stub()
        with pytest.raises(ValueError, match="draft_tokens"):
            FleetEngine(srv, draft_tokens=-1)
        with pytest.raises(ValueError, match="accept_rate"):
            FleetEngine(srv, draft_tokens=2, accept_rate=1.5)
        with pytest.raises(ValueError, match="prefill_chunk_tokens"):
            FleetEngine(srv, prefill_chunk_tokens=1)
