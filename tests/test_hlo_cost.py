"""Loop-aware HLO cost parser tests: the roofline numbers are only as good
as this parser, so it gets its own ground-truth suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import (HloCostModel, _type_bytes, analyze_text,
                                     parse_computations)

pytestmark = pytest.mark.smoke


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


class TestTypeParsing:
    def test_type_bytes(self):
        assert _type_bytes("f32[256,256]{1,0}") == 256 * 256 * 4
        assert _type_bytes("bf16[8,16]{1,0}") == 8 * 16 * 2
        assert _type_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
        assert _type_bytes("pred[]") == 1


class TestFlops:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        c = _compile(lambda x, y: x @ y, a, b)
        s = analyze_text(c.as_text())
        expect = 2 * 128 * 256 * 64
        assert abs(s.flops - expect) / expect < 0.05

    def test_scan_scales_by_trip_count(self):
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        c = _compile(f, a, a)
        s = analyze_text(c.as_text())
        expect = 7 * 2 * 128 ** 3
        assert abs(s.flops - expect) / expect < 0.05

    def test_nested_scans_multiply(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def f(x, w):
            def outer(h, _):
                def inner(g, _):
                    return g @ w, None
                g, _ = jax.lax.scan(inner, h, None, length=3)
                return g, None
            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        c = _compile(f, a, a)
        s = analyze_text(c.as_text())
        expect = 15 * 2 * 64 ** 3
        assert abs(s.flops - expect) / expect < 0.05

    def test_grad_of_scan_counts_fwd_plus_bwd(self):
        a = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

        def loss(params, xx):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, xx, params)
            return jnp.sum(h * h)

        c = _compile(lambda p, xx: jax.grad(loss)(p, xx), a, x)
        s = analyze_text(c.as_text())
        expect = 3 * 4 * 2 * 32 * 64 * 64      # fwd + dgrad + wgrad
        assert 0.8 < s.flops / expect < 1.3


class TestCollectives:
    def test_tp_matmul_psum(self):
        import os
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (run via dryrun env for full check)")

    def test_collective_parsing_from_text(self):
        text = """
HloModule m

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%a), to_apply=%add
}
"""
        s = analyze_text(text)
        assert s.collectives.get("all-reduce") == 64 * 64 * 4

    def test_while_scales_collectives(self):
        text = """
HloModule m

%body (t: (s32[], f32[32])) -> (s32[], f32[32]) {
  %t = (s32[], f32[32]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[32]{0} get-tuple-element(%t), index=1
  %ag = f32[32]{0} all-gather(%x), dimensions={0}
  ROOT %r = (s32[], f32[32]{0}) tuple(%i, %ag)
}

%cond (t: (s32[], f32[32])) -> pred[] {
  %t = (s32[], f32[32]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[32]) -> f32[32] {
  %a = f32[32]{0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[32]{0}) tuple(%z, %a)
  %w = (s32[], f32[32]{0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"9"}}
  ROOT %o = f32[32]{0} get-tuple-element(%w), index=1
}
"""
        s = analyze_text(text)
        assert s.collectives.get("all-gather") == 9 * 32 * 4


class TestLayerAttribution:
    def test_scan_body_attributed_per_layer(self):
        """A depth-scanned stack attributes one loop-body cost per
        layer; the residual is everything outside the layer loop."""
        from repro.roofline.hlo_cost import layer_attribution
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        c = _compile(f, a, a)
        per_layer, residual = layer_attribution(c.as_text(), 7)
        assert len(per_layer) == 7
        expect = 2 * 128 ** 3
        assert abs(per_layer[0].flops - expect) / expect < 0.05
        total = analyze_text(c.as_text())
        assert 7 * per_layer[0].flops + residual.flops == \
            pytest.approx(total.flops)
        assert per_layer[0].bytes > 0

    def test_layer_costs_subtract_weight_stream(self):
        """``layer_w_bytes`` removes the batch-invariant weight-stream
        reads from the batch-scaled act_bytes column (it is priced
        separately by the byte-term rows)."""
        from repro.roofline.analysis import layer_costs_from_hlo
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        c = _compile(f, a, a)
        w_bytes = 128 * 128 * 4
        base = layer_costs_from_hlo(c.as_text(), 7)
        sub = layer_costs_from_hlo(c.as_text(), 7,
                                   layer_w_bytes=[w_bytes] * 7)
        assert sub[0]["act_bytes"] == pytest.approx(
            base[0]["act_bytes"] - w_bytes)
        assert sub[0]["o"] == base[0]["o"]

    def test_no_matching_loop_splits_evenly(self):
        from repro.roofline.hlo_cost import layer_attribution
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = _compile(lambda x: x @ x, a)
        per_layer, residual = layer_attribution(c.as_text(), 3)
        total = analyze_text(c.as_text())
        assert per_layer[0].flops == pytest.approx(total.flops / 3)
        assert residual.flops == 0.0

    def test_backend_spec_overrides_rescale_by_batch(self):
        """layer_costs_from_hlo → set_layer_cost_overrides replaces the
        analytic o/act_bytes columns, normalized to the measured batch
        and re-scaled per request batch."""
        from repro.serving.backends import ClassifierBackend
        from repro.configs.classifier import MNIST_MLP
        b = ClassifierBackend(MNIST_MLP, None)
        L = b.num_layers
        per_layer = [{"o": 1000.0 * (i + 1), "act_bytes": 64.0 * (i + 1)}
                     for i in range(L)]
        b.set_layer_cost_overrides(per_layer, batch=4)
        specs1 = b.layer_specs(batch=4)
        assert [sp.o for sp in specs1] == [o["o"] for o in per_layer]
        specs2 = b.layer_specs(batch=8)
        assert specs2[0].o == pytest.approx(2 * specs1[0].o)
        # z_w / payload math untouched
        assert specs2[0].z_w == specs1[0].z_w
        b.set_layer_cost_overrides(None)
        assert b.layer_specs(batch=4)[0].o != specs1[0].o


class TestStructure:
    def test_parse_computations_finds_entry(self):
        def f(x):
            return jnp.sum(x * x)
        c = _compile(f, jax.ShapeDtypeStruct((64,), jnp.float32))
        comps, entry = parse_computations(c.as_text())
        assert entry in comps
        assert len(comps) >= 1

    def test_fusion_bytes_at_boundary_only(self):
        """A fused elementwise chain charges boundary bytes, not per-op."""
        def f(x):
            return jnp.tanh(jnp.exp(x) * 2.0 + 1.0)
        c = _compile(f, jax.ShapeDtypeStruct((1024,), jnp.float32))
        s = analyze_text(c.as_text())
        # boundary = in + out = 2 * 4KB (+ small constants); allow 3x slack
        assert s.bytes < 3 * 2 * 1024 * 4
