"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in ref.py (per-kernel allclose, deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import quantize
from repro.kernels import ops, ref
from repro.kernels.qmatmul import qmatmul4_pallas, qmatmul_pallas
from repro.kernels.quantize import (dequantize_pallas, quantize_pack4_pallas,
                                    quantize_pallas)

KEY = jax.random.key(0)

SHAPES = [(128, 128), (256, 512), (512, 256), (1024, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _w(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.key(seed), shape, dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
class TestQuantizeKernel:
    def test_quantize_matches_ref(self, shape, bits):
        x = _w(shape)
        codes, scale, mu = quantize(x, bits)
        k = quantize_pallas(x, scale, mu, bits, interpret=True)
        r = ref.quantize_ref(x, scale, mu, bits)
        # round-to-nearest ties can differ by 1 ulp across impls; demand
        # exactness here since both use jnp.round
        np.testing.assert_array_equal(np.asarray(k), np.asarray(r))

    def test_dequantize_matches_ref(self, shape, bits):
        x = _w(shape)
        codes, scale, mu = quantize(x, bits)
        codes8 = codes.astype(jnp.uint8)
        k = dequantize_pallas(codes8, scale, mu, jnp.float32, interpret=True)
        r = ref.dequantize_ref(codes8, scale, mu, jnp.float32)
        np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 512, 256),
                                 (64, 1024, 128), (512, 256, 512)])
@pytest.mark.parametrize("xdtype", DTYPES)
class TestQMatmulKernel:
    def test_w8_matches_ref(self, mkn, xdtype):
        m, k, n = mkn
        x = _w((m, k), xdtype)
        codes, scale, mu = quantize(_w((k, n), seed=1), 8)
        codes8 = codes.astype(jnp.uint8)
        out_k = qmatmul_pallas(x, codes8, scale, mu, jnp.float32,
                               interpret=True)
        out_r = ref.qmatmul_ref(x, codes8, scale, mu, jnp.float32)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-2 if xdtype == jnp.bfloat16 else 1e-4,
                                   atol=1e-2)

    def test_w4_matches_ref(self, mkn, xdtype):
        m, k, n = mkn
        x = _w((m, k), xdtype)
        codes, scale, mu = quantize(_w((k, n), seed=2), 4)
        packed = ref.pack_int4_ref(codes)
        out_k = qmatmul4_pallas(x, packed, scale, mu, jnp.float32,
                                interpret=True)
        out_r = ref.qmatmul4_ref(x, packed, scale, mu, jnp.float32)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-2 if xdtype == jnp.bfloat16 else 1e-4,
                                   atol=1e-2)


def _per_channel_qparams(w, bits):
    """Per-output-column asymmetric grid (Eq. 9–10 at channel granularity)."""
    mu = jnp.min(w, axis=0, keepdims=True)
    phi = jnp.max(w, axis=0, keepdims=True)
    levels = (1 << bits) - 1
    scale = jnp.maximum((phi - mu) / levels, 1e-12)
    codes = jnp.clip(jnp.round((w - mu) / scale), 0, levels)
    return codes, scale, mu


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 512, 256),
                                 (64, 1024, 128)])
class TestPerChannelQMatmul:
    """Per-output-column scale/zero blocks streamed through VMEM: the
    kernels must match the jnp oracle and consume quantize_stacked's
    per-period metadata without reformatting (DESIGN.md §4)."""

    def test_w8_per_channel_matches_oracle(self, mkn):
        m, k, n = mkn
        x = _w((m, k))
        codes, scale, mu = _per_channel_qparams(_w((k, n), seed=11), 8)
        codes8 = codes.astype(jnp.uint8)
        out_k = qmatmul_pallas(x, codes8, scale, mu, jnp.float32,
                               interpret=True)
        out_r = ref.qmatmul_ref(x, codes8, scale, mu, jnp.float32)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=5e-4)

    def test_w4_per_channel_matches_oracle(self, mkn):
        m, k, n = mkn
        x = _w((m, k))
        codes, scale, mu = _per_channel_qparams(_w((k, n), seed=12), 4)
        packed = ref.pack_int4_ref(codes)
        out_k = qmatmul4_pallas(x, packed, scale, mu, jnp.float32,
                                interpret=True)
        out_r = ref.qmatmul4_ref(x, packed, scale, mu, jnp.float32)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=5e-4)


class TestQuantizeStackedToKernel:
    """The serving wire format (core.quantizer.quantize_stacked) plugs
    straight into the kernels: a period slice of codes/scale/mu is a
    valid argument triple."""

    def test_int8_period_slice(self):
        from repro.core.quantizer import quantize_stacked
        x = _w((128, 512))
        w3 = _w((3, 512, 256), seed=13)
        q = quantize_stacked(w3, 8)
        assert q["scale"].shape == (3, 1, 256)          # per-period+channel
        for i in (0, 2):
            out_k = qmatmul_pallas(x, q["codes"][i], q["scale"][i],
                                   q["mu"][i], jnp.float32, interpret=True)
            out_r = ref.qmatmul_ref(x, q["codes"][i], q["scale"][i],
                                    q["mu"][i], jnp.float32)
            np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                       rtol=1e-5, atol=5e-4)

    def test_int4_period_slice(self):
        from repro.core.quantizer import quantize_stacked
        x = _w((128, 512))
        w3 = _w((2, 512, 256), seed=14)
        q = quantize_stacked(w3, 4)
        out_k = qmatmul4_pallas(x, q["codes_packed"][1], q["scale"][1],
                                q["mu"][1], jnp.float32, interpret=True)
        out_r = ref.qmatmul4_ref(x, q["codes_packed"][1], q["scale"][1],
                                 q["mu"][1], jnp.float32)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=5e-4)

    def test_per_tensor_metadata_still_accepted(self):
        from repro.core.quantizer import quantize_stacked
        x = _w((128, 256))
        w3 = _w((2, 256, 128), seed=15)
        q = quantize_stacked(w3, 8, per_channel=False)
        assert q["scale"].shape == (2, 1, 1)
        out_k = qmatmul_pallas(x, q["codes"][0], q["scale"][0], q["mu"][0],
                               jnp.float32, interpret=True)
        out_r = ref.qmatmul_ref(x, q["codes"][0], q["scale"][0], q["mu"][0],
                                jnp.float32)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=5e-4)


class TestFusedQuantizePack:
    """quantize_pack4_pallas = Eq. 10 + nibble packing in one VMEM pass;
    must equal quantize_stacked's wire bytes and the jnp oracle."""

    def test_matches_quantize_stacked_and_ref(self):
        from repro.core.quantizer import quantize_stacked
        leaf = _w((2, 128, 256), seed=16)
        q = quantize_stacked(leaf, 4)                    # jnp path (cpu)
        for i in range(2):
            fused = quantize_pack4_pallas(leaf[i], q["scale"][i], q["mu"][i],
                                          interpret=True)
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(q["codes_packed"][i]))
            np.testing.assert_array_equal(
                np.asarray(fused),
                np.asarray(ref.quantize_pack4_ref(leaf[i], q["scale"][i],
                                                  q["mu"][i])))

    def test_per_tensor_scale(self):
        x = _w((128, 128), seed=17)
        codes, scale, mu = quantize(x, 4)
        fused = quantize_pack4_pallas(x, scale, mu, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(fused), np.asarray(ref.quantize_pack4_ref(x, scale, mu)))

    def test_quantize_stacked_pallas_path_agrees(self):
        from repro.core.quantizer import quantize_stacked
        leaf = _w((3, 256, 512), seed=18)
        jnp_path = quantize_stacked(leaf, 4, use_pallas=False)
        pallas_path = quantize_stacked(leaf, 4, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(pallas_path["codes_packed"]),
                                      np.asarray(jnp_path["codes_packed"]))

    def test_wrapper_dispatch(self):
        x = _w((128, 256), seed=19)
        codes, scale, mu = quantize(x, 4)
        a = ops.quantize_pack4(x, scale, mu, use_pallas=True)
        b = ops.quantize_pack4(x, scale, mu, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPerChannelQuantizeKernels:
    def test_quantize_dequantize_per_channel(self):
        w = _w((256, 512), seed=20)
        codes, scale, mu = _per_channel_qparams(w, 8)
        codes8 = codes.astype(jnp.uint8)
        k = quantize_pallas(w, scale, mu, 8, interpret=True)
        np.testing.assert_array_equal(np.asarray(k),
                                      np.asarray(ref.quantize_ref(w, scale,
                                                                  mu, 8)))
        d = dequantize_pallas(codes8, scale, mu, jnp.float32, interpret=True)
        np.testing.assert_allclose(
            np.asarray(d),
            np.asarray(ref.dequantize_ref(codes8, scale, mu, jnp.float32)),
            rtol=1e-5, atol=1e-6)


class TestPacking:
    def test_pack_unpack_roundtrip(self):
        codes = jax.random.randint(KEY, (64, 128), 0, 16)
        packed = ref.pack_int4_ref(codes)
        assert packed.shape == (64, 64)
        un = ref.unpack_int4_ref(packed)
        np.testing.assert_array_equal(np.asarray(un), np.asarray(codes))


class TestOpsWrappers:
    """The jit'd public wrappers dispatch pallas-vs-ref equivalently."""

    def test_qmatmul_wrapper_both_paths_agree(self):
        x = _w((256, 512))
        codes, scale, mu = quantize(_w((512, 256), seed=3), 8)
        codes8 = codes.astype(jnp.uint8)
        a = ops.qmatmul(x, codes8, scale, mu, out_dtype=jnp.float32,
                        use_pallas=True)
        b = ops.qmatmul(x, codes8, scale, mu, out_dtype=jnp.float32,
                        use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_block_boundary_shapes(self):
        """Shapes exactly at / above one default block."""
        for m, k, n in [(256, 512, 256), (512, 1024, 512)]:
            x = _w((m, k))
            codes, scale, mu = quantize(_w((k, n), seed=4), 8)
            out = qmatmul_pallas(x, codes.astype(jnp.uint8), scale, mu,
                                 jnp.float32, interpret=True)
            assert out.shape == (m, n)

    def test_quantized_error_shrinks_with_bits(self):
        """End-to-end: W8 matmul error < W4 matmul error (noise law at the
        kernel level)."""
        x = _w((128, 256))
        w = _w((256, 128), seed=5)
        exact = x @ w
        c8, s8, m8 = quantize(w, 8)
        c4, s4, m4 = quantize(w, 4)
        e8 = float(jnp.mean(jnp.abs(
            ref.qmatmul_ref(x, c8, s8, m8, jnp.float32) - exact)))
        e4 = float(jnp.mean(jnp.abs(
            ref.qmatmul4_ref(x, ref.pack_int4_ref(c4), s4, m4, jnp.float32)
            - exact)))
        assert e8 < e4


class TestFlashAttentionKernel:
    """Pallas causal flash attention vs the blocked-attention oracle:
    shape/dtype sweep, exactness of the causal-block skip, GQA index map."""

    @pytest.mark.parametrize("cfg", [
        (2, 256, 2, 2, 64, 128, 128),    # GQA, two kv groups
        (1, 512, 4, 1, 128, 256, 128),   # MQA-ish, hd 128, asym blocks
        (2, 128, 1, 3, 64, 64, 64),      # single kv group, 3 q heads
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_blocked_oracle(self, cfg, dtype):
        from repro.kernels.flash_attention import flash_attention
        from repro.models.attention import _blocked_causal_attention
        b, s, kv, g, hd, bq, bk = cfg
        q = _w((b, s, kv, g, hd), dtype, seed=1)
        k = _w((b, s, kv, hd), dtype, seed=2)
        v = _w((b, s, kv, hd), dtype, seed=3)
        out_k = flash_attention(q, k, v, block_q=bq, block_k=bk,
                                interpret=True)
        out_r = _blocked_causal_attention(q, k, v, bq, bk)
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            atol=5e-2 if dtype == jnp.bfloat16 else 1e-5,
            rtol=5e-2 if dtype == jnp.bfloat16 else 1e-5)

    def test_causality(self):
        """Changing a future token never changes an earlier output row."""
        from repro.kernels.flash_attention import flash_attention
        b, s, kv, g, hd = 1, 128, 1, 1, 64
        q = _w((b, s, kv, g, hd), seed=4)
        k = _w((b, s, kv, hd), seed=5)
        v = _w((b, s, kv, hd), seed=6)
        out1 = flash_attention(q, k, v, block_q=64, block_k=64,
                               interpret=True)
        k2 = k.at[:, -1].add(10.0)
        v2 = v.at[:, -1].add(10.0)
        out2 = flash_attention(q, k2, v2, block_q=64, block_k=64,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                                   np.asarray(out2[:, :-1]), atol=1e-6)
        assert float(jnp.max(jnp.abs(out1[:, -1] - out2[:, -1]))) > 1e-3
