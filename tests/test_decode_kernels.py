"""Kernel-grade decode (PR 9): the single-query flash-attention decode
kernel against the jnp oracle (shape/dtype/GQA sweep incl. float8 cache
storage), the ``REPRO_KERNELS`` dispatch contract, the quantized dense
contraction ``ops.qdense`` against fake-quant matmuls, and the
quantized-kernel device segment (``qstacked_for`` wire structs through
``segment_decode_step``) matching the dense fake-quant path on the SAME
compile-once program budget across cuts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.quantizer import fake_quant, quantize_stacked
from repro.core.solver import PartitionPlan
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import DecodeSession

pytestmark = pytest.mark.smoke

KEY = jax.random.key(0)
SEQ = 16
MAX_LEN = 48

# locked parity tolerances: interpret-mode kernel vs the jnp oracle
TOL_F32 = 2e-6
TOL_BF16 = 2e-2


def _manual_plan(p: int, bits: float = 16.0) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


def _qkv(key, b, buf, kvp, gp, hd, dtype, cache_dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, kvp, gp, hd), dtype)
    ck = jax.random.normal(kk, (b, buf, kvp, hd), dtype).astype(cache_dtype)
    cv = jax.random.normal(kv, (b, buf, kvp, hd), dtype).astype(cache_dtype)
    return q, ck, cv


class TestDecodeAttentionKernel:
    """Interpret-mode Pallas kernel == jnp oracle across the layout
    sweep the serving path produces."""

    @pytest.mark.parametrize("b,kvp,gp,buf,hd", [
        (2, 4, 1, 64, 128),      # MHA (group of 1)
        (1, 2, 4, 64, 64),       # GQA
        (2, 1, 8, 128, 64),      # MQA-ish: one KV head, wide group
        (1, 4, 2, 256, 64),      # multi-block ring (nk > 1)
    ])
    def test_parity_shapes(self, b, kvp, gp, buf, hd):
        q, ck, cv = _qkv(KEY, b, buf, kvp, gp, hd, jnp.float32, jnp.float32)
        for pos in (0, 3, buf - 1, buf + 7, 5 * buf + 1):
            want = ref.decode_attention_ref(q, ck, cv, pos)
            got = decode_attention_pallas(q, ck, cv, pos, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=TOL_F32, rtol=0)

    @pytest.mark.parametrize("cache_dtype,tol", [
        (jnp.bfloat16, TOL_BF16),
        (jnp.float8_e4m3fn, TOL_BF16),
    ], ids=["bf16", "float8"])
    def test_parity_quantized_cache_dtypes(self, cache_dtype, tol):
        """The deployed-bit-width cache storage dtypes (float8 for <= 8
        device bits) go through the kernel's f32 upcast exactly like the
        oracle's."""
        q, ck, cv = _qkv(KEY, 2, 64, 2, 2, 64, jnp.float32, cache_dtype)
        for pos in (5, 63, 100):
            want = ref.decode_attention_ref(q, ck, cv, pos)
            got = decode_attention_pallas(q, ck, cv, pos, interpret=True)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                atol=tol, rtol=0)

    def test_bf16_query_parity(self):
        q, ck, cv = _qkv(KEY, 1, 64, 2, 2, 64, jnp.bfloat16, jnp.bfloat16)
        want = ref.decode_attention_ref(q, ck, cv, 40)
        got = decode_attention_pallas(q, ck, cv, 40, interpret=True)
        assert got.dtype == q.dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL_BF16, rtol=0)

    def test_partial_ring_masks_unwritten_slots(self):
        """pos + 1 < buf: garbage beyond the write head must not leak
        into the softmax (validity mask, not zero-padding)."""
        q, ck, cv = _qkv(KEY, 1, 64, 1, 2, 64, jnp.float32, jnp.float32)
        poisoned_k = ck.at[:, 10:].set(1e4)      # pos=9 -> slots 10+ dead
        poisoned_v = cv.at[:, 10:].set(1e4)
        want = ref.decode_attention_ref(q, ck, cv, 9)
        got = decode_attention_pallas(q, poisoned_k, poisoned_v, 9,
                                      interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=TOL_F32, rtol=0)


class TestKernelModeDispatch:
    def test_auto_is_reference_off_tpu(self, monkeypatch):
        monkeypatch.delenv("REPRO_KERNELS", raising=False)
        expected = "kernel" if jax.default_backend() == "tpu" \
            else "reference"
        assert ops.kernel_mode() == expected

    @pytest.mark.parametrize("mode", ops.KERNEL_MODES[1:])
    def test_explicit_modes(self, mode, monkeypatch):
        monkeypatch.setenv("REPRO_KERNELS", mode)
        assert ops.kernel_mode() == mode

    def test_invalid_mode_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNELS", "mosaic")
        with pytest.raises(ValueError, match="REPRO_KERNELS"):
            ops.kernel_mode()

    def test_dispatch_routes_to_oracle(self, monkeypatch):
        """reference mode and interpret mode agree through the public
        entry point — the lane flip changes execution, not values."""
        q, ck, cv = _qkv(KEY, 1, 64, 2, 2, 64, jnp.float32, jnp.float32)
        monkeypatch.setenv("REPRO_KERNELS", "reference")
        a = np.asarray(ops.decode_attention(q, ck, cv, 17))
        monkeypatch.setenv("REPRO_KERNELS", "interpret")
        b = np.asarray(ops.decode_attention(q, ck, cv, 17))
        np.testing.assert_allclose(a, b, atol=TOL_F32, rtol=0)


class TestQDense:
    """ops.qdense == x @ dequant(struct) for every wire layout the
    stacked quantizer emits."""

    def _struct_and_dense(self, key, shape, bits, per_channel):
        w = jax.random.normal(key, (1,) + shape, jnp.float32)  # 1 period
        q = quantize_stacked(w, bits, per_channel=per_channel)
        sliced = {k: v[0] for k, v in q.items()}               # period slice
        codes = q["codes"] if "codes" in q else None
        if codes is None:                                      # unpack int4
            packed = q["codes_packed"]
            lo, hi = packed & 0xF, packed >> 4
            codes = jnp.stack([lo, hi], axis=-1).reshape(
                packed.shape[:-1] + (packed.shape[-1] * 2,))
        dense = (codes.astype(jnp.float32) * q["scale"] + q["mu"])[0]
        return sliced, dense

    @pytest.mark.parametrize("bits", [8, 4], ids=["int8", "int4"])
    @pytest.mark.parametrize("per_channel", [True, False],
                             ids=["per-channel", "per-tensor"])
    def test_matmul_2d(self, bits, per_channel, monkeypatch):
        monkeypatch.setenv("REPRO_KERNELS", "reference")
        struct, dense = self._struct_and_dense(KEY, (48, 64), bits,
                                               per_channel)
        x = jax.random.normal(KEY, (2, 5, 48), jnp.float32)
        got = ops.qdense(x, struct)
        want = jnp.einsum("bsd,dn->bsn", x, dense)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_qkv_projection_3d_out(self, monkeypatch):
        """(D, H, hd) projection: contraction over D, struct output tail
        (H, hd) — per-channel metadata is per-head-dim, broadcast over
        the flattened columns."""
        monkeypatch.setenv("REPRO_KERNELS", "reference")
        struct, dense = self._struct_and_dense(KEY, (64, 4, 32), 8, True)
        x = jax.random.normal(KEY, (2, 5, 64), jnp.float32)
        got = ops.qdense(x, struct)
        want = jnp.einsum("bsd,dhk->bshk", x, dense)
        assert got.shape == (2, 5, 4, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_out_projection_contracts_two_axes(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNELS", "reference")
        struct, dense = self._struct_and_dense(KEY, (4, 32, 64), 8, True)
        x = jax.random.normal(KEY, (2, 5, 4, 32), jnp.float32)
        got = ops.qdense(x, struct, n_contract=2)
        want = jnp.einsum("bshk,hkd->bsd", x, dense)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_interpret_matches_reference(self, monkeypatch):
        """The Pallas qmatmul lane (interpret) agrees with the jnp lane
        through the same dispatch — both int8 and packed int4."""
        x = jax.random.normal(KEY, (6, 48), jnp.float32)
        for bits in (8, 4):
            struct, _ = self._struct_and_dense(KEY, (48, 64), bits, True)
            monkeypatch.setenv("REPRO_KERNELS", "reference")
            a = np.asarray(ops.qdense(x, struct))
            monkeypatch.setenv("REPRO_KERNELS", "interpret")
            b = np.asarray(ops.qdense(x, struct))
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


class TestQuantizedKernelSegment:
    """``qstacked_for`` wire structs through the compile-once decode
    programs == the dense fake-quant path (``stacked_for``)."""

    @pytest.fixture(scope="class")
    def lm(self):
        cfg = dataclasses.replace(
            get_config("smollm-135m").reduced(), name="smollm-qkern",
            d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
            vocab_size=32, tp_pad=1, dtype="float32")
        return cfg, T.init_params(KEY, cfg)

    @pytest.mark.parametrize("bits", [8.0, 4.0], ids=["int8", "int4pack"])
    def test_tokens_match_dense_fake_quant_path(self, lm, bits):
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        p = cfg.num_layers
        dense = DecodeSession(backend, _manual_plan(p, bits=bits),
                              max_len=MAX_LEN, qkernels=False)
        qkern = DecodeSession(backend, _manual_plan(p, bits=bits),
                              max_len=MAX_LEN, qkernels=True)
        r0 = dense.generate(prompt, 6)
        r1 = qkern.generate(prompt, 6)
        np.testing.assert_array_equal(r1.tokens, r0.tokens)

    def test_struct_dequant_matches_split_blocks(self, lm):
        """dequant(qstacked codes) on the active periods == the
        fake-quant leaves ``split_blocks`` ships — bit for bit, the
        invariant that makes token parity exact rather than approximate."""
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        plan = _manual_plan(cfg.num_layers, bits=6.0)
        seg = backend.split(plan)
        qtree = backend.qstacked_for(seg, plan)
        dtree = backend.stacked_for(seg, plan)
        plen = T.period_len(cfg)
        for pos in range(plen):
            for name, keys in T.KERNEL_ROUTED.items():
                if name not in qtree["blocks"][pos]:
                    continue
                for k in keys:
                    if k not in qtree["blocks"][pos][name]:
                        continue
                    s = qtree["blocks"][pos][name][k]
                    codes = s["codes"].astype(jnp.float32)
                    deq = codes * s["scale"] + s["mu"]
                    np.testing.assert_array_equal(
                        np.asarray(deq),
                        np.asarray(dtree["blocks"][pos][name][k]))

    def test_compile_once_across_cuts(self, lm):
        """The struct tree keys its own programs, but the pytree
        structure is cut-independent: after the first quantized-kernel
        cut, further cuts at the same bit-widths add ZERO traces."""
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        L = cfg.num_layers
        DecodeSession(backend, _manual_plan(1, bits=8.0), max_len=MAX_LEN,
                      qkernels=True).generate(prompt, 4)
        traces = backend.trace_count
        for p in (L // 2, L):
            DecodeSession(backend, _manual_plan(p, bits=8.0),
                          max_len=MAX_LEN, qkernels=True).generate(prompt, 4)
        assert backend.trace_count == traces, \
            "quantized-kernel decode re-traced across cut points"

    def test_moe_expert_stacks_stay_dense(self):
        """The context-sensitive routing must NOT struct-ify MoE expert
        stacks (same key names as MLP weights, different contraction) —
        a qkernels session on an MoE arch still decodes correctly."""
        cfg = dataclasses.replace(
            get_config("olmoe-1b-7b").reduced(), name="moe-qkern",
            vocab_size=32, dtype="float32")
        params = T.init_params(KEY, cfg)
        backend = TransformerBackend(cfg, params, seq_len=8)
        prompt = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
        p = cfg.num_layers
        dense = DecodeSession(backend, _manual_plan(p, bits=8.0),
                              max_len=24, qkernels=False)
        qkern = DecodeSession(backend, _manual_plan(p, bits=8.0),
                              max_len=24, qkernels=True)
        np.testing.assert_array_equal(qkern.generate(prompt, 4).tokens,
                                      dense.generate(prompt, 4).tokens)
