"""CostModel v2 provider-layer tests (DESIGN.md §9) — pure NumPy logic
(no model execution, no training; part of the CI smoke subset):

  * AnalyticCost is regression-locked BIT-EXACTLY: its breakdown equals
    ``cost_breakdown`` field-for-field and ``price_window``'s objective
    matrices equal the pre-refactor xi·O1 + delta·O2 + eps·wire
    arithmetic float-for-float on random mixed-model windows.
  * Objective monotonicity: non-increasing in channel capacity and in
    the server clock rate.
  * RooflineCost stage times are lower-bounded by their compute-only
    (analytic) terms.
  * The calibration ledger's least-squares fit recovers planted stage
    rates and its provider predicts with them.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.cost_model import (AnalyticCost, CalibrationLedger, Channel,
                                   CostProvider, DeviceProfile, LayerSpec,
                                   ObjectiveWeights, RooflineCost,
                                   ServerProfile, StageRates,
                                   candidate_byte_rows, act_bytes_row,
                                   cost_breakdown, delta_coeff, eps_coeff,
                                   plan_cost_terms, xi_coeff)
from repro.core.solver import build_offline_store
from repro.serving.pricing import price_window
from repro.serving.simulator import InferenceRequest

from tests._hypothesis_shim import given, settings, st

pytestmark = pytest.mark.smoke

LEVELS = (0.001, 0.0025, 0.005, 0.01, 0.02)


# ---------------------------------------------------------------------------
# Pricing-only fixtures: synthetic layer profiles behind the minimal
# model/backend surface ``price_window`` needs — no JAX, no training.

class _SpecBackend:
    def __init__(self, seed: int, L: int):
        rng = np.random.default_rng(seed)
        self.z_w = rng.integers(200, 5000, L).astype(float)
        self.z_x = rng.integers(16, 400, L).astype(float)
        self.o = rng.integers(10_000, 800_000, L).astype(float)
        self.L = L

    def layer_specs(self, batch: int = 1, seq_len=None):
        return [LayerSpec(f"l{i}", self.z_w[i], self.z_x[i] * batch,
                          self.o[i] * batch) for i in range(self.L)]

    def input_elements(self) -> float:
        return 784.0


class _Model:
    def __init__(self, backend, store):
        self.backend = backend
        self._store = store

    def store(self, context=None):
        return self._store


def _stub_models(device, channel, weights, server,
                 layer_counts=(4, 7), provider=None):
    provider = provider or AnalyticCost()
    models = {}
    for i, L in enumerate(layer_counts):
        b = _SpecBackend(seed=11 * i + 3, L=L)
        specs = b.layer_specs()
        oc = provider.offline_coeffs(weights, device, channel, server)
        store = build_offline_store(
            levels=LEVELS, budgets={a: a * 50 for a in LEVELS},
            layer_z_w=[sp.z_w for sp in specs],
            layer_z_x=[sp.z_x for sp in specs],
            layer_s_w=np.ones(L), layer_s_x=np.ones(L),
            layer_rho=np.full(L, 0.1),
            layer_o=[sp.o for sp in specs],
            xi=oc["xi"], delta_cost=oc["delta"], eps=oc["eps"],
            input_z=b.input_elements(),
            c_dev_bytes=oc["c_dev_bytes"], c_srv_bytes=oc["c_srv_bytes"],
            layer_act_bytes=[sp.act_bytes for sp in specs],
            layer_w_bytes16=[sp.w_bytes16 for sp in specs])
        models[f"m{i}"] = _Model(b, store)
    return models


def _random_window(models, rng, n, device, channel, weights):
    names = sorted(models)
    reqs = []
    for i in range(n):
        dev = dataclasses.replace(
            device, f_clock=float(rng.choice([2e8, 1e9, 2e9])),
            memory_bytes=float(rng.choice([64e3, 512e6])))
        ch = dataclasses.replace(
            channel, capacity_bps=float(rng.choice([2e6, 2e7, 2e8])))
        reqs.append(InferenceRequest(
            names[int(rng.integers(len(names)))],
            float(rng.choice([0.0012, 0.004, 0.01, 0.03])),
            dev, ch, weights, batch=int(rng.choice([1, 4])),
            segment_cached=bool(rng.integers(2))))
    return reqs


def _prerefactor_objectives(models, server, requests):
    """The pre-provider ``price_window`` arithmetic, verbatim: stacked
    per-group matrices, xi·O1 + delta·(O_tot − O1) + eps·wire, memory
    mask to +inf."""
    by_model = {}
    for i, r in enumerate(requests):
        by_model.setdefault(r.model, []).append(i)
    out = [None] * len(requests)
    for name, idxs in by_model.items():
        m = models[name]
        store = m.store(None)
        group = [requests[i] for i in idxs]
        xi = np.array([xi_coeff(r.weights, r.device) for r in group])
        dl = np.array([delta_coeff(r.weights, server) for r in group])
        ep = np.array([eps_coeff(r.weights, r.device, r.channel)
                       for r in group])
        o1_rows, wire_rows, mem_rows = [], [], []
        for r in group:
            a_star = store.level_for(r.accuracy_budget)
            specs = m.backend.layer_specs(batch=r.batch)
            o1_rows.append(np.concatenate(
                [[0.0], np.cumsum([sp.o for sp in specs])]))
            pb, px = store.level_payload_rows(a_star)
            wire_rows.append(px if r.segment_cached else pb)
            mem_rows.append(store.level_memory_rows(a_star))
        o1 = np.stack(o1_rows)
        wire = np.stack(wire_rows)
        obj = xi[:, None] * o1 + dl[:, None] * (o1[:, -1:] - o1) \
            + ep[:, None] * wire
        mem = np.stack(mem_rows)
        dev_mem = np.array([r.device.memory_bytes for r in group])
        obj = np.where(mem > dev_mem[:, None], np.inf, obj)
        for j, i in enumerate(idxs):
            out[i] = obj[j]
    return out


DEV = DeviceProfile()
CH = Channel(capacity_bps=2e6)
W = ObjectiveWeights()
SRV = ServerProfile()


# ---------------------------------------------------------------------------
class TestAnalyticLock:
    def test_breakdown_bit_exact_vs_cost_breakdown(self):
        rng = np.random.default_rng(0)
        provider = AnalyticCost()
        for _ in range(50):
            o1, o2 = float(rng.uniform(0, 1e8)), float(rng.uniform(0, 1e9))
            wire = float(rng.uniform(0, 1e7))
            ref = cost_breakdown(o1, o2, wire, DEV, SRV, CH)
            got = provider.breakdown(o1, o2, wire, DEV, SRV, CH,
                                     dev_bytes=123.0, srv_bytes=456.0)
            assert dataclasses.astuple(got) == dataclasses.astuple(ref)

    def test_price_window_bit_identical_prerefactor_mixed_window(self):
        """The acceptance lock: post-refactor ``price_window`` objective
        matrices are BIT-identical to the pre-refactor arithmetic on a
        random mixed-model window (two models, different layer counts,
        heterogeneous devices/channels/budgets/batches/cache flags)."""
        models = _stub_models(DEV, CH, W, SRV)
        rng = np.random.default_rng(7)
        for trial in range(5):
            reqs = _random_window(models, rng, 40, DEV, CH, W)
            tab = price_window(models, SRV, reqs)
            ref = _prerefactor_objectives(models, SRV, reqs)
            for i in range(len(reqs)):
                np.testing.assert_array_equal(tab.obj[i], ref[i])
            # and therefore the chosen candidates agree
            choices = tab.argmin_choices()
            for i in range(len(reqs)):
                assert choices[i] == int(np.argmin(ref[i]))

    def test_objective_rows_association_order(self):
        """obj accumulates c_0·T_0 + c_1·T_1 + ... left-to-right — the
        association the bit-exactness above relies on."""
        c = np.array([0.3, 0.7, 1.1])
        t = [np.array([1.0, 2.0]), np.array([3.0, 4.0]),
             np.array([5.0, 6.0])]
        got = CostProvider.objective_rows(c, t)
        exact = (c[0] * t[0] + c[1] * t[1]) + c[2] * t[2]
        np.testing.assert_array_equal(got, exact)


# ---------------------------------------------------------------------------
class TestMonotonicity:
    @given(st.floats(min_value=1e5, max_value=1e9),
           st.floats(min_value=1.01, max_value=100.0))
    @settings(max_examples=25, deadline=None, derandomize=True)
    def test_objective_non_increasing_in_channel_capacity(self, cap, k):
        """A faster channel can only cheapen every candidate (eps is the
        only capacity-dependent coefficient and wire bits are >= 0)."""
        rows_o1 = np.array([0.0, 1e5, 3e5])
        for provider in (AnalyticCost(), RooflineCost()):
            ch1 = Channel(capacity_bps=cap)
            ch2 = Channel(capacity_bps=cap * k)
            c1 = provider.coeffs(W, DEV, ch1, SRV)
            c2 = provider.coeffs(W, DEV, ch2, SRV)
            terms = [rows_o1, rows_o1[-1] - rows_o1,
                     np.array([1e6, 5e5, 1e4]),          # wire
                     np.array([0.0, 1e4, 1e5]),          # dev bytes
                     np.array([1e6, 5e5, 0.0])][:len(c1)]
            obj1 = provider.objective_rows(c1, terms)
            obj2 = provider.objective_rows(c2, terms)
            assert np.all(obj2 <= obj1 + 1e-15), provider.name

    @given(st.floats(min_value=1e8, max_value=1e10),
           st.floats(min_value=1.01, max_value=50.0))
    @settings(max_examples=25, deadline=None, derandomize=True)
    def test_objective_non_increasing_in_server_clock(self, f, k):
        for provider in (AnalyticCost(), RooflineCost()):
            s1 = ServerProfile(f_clock=f)
            s2 = ServerProfile(f_clock=f * k)
            c1 = provider.coeffs(W, DEV, CH, s1)
            c2 = provider.coeffs(W, DEV, CH, s2)
            terms = [np.array([0.0, 1e5, 3e5]),
                     np.array([3e5, 2e5, 0.0]),
                     np.array([1e6, 5e5, 1e4]),
                     np.array([0.0, 1e4, 1e5]),
                     np.array([1e6, 5e5, 0.0])][:len(c1)]
            obj1 = provider.objective_rows(c1, terms)
            obj2 = provider.objective_rows(c2, terms)
            assert np.all(obj2 <= obj1 + 1e-15), provider.name


# ---------------------------------------------------------------------------
class TestRoofline:
    def test_stage_times_lower_bounded_by_compute(self):
        rng = np.random.default_rng(1)
        roof, ana = RooflineCost(), AnalyticCost()
        o1 = rng.uniform(0, 1e8, 16)
        nbytes = rng.uniform(0, 1e9, 16)
        assert np.all(roof.device_seconds(DEV, o1, nbytes)
                      >= ana.device_seconds(DEV, o1))
        assert np.all(roof.server_seconds(SRV, o1, nbytes)
                      >= ana.server_seconds(SRV, o1))
        # zero traffic: exactly the compute term
        np.testing.assert_array_equal(roof.device_seconds(DEV, o1, 0.0),
                                      ana.device_seconds(DEV, o1))

    def test_coeffs_extend_analytic(self):
        """Roofline's first three coefficients ARE the analytic ones —
        the memory terms are additive, never a re-weighting."""
        c_roof = RooflineCost().coeffs(W, DEV, CH, SRV)
        c_ana = AnalyticCost().coeffs(W, DEV, CH, SRV)
        np.testing.assert_array_equal(c_roof[:3], c_ana)
        assert c_roof[3] > 0 and c_roof[4] > 0

    def test_offline_store_prices_memory_terms(self):
        """With roofline offline coefficients every stored plan's
        objective gains non-negative memory terms; the water-filled bit
        patterns are untouched (budget math does not price time)."""
        models_a = _stub_models(DEV, CH, W, SRV, layer_counts=(5,))
        models_r = _stub_models(DEV, CH, W, SRV, layer_counts=(5,),
                                provider=RooflineCost())
        sa = models_a["m0"].store()
        sr = models_r["m0"].store()
        for key, plan_a in sa.plans.items():
            plan_r = sr.plans[key]
            np.testing.assert_array_equal(plan_a.bits_w, plan_r.bits_w)
            assert plan_r.objective >= plan_a.objective
            extra = plan_r.breakdown["memory_device"] \
                + plan_r.breakdown["memory_server"]
            assert plan_r.objective == pytest.approx(
                plan_a.objective + extra, rel=1e-12)

    def test_candidate_byte_rows_match_plan_terms(self):
        """The window path's byte rows agree with the scalar
        ``plan_cost_terms`` at every candidate."""
        models = _stub_models(DEV, CH, W, SRV, layer_counts=(6,))
        m = models["m0"]
        store = m.store()
        specs = m.backend.layer_specs(batch=3)
        a = store.level_for(0.01)
        dev_row, srv_row = candidate_byte_rows(
            specs, store.level_memory_rows(a), act_bytes_row(specs))
        for p in range(len(specs) + 1):
            plan = store.plans[(a, p)]
            _o1, _o2, dev_b, srv_b = plan_cost_terms(plan, specs)
            assert dev_row[p] == pytest.approx(dev_b, rel=1e-12)
            assert srv_row[p] == pytest.approx(srv_b, rel=1e-12)


# ---------------------------------------------------------------------------
class TestCalibrated:
    def _planted_ledger(self, rng, dev, srv, r_dev, r_srv, n=24):
        led = CalibrationLedger()
        for _ in range(n):
            o1, o2 = rng.uniform(1e4, 1e7), rng.uniform(1e4, 1e7)
            db, sb = rng.uniform(1e3, 1e6), rng.uniform(1e3, 1e6)
            led.add(dev, srv, o1, o2, db, sb,
                    float(r_dev.seconds(o1, db)),
                    float(r_srv.seconds(o2, sb)))
        return led

    def test_fit_recovers_planted_rates(self):
        rng = np.random.default_rng(3)
        r_dev = StageRates(2e-9, 3e-10, 1e-4)
        r_srv = StageRates(5e-10, 1e-10, 2e-4)
        led = self._planted_ledger(rng, DEV, SRV, r_dev, r_srv)
        cal = led.fit()
        for o1, db in ((1e5, 2e4), (5e6, 8e5)):
            assert float(cal.device_seconds(DEV, o1, db)) == pytest.approx(
                float(r_dev.seconds(o1, db)), rel=1e-6)
            assert float(cal.server_seconds(SRV, o1, db)) == pytest.approx(
                float(r_srv.seconds(o1, db)), rel=1e-6)

    def test_unseen_profiles_fall_back_to_global_fit(self):
        rng = np.random.default_rng(4)
        r_dev = StageRates(1e-9, 0.0, 0.0)
        r_srv = StageRates(1e-10, 0.0, 0.0)
        cal = self._planted_ledger(rng, DEV, SRV, r_dev, r_srv).fit()
        other_dev = dataclasses.replace(DEV, f_clock=9e9)
        other_srv = dataclasses.replace(SRV, f_clock=9e9)
        assert float(cal.device_seconds(other_dev, 1e6, 0.0)) == \
            pytest.approx(float(cal.device_seconds(DEV, 1e6, 0.0)))
        assert float(cal.server_seconds(other_srv, 1e6, 0.0)) == \
            pytest.approx(float(cal.server_seconds(SRV, 1e6, 0.0)))

    def test_calibrated_argmin_tracks_measured_regime(self):
        """Plant device-much-slower-than-analytic rates: the calibrated
        window argmin shifts toward offload relative to analytic."""
        models = _stub_models(DEV, CH, W, SRV, layer_counts=(6,))
        rng = np.random.default_rng(5)
        slow_dev = StageRates(1e-3, 0.0, 0.0)       # 1 ms per MAC (!)
        fast_srv = StageRates(1e-12, 0.0, 0.0)
        cal = self._planted_ledger(rng, DEV, SRV, slow_dev, fast_srv).fit()
        req = InferenceRequest("m0", 0.01, DEV, Channel(), W,
                               segment_cached=True)
        p_cal = int(price_window(models, SRV, [req],
                                 provider=cal).argmin_choices()[0])
        p_ana = int(price_window(models, SRV, [req]).argmin_choices()[0])
        assert p_cal == 0 and p_cal <= p_ana

    def test_empty_ledger_raises(self):
        with pytest.raises(ValueError):
            CalibrationLedger().fit()

    def test_offline_coeffs_follow_online_coeffs(self):
        """Every provider's offline (Alg. 1) coefficients derive from
        the SAME coeffs vector the online paths use — including the
        calibrated provider's byte terms (stores built under it price
        memory traffic, not the analytic defaults)."""
        rng = np.random.default_rng(6)
        cal = self._planted_ledger(rng, DEV, SRV,
                                   StageRates(2e-9, 3e-10, 0.0),
                                   StageRates(5e-10, 1e-10, 0.0)).fit()
        for provider in (AnalyticCost(), RooflineCost(), cal):
            c = provider.coeffs(W, DEV, CH, SRV)
            oc = provider.offline_coeffs(W, DEV, CH, SRV)
            assert oc["xi"] == float(c[0])
            assert oc["delta"] == float(c[1])
            assert oc["eps"] == float(c[2])
            if provider.uses_bytes:
                assert oc["c_dev_bytes"] == float(c[3]) > 0
                assert oc["c_srv_bytes"] == float(c[4]) > 0
            else:
                assert oc["c_dev_bytes"] == oc["c_srv_bytes"] == 0.0


# ---------------------------------------------------------------------------
class TestChannelMemo:
    def test_snr_capacity_matches_formula_and_survives_replace(self):
        ch = Channel(bandwidth_hz=40e6, snr_db=20.0)
        expect = 40e6 * math.log2(1.0 + 10 ** 2.0)
        assert ch.capacity() == expect
        ch2 = dataclasses.replace(ch, snr_db=10.0)
        assert ch2.capacity() == 40e6 * math.log2(1.0 + 10 ** 1.0)
        assert Channel(capacity_bps=3e6).capacity() == 3e6

    def test_coeff_cache_one_entry_per_profile(self):
        provider = AnalyticCost()
        for _ in range(100):
            provider.coeffs_cached(W, DEV, CH, SRV)
        assert len(provider.__dict__["_coeff_cache"]) == 1
        provider.coeffs_cached(W, DEV, Channel(capacity_bps=5e6), SRV)
        assert len(provider.__dict__["_coeff_cache"]) == 2
