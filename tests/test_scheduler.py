"""Dynamic workload balancing tests: under server congestion the chosen
plans shift work toward the devices, and the balanced policy beats FCFS
on total latency for heterogeneous windows."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.data.pipeline import minibatches, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.backends import ClassifierBackend
from repro.serving.qpart_server import QPARTServer
from repro.serving.scheduler import WorkloadBalancer, total_latency
from repro.serving.simulator import InferenceRequest

pytestmark = pytest.mark.smoke


@pytest.fixture(scope="module")
def calibrated_server():
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=4096, n_test=2048)
    params = init_classifier(jax.random.key(0), MNIST_MLP)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, MNIST_MLP, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    it = minibatches(x_tr, y_tr, 128)
    for _ in range(300):
        bx, by = next(it)
        params = step(params, bx, by)
    # strong server (default 3 GHz): attractive at low load so the queue
    # is what pushes work device-side
    srv = QPARTServer()
    srv.register("mnist", ClassifierBackend(MNIST_MLP, params),
                 x_te[1024:1536], y_te[1024:1536])
    srv.calibrate("mnist")
    dev, ch, w = DeviceProfile(), Channel(capacity_bps=2e6), ObjectiveWeights()
    srv.build_store("mnist", dev, ch, w)
    return srv, dev, ch, w


def _window(dev, ch, w, n=6, cached=True):
    return [InferenceRequest("mnist", 0.01, dev, ch, w,
                             segment_cached=cached) for _ in range(n)]


class TestWorkloadBalancing:
    def test_congestion_pushes_work_to_devices(self, calibrated_server):
        """With a long queue, later requests must offload no more server
        work than the first (their p can only move toward the device)."""
        srv, dev, ch, w = calibrated_server
        bal = WorkloadBalancer(ServerProfile(), policy="fcfs")
        results = bal.schedule(srv, _window(dev, ch, w, n=64))
        ps = [r.result.plan.p for r in results]
        # identical requests: p must be monotonically non-decreasing as
        # the queue grows (more layers kept on device under congestion)
        assert all(b >= a for a, b in zip(ps, ps[1:])), ps
        # and the queue really builds up
        delays = [r.queue_delay for r in results]
        assert delays[-1] > 0

    def test_balanced_no_worse_than_fcfs(self, calibrated_server):
        srv, dev, ch, w = calibrated_server
        # heterogeneous window: strong-device + weak-device requesters
        strong = dataclasses.replace(dev, f_clock=2e9)
        reqs = []
        for i in range(6):
            d = strong if i % 2 else dev
            reqs.append(InferenceRequest("mnist", 0.01, d, ch,
                                         ObjectiveWeights(),
                                         segment_cached=True))
        fcfs = WorkloadBalancer(ServerProfile(), policy="fcfs")
        bal = WorkloadBalancer(ServerProfile(), policy="balanced")
        t_f = total_latency(fcfs.schedule(srv, reqs))
        t_b = total_latency(bal.schedule(srv, reqs))
        assert t_b <= t_f * (1 + 1e-9)

    def test_results_keep_request_order(self, calibrated_server):
        srv, dev, ch, w = calibrated_server
        reqs = _window(dev, ch, w, n=4)
        out = WorkloadBalancer(ServerProfile()).schedule(srv, reqs)
        assert [r.request for r in out] == reqs

    def test_duplicate_request_objects_keep_positions(self, calibrated_server):
        """Arrival order restoration must survive duplicate (equal)
        requests — the old requests.index() scan collapsed them."""
        srv, dev, ch, w = calibrated_server
        r = InferenceRequest("mnist", 0.01, dev, ch, w, segment_cached=True)
        reqs = [r, r, r, r]
        out = WorkloadBalancer(ServerProfile(), policy="fcfs").schedule(srv,
                                                                        reqs)
        assert [sr.request for sr in out] == reqs
        # fcfs over identical requests: each sees the queue its
        # predecessors left, so delays are non-decreasing by position
        delays = [sr.queue_delay for sr in out]
        assert delays == sorted(delays)
        assert delays[-1] > 0

    def test_mixed_model_window(self):
        """One window may mix models with different layer counts — rows
        are priced per model group (no calibration needed: pricing only
        touches the store and the cost model)."""
        import numpy as np
        from repro.configs.classifier import CIFAR_CNN
        srv = QPARTServer()
        dev, ch, w = DeviceProfile(), Channel(capacity_bps=2e6), \
            ObjectiveWeights()
        x28 = np.zeros((4, 28, 28), np.float32)
        x32 = np.zeros((4, 3, 32, 32), np.float32)
        y = np.zeros(4, np.int32)
        for name, cfg, x in (("mnist6", MNIST_MLP, x28),
                             ("cifar", CIFAR_CNN, x32)):
            srv.register(name, ClassifierBackend(cfg, None), x, y)
            m = srv.models[name]
            L = cfg.num_layers
            m.s_w = np.ones(L)
            m.s_x = np.ones(L)
            m.rho = np.full(L, 0.1)
            m.delta_table = {a: a * 50 for a in srv.levels}
            srv.build_store(name, dev, ch, w)
        reqs = [InferenceRequest("mnist6" if i % 2 else "cifar", 0.01,
                                 dev, ch, w, segment_cached=True)
                for i in range(8)]
        bal = WorkloadBalancer(ServerProfile(), policy="fcfs")
        out = bal.schedule(srv, reqs)
        assert [sr.request for sr in out] == reqs
        queue = 0.0
        for sr in out:
            ref = bal._serve_under_load(srv, sr.request, queue)
            assert sr.result.plan is ref.plan
            queue += ref.costs.t_server

    def test_matches_scalar_reference_pricing(self, calibrated_server):
        """The window objective matrix must reproduce the per-request
        Alg. 2 re-pricing (_serve_under_load) decision-for-decision."""
        srv, dev, ch, w = calibrated_server
        bal = WorkloadBalancer(ServerProfile(), policy="fcfs")
        strong = dataclasses.replace(dev, f_clock=2e9)
        reqs = [InferenceRequest("mnist", 0.01 if i % 2 else 0.004,
                                 strong if i % 3 == 0 else dev, ch, w,
                                 segment_cached=bool(i % 2))
                for i in range(12)]
        out = bal.schedule(srv, reqs)
        queue = 0.0
        for sr in out:
            ref = bal._serve_under_load(srv, sr.request, queue)
            assert sr.result.plan is ref.plan
            assert sr.result.objective == pytest.approx(ref.objective,
                                                        rel=1e-9)
            queue += ref.costs.t_server
