"""Dynamic workload balancing tests: under server congestion the chosen
plans shift work toward the devices, and the balanced policy beats FCFS
on total latency for heterogeneous windows."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.data.pipeline import minibatches, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.qpart_server import QPARTServer
from repro.serving.scheduler import WorkloadBalancer, total_latency
from repro.serving.simulator import InferenceRequest


@pytest.fixture(scope="module")
def calibrated_server():
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=4096, n_test=2048)
    params = init_classifier(jax.random.key(0), MNIST_MLP)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, MNIST_MLP, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    it = minibatches(x_tr, y_tr, 128)
    for _ in range(300):
        bx, by = next(it)
        params = step(params, bx, by)
    # strong server (default 3 GHz): attractive at low load so the queue
    # is what pushes work device-side
    srv = QPARTServer()
    srv.register_model("mnist", MNIST_MLP, params,
                       x_te[1024:1536], y_te[1024:1536])
    srv.calibrate("mnist")
    dev, ch, w = DeviceProfile(), Channel(capacity_bps=2e6), ObjectiveWeights()
    srv.build_store("mnist", dev, ch, w)
    return srv, dev, ch, w


def _window(dev, ch, w, n=6, cached=True):
    return [InferenceRequest("mnist", 0.01, dev, ch, w,
                             segment_cached=cached) for _ in range(n)]


class TestWorkloadBalancing:
    def test_congestion_pushes_work_to_devices(self, calibrated_server):
        """With a long queue, later requests must offload no more server
        work than the first (their p can only move toward the device)."""
        srv, dev, ch, w = calibrated_server
        bal = WorkloadBalancer(ServerProfile(), policy="fcfs")
        results = bal.schedule(srv, _window(dev, ch, w, n=64))
        ps = [r.result.plan.p for r in results]
        # identical requests: p must be monotonically non-decreasing as
        # the queue grows (more layers kept on device under congestion)
        assert all(b >= a for a, b in zip(ps, ps[1:])), ps
        # and the queue really builds up
        delays = [r.queue_delay for r in results]
        assert delays[-1] > 0

    def test_balanced_no_worse_than_fcfs(self, calibrated_server):
        srv, dev, ch, w = calibrated_server
        # heterogeneous window: strong-device + weak-device requesters
        strong = dataclasses.replace(dev, f_clock=2e9)
        reqs = []
        for i in range(6):
            d = strong if i % 2 else dev
            reqs.append(InferenceRequest("mnist", 0.01, d, ch,
                                         ObjectiveWeights(),
                                         segment_cached=True))
        fcfs = WorkloadBalancer(ServerProfile(), policy="fcfs")
        bal = WorkloadBalancer(ServerProfile(), policy="balanced")
        t_f = total_latency(fcfs.schedule(srv, reqs))
        t_b = total_latency(bal.schedule(srv, reqs))
        assert t_b <= t_f * (1 + 1e-9)

    def test_results_keep_request_order(self, calibrated_server):
        srv, dev, ch, w = calibrated_server
        reqs = _window(dev, ch, w, n=4)
        out = WorkloadBalancer(ServerProfile()).schedule(srv, reqs)
        assert [r.request for r in out] == reqs
