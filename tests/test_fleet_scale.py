"""Scale-path equivalence suite (DESIGN.md §12): every scale knob of the
fleet engine — vectorized admission, columnar records, light/off
journaling, cached re-price ladders — is locked bit-for-bit against the
full-fidelity path it replaces, on chaos traces (device churn + channel
drift + retry) and autoregressive decode traces (continuous batching +
mid-stream severance). The lock is the JOURNAL (every processed event
with its outcome facts) plus the metrics SUMMARY, so a single drifting
admission decision or stage boundary fails loudly."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import (DISCONNECT, RECONNECT, FaultEvent,
                                  FleetEngine, FleetMetrics, RetryPolicy,
                                  churn_trace, degrade_trace, materialize,
                                  mmpp_arrivals)
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import (poisson_trace, stub_classifier_server,
                                   stub_transformer_calibration)
from repro.configs.classifier import MNIST_MLP

from tests._hypothesis_shim import given, settings, st

pytestmark = pytest.mark.smoke

DEV = DeviceProfile()
CH = Channel(capacity_bps=2e6)
W = ObjectiveWeights()

# offloading unattractive (slow fleet, fast channel): plans go
# device-side, segments really ship, disconnects have a radio window
SLOW = ServerProfile(f_clock=1e7)
SRV = stub_classifier_server([("mnist", MNIST_MLP)], server=SLOW,
                             device=DEV, channel=Channel(), weights=W)
# heterogeneous fleet: the second profile prices through the delta
# correction; the third is value-equal to the reference but a DIFFERENT
# object, so it exercises the correction path too (identity, not value,
# decides — the correction of an equal profile is exactly zero work)
HETERO = [SLOW, ServerProfile(f_clock=4e7), ServerProfile(f_clock=1e7)]
RETRY = RetryPolicy(max_attempts=3, base_backoff_s=0.01, max_backoff_s=0.1,
                    degrade_on_retry=True)


def _chaos(n=300, seed=0, device_pool=24):
    arrivals = mmpp_arrivals(n, rates=(100.0, 900.0), mean_dwell=(0.3, 0.1),
                             seed=seed)
    trace = materialize("mnist", arrivals, [DEV], [Channel()], W,
                        budgets=(0.004, 0.01, 0.02),
                        deadlines=(0.05, 0.2), batches=(1,),
                        device_pool=device_pool, seed=seed)
    horizon = trace[-1].arrival_time + 0.5
    devs = [f"dev-{i}" for i in range(device_pool)]
    faults = (churn_trace(devs[::2], horizon, mean_uptime=0.2,
                          mean_downtime=0.1, seed=seed)
              + degrade_trace(devs[1::2], horizon, mean_interval=0.5,
                              mean_duration=0.1, seed=seed + 1))
    return trace, faults


def _run(trace, faults=None, servers=HETERO, policy="fcfs", **kw):
    kw.setdefault("slo", "degrade")
    kw.setdefault("epoch_interval", 0.005)
    eng = FleetEngine(SRV, servers=servers, policy=policy, retry=RETRY,
                      faults=faults, **kw)
    return eng.run(trace)


def _assert_same_run(a, b):
    """Two runs produced identical decisions: same journal (when both
    full), same summary, same terminal columns."""
    if a.journal is not None and b.journal is not None \
            and hasattr(a.journal, "entries"):
        delta = a.journal.diff(b.journal)
        assert delta is None, delta
    assert a.summary() == b.summary()


class TestVectorizedAdmissionEquivalence:
    """admission="vectorized" vs the historical scalar loop, decision
    for decision, on the chaos trace — all four policies."""

    @pytest.mark.parametrize("policy",
                             ["fcfs", "balanced", "edf", "least_loaded"])
    def test_chaos_trace(self, policy):
        trace, faults = _chaos()
        vec = _run(trace, faults, policy=policy, admission="vectorized")
        ref = _run(trace, faults, policy=policy, admission="reference")
        _assert_same_run(vec, ref)
        vec.assert_terminal()

    def test_homogeneous_fleet(self):
        # the broadcast fast path (every profile IS the reference object)
        trace, faults = _chaos(n=200, seed=3)
        fleet = [SLOW] * 3
        vec = _run(trace, faults, servers=fleet, admission="vectorized")
        ref = _run(trace, faults, servers=fleet, admission="reference")
        _assert_same_run(vec, ref)


class TestDecodeEquivalence:
    """Vectorized admission + columnar records on the decode lane:
    continuous batching, mid-stream disconnect severance, retries."""

    def _lm(self):
        cfg = _f32(get_config("smollm-135m").reduced())
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e6)
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, W,
                                     seq_len=16, decode_max_len=64)
        return srv, dev, ch

    def test_decode_trace(self):
        srv, dev, ch = self._lm()
        reqs = [InferenceRequest("lm", 0.05, dev, ch, W, arrival_time=0.0,
                                 device_id=f"d{i}", max_new_tokens=30)
                for i in range(4)]
        reqs.append(InferenceRequest("lm", 0.05, dev, ch, W,
                                     arrival_time=0.0, device_id="d0",
                                     max_new_tokens=50))
        horizon = FleetEngine(srv).run(reqs).horizon
        faults = [FaultEvent(horizon / 2, DISCONNECT, "d0"),
                  FaultEvent(horizon, RECONNECT, "d0")]
        runs = [FleetEngine(srv, faults=faults, admission=mode).run(reqs)
                for mode in ("vectorized", "reference")]
        _assert_same_run(*runs)
        runs[0].assert_terminal()
        assert runs[0].summary()["tokens_per_s"] > 0
        runs[0].journal.verify_replay(srv, reqs)


class TestRecordAndJournalModes:
    """records="light" and journal="light"/"off" change bookkeeping
    cost, never a decision or a terminal fact."""

    TERMINAL = ("server", "start_order", "backlog", "queue_delay",
                "degraded_to", "rejected", "drop_code", "attempts",
                "faults", "parked", "decode_tokens", "tokens_emitted",
                "decode_done", "payload_bits", "tl")

    @staticmethod
    def _same_store(a: FleetMetrics, b: FleetMetrics):
        for col in TestRecordAndJournalModes.TERMINAL:
            va = getattr(a.store, col)
            vb = getattr(b.store, col)
            assert np.array_equal(va, vb, equal_nan=True), col

    def test_light_records_identical(self):
        trace, faults = _chaos(n=200, seed=1)
        full = _run(trace, faults, records="full")
        light = _run(trace, faults, records="light")
        self._same_store(full, light)
        assert full.summary() == light.summary()
        # full keeps deployments for every committed attempt; light none
        done = full.completed()
        assert done and all(r.deployment is not None for r in done)
        assert all(r.deployment is None for r in light.completed())

    def test_journal_modes_identical(self):
        trace, faults = _chaos(n=200, seed=2)
        full = _run(trace, faults, journal="full")
        light = _run(trace, faults, journal="light")
        off = _run(trace, faults, journal="off")
        self._same_store(full, light)
        self._same_store(full, off)
        assert full.summary() == light.summary() == off.summary()
        # light journals the same events in the same order, columnar
        assert len(light.journal) == len(full.journal)
        assert np.array_equal(
            light.journal.times,
            np.array([e.time for e in full.journal.entries]))
        assert sum(light.journal.counts().values()) == len(full.journal)
        assert off.journal is None

    def test_columnar_metrics_match_legacy_aggregation(self):
        """Every FleetMetrics aggregate: columnar fast path == the
        record-by-record legacy loop on materialized dataclasses."""
        trace, faults = _chaos(n=250, seed=4)
        m = _run(trace, faults)
        legacy = FleetMetrics(
            records=[m.records[i] for i in range(len(m.records))],
            server_busy=m.server_busy,
            queue_samples=[(float(t), int(d)) for t, d in m.queue_samples],
            horizon=m.horizon, dead_letters=m.dead_letters,
            journal=m.journal, store=None)
        assert legacy.summary() == m.summary()
        assert legacy.deadline_miss_rate() == m.deadline_miss_rate()
        assert legacy.drop_reasons() == m.drop_reasons()
        assert legacy.retry_rate() == m.retry_rate()
        assert legacy.goodput_rps() == m.goodput_rps()
        assert legacy.mean_stage_seconds() == m.mean_stage_seconds()
        assert np.array_equal(legacy.latencies(), m.latencies())
        assert np.array_equal(legacy.ttfts(), m.ttfts())
        assert [r.index for r in legacy.completed()] \
            == [r.index for r in m.completed()]
        legacy.assert_terminal()
        m.assert_terminal()

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_journal_off_never_changes_terminal_records(self, seed):
        trace = poisson_trace("mnist", 25, 400.0, [DEV], [Channel()], W,
                              budgets=(0.004, 0.02), deadlines=(0.05, 0.2),
                              device_pool=6, seed=seed)
        full = _run(trace, None, journal="full")
        off = _run(trace, None, journal="off")
        self._same_store(full, off)
        assert full.summary() == off.summary()


class TestLazyRecords:
    def test_sequence_facade(self):
        trace, faults = _chaos(n=60, seed=6)
        m = _run(trace, faults)
        recs = m.records
        assert len(recs) == 60
        assert recs[0].index == 0 and recs[-1].index == 59
        assert recs[5] is recs[5]            # memoized view
        assert [r.index for r in recs[10:13]] == [10, 11, 12]
        assert sum(1 for _ in recs) == 60
        with pytest.raises(IndexError):
            recs[60]

    def test_invalid_modes_rejected(self):
        for kw in ({"journal": "none"}, {"records": "columnar"},
                   {"admission": "scalar"}):
            with pytest.raises(ValueError):
                FleetEngine(SRV, servers=[SLOW], **kw)


def _f32(cfg):
    import dataclasses
    return dataclasses.replace(cfg, dtype="float32")
