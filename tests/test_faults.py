"""Fault-tolerance tests (serving.engine resilience layer, DESIGN.md
§10): fault injection and trace generators, disconnect recovery
(reservation release, cache invalidation, parking), retry with degraded
budget, dead-letter accounting, the exact epoch-boundary fix, the
replayable event journal, and the ≥1k-request chaos accounting
invariant."""
import dataclasses
import math

import pytest

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import (DEGRADE, DISCONNECT, RECONNECT,
                                  REASON_ABANDONED, REASON_EXHAUSTED,
                                  EventJournal, FaultEvent, FaultInjector,
                                  FleetEngine, RetryPolicy, churn_trace,
                                  degrade_trace, diurnal_arrivals,
                                  materialize, mmpp_arrivals)
from repro.serving.errors import FaultConfigError
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import poisson_trace, stub_classifier_server

from tests._hypothesis_shim import given, settings, st

pytestmark = pytest.mark.smoke

DEV = DeviceProfile()
CH = Channel(capacity_bps=2e6)
W = ObjectiveWeights()


def stub_server(server=None, channel=CH):
    return stub_classifier_server([("mnist", MNIST_MLP)], server=server,
                                  device=DEV, channel=channel, weights=W)


def req(budget=0.01, channel=CH, **kw):
    return InferenceRequest("mnist", budget, DEV, channel, W, **kw)


def mid(t0: float, t1: float) -> float:
    assert t1 > t0
    return (t0 + t1) / 2


# shared read-only pricing server (the store is immutable under pricing)
SRV = stub_server()
# offloading unattractive (10 MHz server, fast channel): plans go
# device-side (p > 0), so model segments really ship and disconnects
# have a radio window to land in
SLOW_FLEET = [ServerProfile(f_clock=1e7)]
SRV_SLOW = stub_server(server=SLOW_FLEET[0], channel=Channel())


def slow_req(**kw):
    return req(channel=Channel(), **kw)


# ---------------------------------------------------------------------------
class TestFaultPrimitives:
    def test_fault_event_validation(self):
        with pytest.raises(FaultConfigError):
            FaultEvent(1.0, "power_surge", "dev-1")
        with pytest.raises(FaultConfigError):
            FaultEvent(-1.0, DISCONNECT, "dev-1")
        with pytest.raises(FaultConfigError):
            FaultEvent(1.0, DEGRADE, "dev-1", factor=0.0)
        rt = FaultEvent.from_dict(FaultEvent(0.5, DEGRADE, "d",
                                             factor=0.25).to_dict())
        assert rt == FaultEvent(0.5, DEGRADE, "d", factor=0.25)

    def test_injector_sorted_and_addable(self):
        a = FaultInjector([FaultEvent(2.0, DISCONNECT, "x"),
                           FaultEvent(1.0, RECONNECT, "x")])
        b = FaultInjector([FaultEvent(1.5, DEGRADE, "y", factor=0.5)])
        merged = a + b
        assert [f.time for f in merged.events] == [1.0, 1.5, 2.0]
        assert len(merged) == 3

    def test_churn_trace_alternates_per_device(self):
        tr = churn_trace(["a", "b"], horizon=10.0, mean_uptime=1.0,
                         mean_downtime=0.3, seed=7)
        assert len(tr) > 0
        for dev in ("a", "b"):
            kinds = [f.kind for f in tr.events if f.device_id == dev]
            assert kinds[0] == DISCONNECT
            assert all(k != kinds[i] for i, k in enumerate(kinds[1:]))

    def test_degrade_trace_restores(self):
        tr = degrade_trace(["a"], horizon=20.0, mean_interval=1.0,
                           mean_duration=0.2, seed=3)
        factors = [f.factor for f in tr.events]
        assert any(f < 1.0 for f in factors)
        # every degrade episode that ends restores factor 1.0
        assert factors[1] == 1.0
        assert all(t0.time <= t1.time
                   for t0, t1 in zip(tr.events, tr.events[1:]))

    def test_trace_generators_monotone(self):
        for arr in (mmpp_arrivals(200, seed=1), diurnal_arrivals(200, seed=1)):
            assert len(arr) == 200
            assert all(b > a for a, b in zip(arr, arr[1:]))

    def test_retry_policy(self):
        rp = RetryPolicy(max_attempts=4, base_backoff_s=0.1,
                         backoff_factor=2.0, max_backoff_s=0.3)
        assert rp.backoff(2) == pytest.approx(0.1)
        assert rp.backoff(3) == pytest.approx(0.2)
        assert rp.backoff(4) == pytest.approx(0.3)   # capped
        assert rp.budget_for(req()) == 4
        assert rp.budget_for(req(attempt_budget=1)) == 1
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(backoff_factor=0.5)


# ---------------------------------------------------------------------------
class TestDisconnectRecovery:
    def _fault_free(self, r):
        return FleetEngine(SRV_SLOW, servers=SLOW_FLEET).run([r]).records[0]

    def test_midflight_disconnect_cancels_parks_and_retries(self):
        r = slow_req(device_id="phone-1")
        tl = self._fault_free(r).timeline
        cut = mid(tl.admit, tl.transfer_done)
        faults = [FaultEvent(cut, DISCONNECT, "phone-1"),
                  FaultEvent(cut + 0.5, RECONNECT, "phone-1")]
        eng = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                          retry=RetryPolicy(base_backoff_s=0.01),
                          faults=faults)
        m = eng.run([r])
        rec = m.records[0]
        assert rec.completed and rec.faults == 1 and rec.attempts == 2
        # backoff fired while the device was still down: the retry parked
        assert rec.parked == 1
        # the retry re-admits only after the reconnect
        assert rec.timeline.admit >= cut + 0.5
        assert rec.latency > tl.latency_from(r.arrival_time)
        assert not m.dead_letters
        m.assert_terminal()

    def test_attempt_past_transfer_done_completes_server_side(self):
        """Once the cut activation reached the server, a disconnect no
        longer cancels: the attempt completes untouched."""
        r = req(segment_cached=True, device_id="phone-1")
        base = FleetEngine(SRV).run([r]).records[0]
        assert base.timeline.finish > base.timeline.transfer_done
        cut = mid(base.timeline.transfer_done, base.timeline.finish)
        rec = FleetEngine(SRV,
                          faults=[FaultEvent(cut, DISCONNECT, "phone-1")]
                          ).run([r]).records[0]
        assert rec.completed and rec.faults == 0 and rec.attempts == 1
        assert rec.timeline == base.timeline

    def test_cancellation_releases_reservation(self):
        """The cancelled attempts' server seconds are refunded: a
        request admitted after the cancel prices an empty backlog."""
        burst = [req(segment_cached=True, device_id="d1")
                 for _ in range(16)]
        tl0 = FleetEngine(SRV).run(burst).records[0].timeline
        cut = tl0.admit + 0.1 * (tl0.transfer_done - tl0.admit)
        probe = req(segment_cached=True, device_id="d2",
                    arrival_time=cut + 1e-5)
        # fault-free: the probe prices the burst's reservations
        base = FleetEngine(SRV).run(burst + [probe]).records[-1]
        assert base.backlog_at_admission > 0
        m = FleetEngine(SRV, faults=[FaultEvent(cut, DISCONNECT, "d1")]
                        ).run(burst + [probe])
        assert m.records[-1].completed
        assert m.records[-1].backlog_at_admission == 0.0
        # d1 never reconnects: the retries park forever -> dead letters
        assert all(r.drop_reason == REASON_ABANDONED
                   for r in m.records[:-1])
        assert all(d.reason == REASON_ABANDONED for d in m.dead_letters)
        m.assert_terminal()

    def test_cache_invalidated_when_cut_precedes_ship_done(self):
        """Disconnect mid-shipment: the pending CACHE_INSTALL is stale,
        so the retry pays the full weight payload again."""
        r = slow_req(device_id="phone-1")
        base = self._fault_free(r)
        assert base.deployment.plan.p > 0
        full = base.deployment.plan.payload_bits
        cut = mid(base.timeline.admit, base.timeline.ship_done)
        rec = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                          retry=RetryPolicy(base_backoff_s=0.01),
                          faults=[FaultEvent(cut, DISCONNECT, "phone-1"),
                                  FaultEvent(cut + 0.2, RECONNECT,
                                             "phone-1")]).run([r]).records[0]
        assert rec.completed and rec.attempts == 2
        assert rec.deployment.payload_bits == full

    def test_cache_survives_when_cut_follows_ship_done(self):
        """Disconnect after the downlink finished but before the
        activation uplink: the device keeps the weights, so the retry
        pays activation-only."""
        r = slow_req(device_id="phone-1")
        base = self._fault_free(r)
        assert base.timeline.transfer_done > base.timeline.ship_done
        cut = mid(base.timeline.ship_done, base.timeline.transfer_done)
        rec = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                          retry=RetryPolicy(base_backoff_s=0.01),
                          faults=[FaultEvent(cut, DISCONNECT, "phone-1"),
                                  FaultEvent(cut + 0.2, RECONNECT,
                                             "phone-1")]).run([r]).records[0]
        assert rec.completed and rec.attempts == 2
        assert rec.deployment.payload_bits == \
            rec.deployment.plan.payload_x_bits
        assert rec.deployment.payload_bits < base.deployment.plan.payload_bits

    def test_arrival_on_down_device_parks_without_burning_attempts(self):
        r = slow_req(device_id="phone-1", arrival_time=1.0)
        rec = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                          faults=[FaultEvent(0.5, DISCONNECT, "phone-1"),
                                  FaultEvent(2.0, RECONNECT, "phone-1")]
                          ).run([r]).records[0]
        assert rec.completed and rec.parked == 1 and rec.attempts == 1
        assert rec.timeline.admit >= 2.0

    def test_parked_forever_becomes_abandoned_dead_letter(self):
        r = slow_req(device_id="phone-1", arrival_time=1.0)
        m = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                        faults=[FaultEvent(0.5, DISCONNECT, "phone-1")]
                        ).run([r])
        rec = m.records[0]
        assert rec.rejected and rec.drop_reason == REASON_ABANDONED
        assert rec.attempts == 0 and rec.deployment is None
        assert [d.reason for d in m.dead_letters] == [REASON_ABANDONED]
        assert m.summary()["drop_reasons"] == {REASON_ABANDONED: 1}
        m.assert_terminal()


# ---------------------------------------------------------------------------
class TestRetryPolicyInEngine:
    def test_retries_exhausted_goes_to_dead_letter_queue(self):
        """Cut every attempt mid-flight: the attempt budget runs out and
        the request terminates in the DLQ with a recorded reason."""
        r = slow_req(device_id="phone-1")
        retry = RetryPolicy(max_attempts=2, base_backoff_s=0.01)
        tl1 = FleetEngine(SRV_SLOW, servers=SLOW_FLEET).run([r]
                                                            ).records[0].timeline
        cut1 = mid(tl1.admit, tl1.transfer_done)
        f1 = [FaultEvent(cut1, DISCONNECT, "phone-1"),
              FaultEvent(cut1 + 0.2, RECONNECT, "phone-1")]
        # attempt 2's window comes from the singly-faulted run
        tl2 = FleetEngine(SRV_SLOW, servers=SLOW_FLEET, retry=retry,
                          faults=f1).run([r]).records[0].timeline
        cut2 = mid(tl2.admit, tl2.transfer_done)
        m = FleetEngine(SRV_SLOW, servers=SLOW_FLEET, retry=retry,
                        faults=f1 + [FaultEvent(cut2, DISCONNECT, "phone-1"),
                                     FaultEvent(cut2 + 0.2, RECONNECT,
                                                "phone-1")]).run([r])
        rec = m.records[0]
        assert rec.rejected and rec.drop_reason == REASON_EXHAUSTED
        assert rec.attempts == 2 and rec.faults == 2
        assert m.dead_letters[0].reason == REASON_EXHAUSTED
        assert m.dead_letters[0].attempts == 2
        m.assert_terminal()

    def test_attempt_budget_override(self):
        """attempt_budget=1 means one strike: the first cancellation is
        terminal even though the policy allows three attempts."""
        r = slow_req(device_id="phone-1", attempt_budget=1)
        tl = FleetEngine(SRV_SLOW, servers=SLOW_FLEET).run([r]
                                                           ).records[0].timeline
        cut = mid(tl.admit, tl.transfer_done)
        m = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                        retry=RetryPolicy(max_attempts=3),
                        faults=[FaultEvent(cut, DISCONNECT, "phone-1"),
                                FaultEvent(cut + 0.2, RECONNECT, "phone-1")]
                        ).run([r])
        assert m.records[0].drop_reason == REASON_EXHAUSTED
        assert m.records[0].attempts == 1

    def test_degrade_on_retry_coarsens_budget(self):
        """With degrade_on_retry, attempt 2 re-prices one accuracy level
        coarser than the original budget (the SLO degrade ladder)."""
        levels = sorted(SRV_SLOW.levels)
        r = slow_req(budget=levels[0], device_id="phone-1")
        base = FleetEngine(SRV_SLOW, servers=SLOW_FLEET).run([r]).records[0]
        assert base.degraded_to is None
        cut = mid(base.timeline.admit, base.timeline.transfer_done)
        rec = FleetEngine(SRV_SLOW, servers=SLOW_FLEET,
                          retry=RetryPolicy(base_backoff_s=0.01,
                                            degrade_on_retry=True),
                          faults=[FaultEvent(cut, DISCONNECT, "phone-1"),
                                  FaultEvent(cut + 0.2, RECONNECT,
                                             "phone-1")]).run([r]).records[0]
        assert rec.completed and rec.attempts == 2
        assert rec.degraded_to == levels[1]
        assert rec.deployment.extra["degraded_to"] == levels[1]


# ---------------------------------------------------------------------------
class TestChannelDegrade:
    def test_degrade_slows_priced_transfer(self):
        r = req(segment_cached=True, arrival_time=1.0, device_id="a")
        base = FleetEngine(SRV).run([r]).records[0]
        rec = FleetEngine(SRV, faults=[FaultEvent(0.5, DEGRADE, "a",
                                                  factor=0.25)]
                          ).run([dataclasses.replace(r)]).records[0]
        assert rec.latency > base.latency

    def test_degrade_targets_only_its_device(self):
        r1 = req(segment_cached=True, arrival_time=1.0, device_id="a")
        r2 = req(segment_cached=True, arrival_time=1.0, device_id="b")
        base = FleetEngine(SRV).run([r1, r2])
        m = FleetEngine(SRV, faults=[FaultEvent(0.5, DEGRADE, "a",
                                                factor=0.25)]).run([r1, r2])
        assert m.records[0].latency > base.records[0].latency
        tb = base.records[1].timeline
        tf = m.records[1].timeline
        assert tf.transfer_done - tf.admit == \
            pytest.approx(tb.transfer_done - tb.admit)

    def test_restore_returns_to_baseline_pricing(self):
        r = req(segment_cached=True, arrival_time=1.0, device_id="a")
        base = FleetEngine(SRV).run([r]).records[0]
        rec = FleetEngine(SRV, faults=[FaultEvent(0.2, DEGRADE, "a",
                                                  factor=0.25),
                                       FaultEvent(0.6, DEGRADE, "a",
                                                  factor=1.0)]
                          ).run([r]).records[0]
        assert rec.deployment.objective == base.deployment.objective
        assert rec.timeline == base.timeline


# ---------------------------------------------------------------------------
class TestEpochBoundary:
    """The exact-bucketing fix: ``ceil(round(t / iv, 9))`` misplaced
    on-boundary arrivals for non-dyadic intervals."""

    def test_on_boundary_arrival_admits_at_its_own_epoch(self):
        iv, k = 0.007, 4691883
        t = k * iv                       # 32843.181000000004
        assert t / iv != k               # the float ratio drifts
        rec = FleetEngine(SRV, epoch_interval=iv).run(
            [req(segment_cached=True, arrival_time=t)]).records[0]
        assert rec.timeline.admit == t   # NOT (k + 1) * iv

    def test_just_after_boundary_never_admits_in_the_past(self):
        iv = 0.007
        t = math.nextafter(iv, math.inf)
        rec = FleetEngine(SRV, epoch_interval=iv).run(
            [req(segment_cached=True, arrival_time=t)]).records[0]
        assert rec.timeline.admit >= t
        assert rec.timeline.admit == 2 * iv

    @given(st.sampled_from([0.001, 0.003, 0.005, 0.007, 0.01, 0.1, 1/3]),
           st.integers(min_value=0, max_value=10_000_000))
    @settings(max_examples=50, deadline=None, derandomize=True)
    def test_admit_epoch_is_minimal(self, iv, k):
        """For any arrival, the admitting epoch k*iv is the SMALLEST
        float multiple of iv at or after the arrival."""
        t = k * iv
        for arrival in (t, math.nextafter(t, math.inf)):
            rec = FleetEngine(SRV, epoch_interval=iv).run(
                [req(segment_cached=True, arrival_time=arrival)]).records[0]
            admit = rec.timeline.admit
            assert admit >= arrival
            j = round(admit / iv)
            assert admit == j * iv
            assert (j - 1) * iv < arrival


# ---------------------------------------------------------------------------
def _chaos_ingredients(n=60, seed=0, device_pool=12):
    arrivals = mmpp_arrivals(n, rates=(100.0, 900.0), mean_dwell=(0.3, 0.1),
                             seed=seed)
    trace = materialize("mnist", arrivals, [DEV], [CH], W,
                        budgets=(0.004, 0.01, 0.02),
                        deadlines=(0.05, 0.2), batches=(1,),
                        device_pool=device_pool, seed=seed)
    horizon = trace[-1].arrival_time + 0.5
    devs = [f"dev-{i}" for i in range(device_pool)]
    faults = (churn_trace(devs[::2], horizon, mean_uptime=0.2,
                          mean_downtime=0.1, seed=seed)
              + degrade_trace(devs[1::2], horizon, mean_interval=0.5,
                              mean_duration=0.1, seed=seed + 1))
    return trace, faults


class TestJournal:
    def test_zero_fault_engine_is_bit_for_bit_sunny_day(self):
        """Default engine vs engine with explicit (empty) fault state:
        identical plans, timelines, servers, everything."""
        trace = poisson_trace("mnist", 50, 400.0, [DEV], [CH], W,
                              budgets=(0.004, 0.01), deadlines=(0.05,),
                              batches=(1,), device_pool=8, seed=2)
        fleet = [ServerProfile(), ServerProfile()]
        a = FleetEngine(SRV, servers=fleet, policy="edf", slo="degrade",
                        epoch_interval=0.005).run(trace)
        b = FleetEngine(SRV, servers=fleet, policy="edf", slo="degrade",
                        epoch_interval=0.005, retry=RetryPolicy(),
                        faults=FaultInjector()).run(trace)
        for ra, rb in zip(a.records, b.records):
            assert ra.rejected == rb.rejected
            assert ra.timeline == rb.timeline
            assert ra.server == rb.server
            if ra.deployment is not None:
                assert ra.deployment.objective == rb.deployment.objective
                assert ra.deployment.payload_bits == rb.deployment.payload_bits
        assert a.server_busy == b.server_busy
        assert a.journal == b.journal or a.journal.diff(b.journal) is None

    def test_journal_replay_of_faulted_run(self):
        trace, faults = _chaos_ingredients()
        eng = FleetEngine(SRV, servers=[ServerProfile()] * 2, policy="edf",
                          slo="degrade", epoch_interval=0.005,
                          retry=RetryPolicy(base_backoff_s=0.01,
                                            degrade_on_retry=True),
                          faults=faults)
        m = eng.run(trace)
        m.journal.verify_replay(SRV, trace,
                                servers=[ServerProfile()] * 2)

    def test_journal_diff_flags_divergence(self):
        trace, faults = _chaos_ingredients()
        kw = dict(servers=[ServerProfile()], epoch_interval=0.005)
        j1 = FleetEngine(SRV, faults=faults, **kw).run(trace).journal
        j2 = FleetEngine(SRV, faults=faults.events[:-4], **kw
                         ).run(trace).journal
        assert j1.diff(j2) is not None
        with pytest.raises(AssertionError):
            j1.verify_replay(SRV, trace, servers=[ServerProfile()] * 3)

    def test_journal_jsonl_round_trip(self):
        trace, faults = _chaos_ingredients(n=30)
        j = FleetEngine(SRV, servers=[ServerProfile()], faults=faults,
                        epoch_interval=0.005).run(trace).journal
        rt = EventJournal.from_jsonl(j.to_jsonl())
        assert rt == j and rt.diff(j) is None
        assert [f.to_dict() for f in rt.fault_trace()] == \
            [f.to_dict() for f in j.fault_trace()]

    @given(st.integers(min_value=0, max_value=30), st.booleans())
    @settings(max_examples=8, deadline=None, derandomize=True)
    def test_any_seeded_trace_replays_identically(self, seed, with_faults):
        """Property: a run journaled from any seeded trace — with or
        without faults — replays to an identical journal and identical
        per-request terminal state."""
        trace, faults = _chaos_ingredients(n=25, seed=seed)
        eng = FleetEngine(SRV, servers=[ServerProfile()], policy="fcfs",
                          slo="degrade", epoch_interval=0.005,
                          retry=RetryPolicy(base_backoff_s=0.01),
                          faults=faults if with_faults else None)
        m = eng.run(trace)
        replayed = m.journal.replay(SRV, trace, servers=[ServerProfile()])
        assert m.journal.diff(replayed.journal) is None
        for ra, rb in zip(m.records, replayed.records):
            assert (ra.rejected, ra.drop_reason, ra.attempts, ra.faults) \
                == (rb.rejected, rb.drop_reason, rb.attempts, rb.faults)
            assert ra.timeline == rb.timeline


# ---------------------------------------------------------------------------
class TestChaosAccounting:
    def test_thousand_request_chaos_run_is_terminally_accounted(self):
        """The acceptance invariant: >=1k requests under churn + drift +
        permanent loss — every request completes, is rejected, or is
        dead-lettered with a reason; nothing is lost."""
        trace, faults = _chaos_ingredients(n=1000, seed=5, device_pool=40)
        horizon = trace[-1].arrival_time + 0.5
        # a couple of devices die mid-trace and never come back
        faults = faults + FaultInjector(
            [FaultEvent(horizon * 0.4, DISCONNECT, "dev-1"),
             FaultEvent(horizon * 0.5, DISCONNECT, "dev-3")])
        m = FleetEngine(SRV, servers=[ServerProfile()] * 3,
                        policy="least_loaded", slo="degrade",
                        epoch_interval=0.005,
                        retry=RetryPolicy(base_backoff_s=0.005,
                                          max_backoff_s=0.05,
                                          degrade_on_retry=True),
                        faults=faults).run(trace)
        m.assert_terminal()
        s = m.summary()
        assert s["requests"] == 1000
        assert s["completed"] + s["rejected"] == 1000
        assert s["completed"] > 0
        assert sum(s["drop_reasons"].values()) == s["rejected"]
        assert s["dead_lettered"] == len(m.dead_letters)
        # queue drains: no request left in flight
        assert m.queue_samples[-1][1] == 0
