"""Training loop, optimizer, checkpointing and data-pipeline tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import (TokenStream, TokenStreamConfig, minibatches,
                                 synthetic_mnist)
from repro.models import transformer as T
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import (AdamWConfig, adamw_update, cosine_lr,
                                   global_norm, init_opt_state)
from repro.train.train_loop import init_train_state, lm_loss, make_train_step

KEY = jax.random.key(0)


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                          total_steps=100)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clipping(self):
        params = {"w": jnp.zeros(3)}
        state = init_opt_state(params)
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        huge = {"w": jnp.full(3, 1e6)}
        _, _, metrics = adamw_update(cfg, params, huge, state)
        assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip

    def test_cosine_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(cosine_lr(cfg, 0)) == 0.0
        assert float(cosine_lr(cfg, 10)) == pytest.approx(1.0, abs=1e-5)
        assert float(cosine_lr(cfg, 100)) == pytest.approx(0.1, abs=1e-5)

    def test_global_norm(self):
        t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        assert float(global_norm(t)) == pytest.approx(5.0)


class TestTrainLoop:
    def test_loss_decreases_smollm_reduced(self):
        cfg = get_config("smollm-135m").reduced()
        params, opt = init_train_state(KEY, cfg)
        step = jax.jit(make_train_step(
            cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40),
            remat=False))
        stream = TokenStream(TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=65, batch_size=8))
        losses = []
        for i, batch in enumerate(stream.batches()):
            if i >= 40:
                break
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05

    def test_remat_equals_no_remat_loss(self):
        cfg = get_config("smollm-135m").reduced()
        params, _ = init_train_state(KEY, cfg)
        batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
        l1, _ = lm_loss(params, cfg, batch, remat=False)
        l2, _ = lm_loss(params, cfg, batch, remat=True)
        assert float(jnp.abs(l1 - l2)) < 1e-4

    def test_moe_aux_losses_flow(self):
        cfg = get_config("olmoe-1b-7b").reduced()
        params, opt = init_train_state(KEY, cfg)
        step = make_train_step(cfg, AdamWConfig(), remat=False)
        batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
        _, _, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert 0.0 <= float(metrics["dropped_frac"]) <= 1.0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = get_config("smollm-135m").reduced()
        params, opt = init_train_state(KEY, cfg)
        path = os.path.join(tmp_path, "ckpt")
        save_checkpoint(path, params, opt, step=7, metadata={"arch": "x"})
        p2, o2, meta = load_checkpoint(path, params, opt)
        assert meta["step"] == 7 and meta["arch"] == "x"
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDataPipeline:
    def test_token_stream_deterministic_and_restartable(self):
        cfg = TokenStreamConfig(vocab_size=64, seq_len=17, batch_size=4)
        s1 = [b["tokens"] for _, b in zip(range(3), TokenStream(cfg).batches())]
        s2 = [b["tokens"] for _, b in zip(range(3), TokenStream(cfg).batches())]
        for a, b in zip(s1, s2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restart mid-stream
        s3 = next(TokenStream(cfg).batches(start_step=2))
        np.testing.assert_array_equal(np.asarray(s1[2]), np.asarray(s3["tokens"]))

    def test_token_stream_has_structure(self):
        """The low-rank bigram source must be more predictable than
        uniform: a simple bigram count model beats uniform entropy."""
        cfg = TokenStreamConfig(vocab_size=32, seq_len=257, batch_size=8)
        batch = next(TokenStream(cfg).batches())
        toks = np.asarray(batch["tokens"]).ravel()
        counts = np.ones((32, 32))
        for a, b in zip(toks[:-1], toks[1:]):
            counts[a, b] += 1
        probs = counts / counts.sum(1, keepdims=True)
        nll = -np.mean(np.log(probs[toks[:-1], toks[1:]]))
        assert nll < np.log(32) * 0.98

    def test_synthetic_mnist_learnable(self):
        x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=512, n_test=128)
        assert x_tr.shape == (512, 784) and y_tr.shape == (512,)
        assert x_tr.min() >= 0.0
        assert set(np.unique(y_tr)) <= set(range(10))

    def test_minibatches_cover_epoch(self):
        x = np.arange(100, dtype=np.float32)[:, None]
        y = np.arange(100, dtype=np.int32)
        seen = set()
        it = minibatches(x, y, 10)
        for _ in range(10):
            bx, by = next(it)
            seen.update(np.asarray(by).tolist())
        assert len(seen) == 100


class TestGradAccumulation:
    def test_accum_matches_full_batch(self):
        """accum_steps=4 must produce the same update as one full batch
        (same grads up to fp reassociation)."""
        cfg = get_config("smollm-135m").reduced()
        params, opt = init_train_state(KEY, cfg)
        batch = {"tokens": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)}
        s1 = make_train_step(cfg, AdamWConfig(lr=1e-3), remat=False,
                             accum_steps=1)
        s4 = make_train_step(cfg, AdamWConfig(lr=1e-3), remat=False,
                             accum_steps=4)
        p1, _, m1 = s1(params, opt, batch)
        p4, _, m4 = s4(params, opt, batch)
        # microbatch statistics average to the full-batch loss
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-3)
