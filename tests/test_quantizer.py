"""Quantizer unit + property tests (paper Eq. 9–10, 18–19)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.quantizer import (analytic_noise_scale, dequantize,
                                  fake_quant, payload_bits, quant_noise_energy,
                                  quantize, round_bits)

LN4 = np.log(4.0)

pytestmark = pytest.mark.smoke


def _rand(shape, seed=0, lo=-3.0, hi=5.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


class TestQuantizeBasics:
    def test_codes_in_range(self):
        x = _rand((64, 32))
        for bits in (2, 4, 8, 12):
            codes, scale, mu = quantize(x, bits)
            assert int(codes.min()) >= 0
            assert int(codes.max()) <= (1 << bits) - 1

    def test_roundtrip_error_bounded_by_half_step(self):
        x = _rand((128,))
        for bits in (3, 5, 8):
            codes, scale, mu = quantize(x, bits)
            xq = dequantize(codes, scale, mu)
            assert float(jnp.max(jnp.abs(x - xq))) <= float(scale) / 2 + 1e-6

    def test_extremes_are_exact_gridpoints(self):
        x = _rand((50,))
        codes, scale, mu = quantize(x, 8)
        xq = dequantize(codes, scale, mu)
        assert np.isclose(float(xq.min()), float(x.min()), atol=1e-5)
        assert np.isclose(float(xq.max()), float(x.max()), atol=1e-5)

    def test_fake_quant_idempotent(self):
        x = _rand((32, 16))
        q1 = fake_quant(x, 6)
        q2 = fake_quant(q1, 6)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-5)

    def test_round_bits_clips(self):
        b = jnp.array([0.3, 2.2, 7.9, 40.0])
        r = np.asarray(round_bits(b, lo=2, hi=16))
        assert r.tolist() == [2, 3, 8, 16]

    def test_payload_bits(self):
        assert float(payload_bits(1000, 8)) == 1000 * 8 + 64

    def test_pinned_mu_only(self):
        """Regression: quantize(x, b, mu=...) with phi=None must fall back
        to the tensor max for the top of the grid."""
        x = _rand((128,), lo=0.5, hi=2.0)
        codes, scale, mu = quantize(x, 8, mu=0.0)
        assert float(mu) == 0.0
        xq = dequantize(codes, scale, mu)
        assert np.isclose(float(xq.max()), float(x.max()), atol=1e-5)
        assert float(jnp.max(jnp.abs(x - xq))) <= float(scale) / 2 + 1e-6

    def test_pinned_phi_only(self):
        x = _rand((128,), lo=-2.0, hi=-0.5)
        codes, scale, mu = quantize(x, 8, phi=0.0)
        xq = dequantize(codes, scale, mu)
        assert np.isclose(float(xq.min()), float(x.min()), atol=1e-5)

    def test_stacked_wire_bits_counts_real_metadata(self):
        from repro.core.quantizer import quantize_stacked, stacked_wire_bits
        w = _rand((2, 16, 8))
        q8 = quantize_stacked(w, 8)                   # per-channel default
        assert stacked_wire_bits(q8) == 2 * 16 * 8 * 8 + 32 * 2 * (2 * 8)
        q8t = quantize_stacked(w, 8, per_channel=False)
        assert stacked_wire_bits(q8t) == 2 * 16 * 8 * 8 + 32 * 2 * 2
        q4 = quantize_stacked(w, 4)                   # packed: half codes
        assert stacked_wire_bits(q4) == 2 * 16 * 4 * 8 + 32 * 2 * (2 * 8)


class TestNoiseLaw:
    """Paper Eq. 18: ||sigma(b)||^2 = s * e^(-ln4 * b). The uniform
    quantizer's round-off energy must follow the 4^-b law and match the
    analytic scale s = n * range^2 / 12."""

    def test_exponent_matches_minus_ln4(self):
        x = _rand((4096,), seed=3)
        bits = np.arange(4, 10)
        energies = np.array([float(quant_noise_energy(x, int(b)))
                             for b in bits])
        slope = np.polyfit(bits, np.log(energies), 1)[0]
        assert abs(slope - (-LN4)) < 0.08 * LN4

    def test_analytic_scale_matches_measured(self):
        x = _rand((8192,), seed=7)
        s = float(analytic_noise_scale(x))
        for b in (6, 8):
            measured = float(quant_noise_energy(x, b))
            predicted = s * np.exp(-LN4 * b)
            assert 0.7 < measured / predicted < 1.4, (b, measured, predicted)


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_property_noise_monotone_in_bits(bits, seed):
    """More bits never increases quantization noise (the monotonicity the
    solver's ceil-rounding relies on)."""
    x = _rand((512,), seed=seed)
    e1 = float(quant_noise_energy(x, bits))
    e2 = float(quant_noise_energy(x, bits + 1))
    assert e2 <= e1 + 1e-6


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), lo=st.floats(-10, 0), width=st.floats(0.1, 20))
def test_property_quantize_respects_range(seed, lo, width):
    x = _rand((256,), seed=seed, lo=lo, hi=lo + width)
    codes, scale, mu = quantize(x, 8)
    xq = dequantize(codes, scale, mu)
    assert float(xq.min()) >= lo - float(scale)
    assert float(xq.max()) <= lo + width + float(scale)
