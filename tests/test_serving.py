"""End-to-end QPART serving tests (paper §V claims, scaled down):
calibrate -> offline store -> online serve -> Deployment.execute ->
measured accuracy degradation within budget, payload reduced vs f32,
QPART beats the no-opt baseline on the objective at matched accuracy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile, delta_coeff, eps_coeff,
                                   xi_coeff)
from repro.data.pipeline import minibatches, synthetic_mnist
from repro.models.classifier import classifier_forward, init_classifier
from repro.serving.backends import ClassifierBackend
from repro.serving.baselines import (AutoencoderBaseline, PruningBaseline,
                                     no_opt_offload)
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest


@pytest.fixture(scope="module")
def trained_mnist():
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=4096, n_test=1024)
    params = init_classifier(jax.random.key(0), MNIST_MLP)

    def loss_fn(p, x, y):
        lg = classifier_forward(p, MNIST_MLP, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(y)), y])

    @jax.jit
    def step(p, x, y):
        _, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    it = minibatches(x_tr, y_tr, 128)
    for _ in range(400):
        bx, by = next(it)
        params = step(params, bx, by)
    return params, (x_tr, y_tr, x_te, y_te)


@pytest.fixture(scope="module")
def backend(trained_mnist):
    params, _ = trained_mnist
    return ClassifierBackend(MNIST_MLP, params)


@pytest.fixture(scope="module")
def served(trained_mnist, backend):
    params, (x_tr, y_tr, x_te, y_te) = trained_mnist
    srv = QPARTServer()
    srv.register("mnist", backend, x_tr[:512], y_tr[:512])
    srv.calibrate("mnist")
    dev, ch, w = DeviceProfile(), Channel(), ObjectiveWeights()
    srv.build_store("mnist", dev, ch, w)
    return srv, (dev, ch, w), (x_te, y_te)


class TestQPARTEndToEnd:
    def test_base_accuracy_reasonable(self, served):
        srv, _, _ = served
        assert srv.models["mnist"].base_accuracy > 0.9

    def test_degradation_within_budget(self, served):
        srv, (dev, ch, w), (x_te, y_te) = served
        for budget in (0.005, 0.01, 0.02):
            dep = srv.serve(InferenceRequest("mnist", budget, dev, ch, w))
            res = dep.execute(jnp.asarray(x_te), y_te)
            # Delta calibration is statistical; allow 2x slack + noise floor
            assert res.accuracy_degradation <= 2 * budget + 0.01, \
                (budget, res.accuracy_degradation)

    def test_noise_profile_calibrated(self, served):
        srv, _, _ = served
        m = srv.models["mnist"]
        assert np.all(m.s_w > 0) and np.all(m.rho > 0)
        assert len(m.s_w) == MNIST_MLP.num_layers

    def test_payload_reduced_vs_f32_when_on_device(self, served, backend):
        """Fig. 3: when the plan keeps layers on-device the quantized wire
        size must be way below the f32 wire size of the same segment."""
        srv, (dev, ch, w), (x_te, y_te) = served
        m = srv.models["mnist"]
        specs = backend.layer_specs()
        # force evaluation of every stored partition pattern
        for (a, p), plan in m.store().plans.items():
            if p == 0:
                continue
            f32_wire = sum(specs[i].z_w for i in range(p)) * 32.0 \
                + specs[p - 1].z_x * 32.0
            assert plan.payload_bits < f32_wire
            # paper claims >80% payload reduction at the lax budgets
            if a >= 0.01:
                assert plan.payload_bits < 0.5 * f32_wire, (a, p)

    def test_bits_monotone_in_budget(self, served):
        """Tighter accuracy budgets must never use fewer bits."""
        srv, _, _ = served
        m = srv.models["mnist"]
        p = 3
        tight = m.store().plans[(0.001, p)].bits_w
        loose = m.store().plans[(0.02, p)].bits_w
        assert np.all(tight >= loose - 1e-9)

    def test_quantized_execution_runs(self, served):
        srv, (dev, ch, w), (x_te, y_te) = served
        dep = srv.serve(InferenceRequest("mnist", 0.01, dev, ch, w))
        res = dep.execute(jnp.asarray(x_te), y_te)
        assert res.accuracy is not None and res.accuracy > 0.8
        assert res.objective > 0
        assert dep.accuracy == res.accuracy     # view over the same result

    def test_device_segment_callable(self, served):
        """The Deployment hands out a callable quantized device segment
        whose cut activation feeds the server tail to the same logits the
        executed result was measured on."""
        srv, (dev, ch, w), (x_te, y_te) = served
        m = srv.models["mnist"]
        plan = m.store().plans[(0.01, 3)]
        seg = m.backend.device_executor(plan)
        assert seg.payload_bits > 0
        # plan-time memory accounting == materialized segment footprint
        assert seg.memory_bytes == pytest.approx(plan.device_memory_bytes)
        h = seg(jnp.asarray(x_te[:32]))
        logits = m.backend.forward_from_layer(h, plan.p)
        assert logits.shape == (32, MNIST_MLP.num_classes)


class TestServeBatch:
    """The batched window pricing must be result-for-result identical to
    the per-request serve loop (same plan object, objective, costs)."""

    def _window(self, dev, ch, w, n=32):
        strong = dataclasses.replace(dev, f_clock=2e9)
        slow = dataclasses.replace(ch, capacity_bps=2e6)
        budgets = (0.001, 0.004, 0.011, 0.05)
        return [InferenceRequest("mnist", budgets[i % 4],
                                 strong if i % 3 == 0 else dev,
                                 slow if i % 2 else ch, w,
                                 batch=1 + (i % 2) * 3,
                                 segment_cached=bool(i % 5))
                for i in range(n)]

    def test_matches_sequential_serve(self, served):
        srv, (dev, ch, w), _ = served
        reqs = self._window(dev, ch, w)
        batch = srv.serve_batch(reqs)
        for req, br in zip(reqs, batch):
            sr = srv.serve(req)
            assert br.plan is sr.plan
            assert br.objective == pytest.approx(sr.objective, rel=1e-9)
            assert br.payload_bits == pytest.approx(sr.payload_bits, rel=1e-12)
            assert br.costs.t_total == pytest.approx(sr.costs.t_total,
                                                     rel=1e-9)
            assert br.costs.e_total == pytest.approx(sr.costs.e_total,
                                                     rel=1e-9)
            np.testing.assert_array_equal(np.asarray(br.extra["bits_w"]),
                                          np.asarray(sr.extra["bits_w"]))

    def test_matches_prerefactor_reference(self, served, backend):
        """Regression lock: serve/serve_batch must reproduce the
        PRE-backend-refactor Alg. 2 semantics on the classifier path —
        reimplemented inline here exactly as the old ``serve`` computed
        them (store.lookup over the level's plans with the reduced-
        coefficient runtime objective, no memory filter: the default
        device fits every MNIST plan)."""
        srv, (dev, ch, w), _ = served
        m = srv.models["mnist"]
        store = m.store()
        reqs = self._window(dev, ch, w, n=16)
        batch = srv.serve_batch(reqs)
        for req, br in zip(reqs, batch):
            from repro.core.cost_model import classifier_layer_specs
            specs = classifier_layer_specs(MNIST_MLP, batch=req.batch)
            xi = xi_coeff(req.weights, req.device)
            dl = delta_coeff(req.weights, srv.server)
            ep = eps_coeff(req.weights, req.device, req.channel)
            o_cum = np.cumsum([sp.o for sp in specs])

            def runtime_objective(plan):
                o1 = o_cum[plan.p - 1] if plan.p else 0.0
                wire = plan.payload_x_bits if req.segment_cached \
                    else plan.payload_bits
                return xi * o1 + dl * (o_cum[-1] - o1) + ep * wire

            ref_plan = store.lookup(req.accuracy_budget, runtime_objective)
            assert br.plan is ref_plan
            # objective recomputed from the chosen plan's cost breakdown
            o1 = o_cum[ref_plan.p - 1] if ref_plan.p else 0.0
            wire = ref_plan.payload_x_bits if req.segment_cached \
                else ref_plan.payload_bits
            from repro.core.cost_model import cost_breakdown
            costs = cost_breakdown(float(o1), float(o_cum[-1] - o1), wire,
                                   req.device, srv.server, req.channel)
            assert br.objective == pytest.approx(
                costs.objective(req.weights), rel=1e-12)

    def test_empty_window(self, served):
        srv, _, _ = served
        assert srv.serve_batch([]) == []

    def test_mixed_accuracy_levels_pick_feasible(self, served):
        srv, (dev, ch, w), _ = served
        m = srv.models["mnist"]
        for a in (0.0012, 0.006, 0.03, 0.2):
            dep = srv.serve_batch([InferenceRequest("mnist", a, dev, ch, w)])[0]
            lv = [k[0] for k, v in m.store().plans.items() if v is dep.plan][0]
            assert lv <= a or lv == min(srv.levels)


class TestMeasuredTimings:
    """CostModel v2 satellites: wall-clock-fenced stage timings on
    execute, the calibration ledger, and the calibrated provider."""

    def test_execute_records_measured_stages(self, served):
        srv, (dev, ch, w), (x_te, y_te) = served
        dep = srv.serve(InferenceRequest("mnist", 0.01, dev, ch, w))
        dep.execute(jnp.asarray(x_te[:64]), y_te[:64])
        m = dep.result.extra["measured"]
        assert m["batch"] == 64
        assert m["t_device_s"] >= 0 and m["t_server_s"] >= 0
        assert m["t_total_s"] == pytest.approx(
            m["t_device_s"] + m["t_server_s"])
        # the predicted breakdown rides alongside
        assert m["t_device_pred_s"] == dep.costs.t_local
        assert m["t_server_pred_s"] == dep.costs.t_server

    def test_ledger_fit_and_calibrated_serving(self, served):
        srv, (dev, ch, w), (x_te, y_te) = served
        for budget in (0.005, 0.02):
            for batch in (32, 128):
                dep = srv.serve(InferenceRequest("mnist", budget, dev, ch, w,
                                                 batch=batch))
                tx, ty = jnp.asarray(x_te[:batch]), y_te[:batch]
                dep.execute(tx, ty)          # warm (compiles)
                dep.execute(tx, ty)
                srv.record_execution(dep)
        assert len(srv.ledger) == 4
        cal = srv.calibrated_provider()
        # calibrated prediction is in the ballpark of the measured wall
        # clock (same fit data, generous 10x bound); the analytic
        # prediction is orders of magnitude off the host
        from repro.core.cost_model import plan_cost_terms
        dep = srv.serve(InferenceRequest("mnist", 0.01, dev, ch, w, batch=64))
        dep.execute(jnp.asarray(x_te[:64]), y_te[:64])
        dep.execute(jnp.asarray(x_te[:64]), y_te[:64])
        meas = dep.result.extra["measured"]
        o1, o2, db, sb = plan_cost_terms(dep.plan,
                                         dep.backend.layer_specs(batch=64))
        pred = float(cal.device_seconds(dev, o1, db)
                     + cal.server_seconds(srv.server, o2, sb))
        measured = meas["t_device_s"] + meas["t_server_s"]
        assert pred == pytest.approx(measured, rel=10.0)
        # a calibrated server still serves (plans stay feasible)
        srv2_dep = srv.serve(InferenceRequest("mnist", 0.01, dev, ch, w))
        assert srv2_dep.plan is not None

    def test_serve_with_roofline_provider(self, served):
        """A provider swap re-prices the online path without touching
        the stores: roofline objectives are analytic + memory terms."""
        from repro.core.cost_model import RooflineCost
        srv, (dev, ch, w), _ = served
        old = srv.provider
        try:
            srv.provider = RooflineCost()
            dep = srv.serve(InferenceRequest("mnist", 0.01, dev, ch, w))
            ana = srv.models["mnist"].backend  # same stores, new pricing
            assert dep.plan is not None and dep.costs.t_total > 0
            # roofline stage time is lower-bounded by the analytic
            # compute-only term (the memory term is additive)
            from repro.core.cost_model import AnalyticCost, plan_cost_terms
            specs = ana.layer_specs(batch=1)
            o1, o2, _db, _sb = plan_cost_terms(dep.plan, specs)
            assert dep.costs.t_local >= \
                AnalyticCost().device_seconds(dev, o1) - 1e-18
            assert dep.costs.t_server >= \
                AnalyticCost().server_seconds(srv.server, o2) - 1e-18
        finally:
            srv.provider = old


class TestBaselines:
    def test_no_opt_keeps_base_accuracy(self, trained_mnist, backend):
        params, (x_tr, y_tr, x_te, y_te) = trained_mnist
        dev, srv_p, ch, w = (DeviceProfile(), ServerProfile(), Channel(),
                             ObjectiveWeights())
        res = no_opt_offload(backend, 3, dev, srv_p, ch, w,
                             jnp.asarray(x_te), y_te)
        base = float(jnp.mean(jnp.argmax(
            classifier_forward(params, MNIST_MLP, jnp.asarray(x_te)), -1)
            == y_te))
        assert res.accuracy == pytest.approx(base)

    def test_autoencoder_compresses_but_perturbs(self, trained_mnist, backend):
        params, (x_tr, y_tr, x_te, y_te) = trained_mnist
        dev, srv_p, ch, w = (DeviceProfile(), ServerProfile(), Channel(),
                             ObjectiveWeights())
        ae = AutoencoderBaseline(code_ratio=0.25)
        res = ae.offload(backend, 2, jnp.asarray(x_tr[:512]),
                         dev, srv_p, ch, w, jnp.asarray(x_te), y_te)
        assert res.accuracy is not None and res.accuracy > 0.5
        assert res.extra["code_dim"] == int(0.25 * 256)

    def test_pruning_calibration_meets_budget(self, trained_mnist, backend):
        params, (x_tr, y_tr, x_te, y_te) = trained_mnist
        base = float(jnp.mean(jnp.argmax(
            classifier_forward(params, MNIST_MLP, jnp.asarray(x_tr[:1024])),
            -1) == y_tr[:1024]))
        pb = PruningBaseline().calibrated(
            backend, 3, jnp.asarray(x_tr[:1024]),
            y_tr[:1024], budget=0.02, base_accuracy=base)
        assert 0.0 < pb.retain <= 1.0

    def test_qpart_beats_no_opt_objective(self, served, backend):
        """Fig. 7: at every partition point the QPART pattern's objective
        is below the f32 no-opt objective (quantization only reduces the
        payload term; compute terms are identical)."""
        srv, (dev, ch, w), _ = served
        specs = backend.layer_specs()
        m = srv.models["mnist"]
        from repro.serving.simulator import simulate_plan
        for p in range(1, MNIST_MLP.num_layers + 1):
            qp = m.store().plans[(0.01, p)]
            q_res = simulate_plan(qp, specs, dev, ServerProfile(), ch, w)
            n_res = no_opt_offload(backend, p, dev,
                                   ServerProfile(), ch, w)
            assert q_res.objective < n_res.objective, p
